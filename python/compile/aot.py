"""AOT export: jax -> HLO text + weights + metadata (DESIGN.md L2->L3).

This is the single build-time entry point (`make artifacts`):

  1. generate the dataset (data.py) — .npz + .bin
  2. train the zoo (train.py) — checkpoints + loss log
  3. 2:4-prune + fine-tune the STC subset (prune.py)
  4. for every (model, variant): fold BN, quantize weights, and export
       <arch>[_p24]_float.hlo.txt   f(img)                  -> (logits,)
       <arch>[_p24]_calib.hlo.txt   f(img)                  -> (max, mean)
       <arch>[_p24]_sparq.hlo.txt   f(img, scales, cfg)     -> (logits,)
       <arch>[_p24]_weights.npz     int8 weights + scales + biases
       <arch>[_p24]_meta.json       graph IR + layout for the rust engine
  5. write manifest.json + .stamp

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits 64-bit instruction ids that the rust side's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
Lowering uses return_tuple=True; the rust runtime unwraps with
to_tuple1()/to_tuple().

Weights are baked into the HLO as constants, so the rust request path
needs only the HLO text; the .npz/meta.json feed the rust-native engine
(bit-exact cross-validation + STC/Table-6 path + toggle statistics).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as dataset
from . import layers, model, prune, train

EVAL_BATCH = 64
IMG_SHAPE = (dataset.H, dataset.W, dataset.C)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe format).

    print_large_constants=True is load-bearing: the default print options
    elide big literals as `constant({...})`, which the rust side's
    xla_extension 0.5.1 text parser silently reads back as *zeros* —
    every baked weight would vanish (caught by
    rust/tests/integration.rs::exported_graphs_have_no_elided_constants).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text) / 1e6:.2f} MB)")


def export_weights_npz(path, graph, qweights):
    """Flattened int8 GEMM weights + scales + biases for the rust engine.

    Layout per quantized conv `name` (K = C*k*k rows in (C, kh, kw)
    order — must match rust/src/tensor/im2col.rs):
      {name}.wq    int8  (K, O)
      {name}.scale f32   (O,)
      {name}.bias  f32   (O,)
    First (unquantized) conv: {name}.w f32 HWIO, {name}.bias.
    Head: fc.w f32 (C, classes), fc.b f32.
    """
    out = {}
    for node in layers.conv_nodes(graph):
        name = node["name"]
        qp = qweights[name]
        if node["quant"]:
            out[f"{name}.wq"] = np.asarray(
                layers._flatten_weights(qp["wq"]), dtype=np.int8
            )
            out[f"{name}.scale"] = np.asarray(qp["scale"], dtype=np.float32)
            out[f"{name}.bias"] = np.asarray(qp["b"], dtype=np.float32)
        else:
            out[f"{name}.w"] = np.asarray(qp["w"], dtype=np.float32)
            out[f"{name}.bias"] = np.asarray(qp["b"], dtype=np.float32)
    out["fc.w"] = np.asarray(qweights["fc"]["w"], dtype=np.float32)
    out["fc.b"] = np.asarray(qweights["fc"]["b"], dtype=np.float32)
    np.savez(path, **out)


def export_meta_json(path, graph, variant: str):
    meta = {
        "arch": graph["arch"],
        "variant": variant,
        "num_classes": graph["num_classes"],
        "input_hwc": list(IMG_SHAPE),
        "eval_batch": EVAL_BATCH,
        "quant_convs": layers.quant_conv_names(graph),
        "nodes": graph["nodes"],
    }
    json.dump(meta, open(path, "w"), indent=1)


def export_model(arch: str, out_dir: str, pruned: bool = False) -> dict:
    """Export all artifacts for one (arch, variant); returns manifest row."""
    suffix = "_p24" if pruned else ""
    tag = f"{arch}{suffix}"
    stamp = os.path.join(out_dir, f"{tag}_meta.json")
    graph = model.build(arch)
    nq = len(layers.quant_conv_names(graph))
    row = {
        "tag": tag,
        "arch": arch,
        "pruned": pruned,
        "quant_convs": nq,
        "files": {
            kind: f"{tag}_{kind}.hlo.txt" for kind in ("float", "calib", "sparq")
        },
        "weights": f"{tag}_weights.npz",
        "meta": f"{tag}_meta.json",
    }
    if os.path.exists(stamp):
        return row

    params, state = train.load_checkpoint(os.path.join(out_dir, f"ckpt_{tag}.npz"))
    folded = layers.fold_batchnorm(graph, params, state)
    qweights = layers.quantize_weights(graph, folded)
    export_weights_npz(os.path.join(out_dir, f"{tag}_weights.npz"), graph, qweights)

    img = jax.ShapeDtypeStruct((EVAL_BATCH,) + IMG_SHAPE, jnp.float32)
    scales = jax.ShapeDtypeStruct((nq,), jnp.float32)
    cfg = jax.ShapeDtypeStruct((5,), jnp.int32)

    f_float = lambda x: (layers.forward_folded(graph, folded, x),)
    f_calib = lambda x: layers.calib_forward(graph, folded, x)
    f_sparq = lambda x, s, c: (layers.forward_quant(graph, qweights, s, c, x),)

    _write(
        os.path.join(out_dir, row["files"]["float"]),
        to_hlo_text(jax.jit(f_float).lower(img)),
    )
    _write(
        os.path.join(out_dir, row["files"]["calib"]),
        to_hlo_text(jax.jit(f_calib).lower(img)),
    )
    _write(
        os.path.join(out_dir, row["files"]["sparq"]),
        to_hlo_text(jax.jit(f_sparq).lower(img, scales, cfg)),
    )
    export_meta_json(stamp, graph, "p24" if pruned else "dense")
    return row


def prepare_pruned(out_dir: str, d: dict):
    """2:4-prune + fine-tune the STC subset; idempotent per checkpoint."""
    logs = []
    for arch in model.STC_ZOO:
        ckpt = os.path.join(out_dir, f"ckpt_{arch}_p24.npz")
        if os.path.exists(ckpt):
            continue
        params, state = train.load_checkpoint(os.path.join(out_dir, f"ckpt_{arch}.npz"))
        p, s, log = prune.prune_and_finetune(arch, d, params, state)
        graph = model.build(arch)
        assert prune.sparsity(p, graph) >= 0.45, "2:4 pruning did not take"
        train.save_checkpoint(ckpt, p, s)
        log["arch"] = f"{arch}_p24"
        logs.append(log)
        print(f"[prune] {arch}: acc={log['test_acc']:.4f}")
    if logs:
        log_path = os.path.join(out_dir, "train_log.json")
        prev = json.load(open(log_path)) if os.path.exists(log_path) else []
        done = {l["arch"] for l in logs}
        json.dump([l for l in prev if l["arch"] not in done] + logs, open(log_path, "w"), indent=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=train.DEFAULT_STEPS)
    ap.add_argument("--archs", nargs="*", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    d = dataset.load_or_generate(args.out)
    train.train_all(args.out, steps=args.steps, archs=args.archs)
    prepare_pruned(args.out, d)

    manifest = []
    for arch in args.archs or model.ZOO:
        manifest.append(export_model(arch, args.out, pruned=False))
    for arch in model.STC_ZOO:
        if args.archs and arch not in args.archs:
            continue
        manifest.append(export_model(arch, args.out, pruned=True))
    json.dump(manifest, open(os.path.join(args.out, "manifest.json"), "w"), indent=1)
    open(os.path.join(args.out, ".stamp"), "w").write("ok\n")
    print(f"[aot] manifest: {len(manifest)} model variants")


if __name__ == "__main__":
    main()
