"""Pure-jnp SPARQ oracle — the canonical semantics (DESIGN.md S4/S5).

Everything here operates on *already uniformly quantized* integers carried
as int32:

  * activations: unsigned, in [0, 255] (paper: symmetric unsigned
    per-layer min-max quantization of post-ReLU activations),
  * weights: signed, in [-127, 127] (symmetric per-kernel).

All arithmetic is integer-exact, so the Pallas kernel
(kernels/sparq.py), the rust quant library (rust/src/quant/) and the rust
PE cycle simulator (rust/src/hw/pe.rs) are validated for *equality*
against this file, not approximate closeness.

Configuration vector (shared encoding with rust — see
rust/src/quant/config.rs):

  cfg = [n_bits, mode, round_flag, vsparq_flag, w_bits]   (int32[5])

  n_bits : window width for bSPARQ (4, 3 or 2); 8 disables trimming
           (plain A8 behaviour).
  mode   : window-placement set.
             0 = full  — all consecutive placements
                         (5opt for n=4, 6opt for n=3, 7opt for n=2)
             1 = 3opt  — shifts {0, 2, 4}   (n=4 only)
             2 = 2opt  — shifts {0, 4}      (n=4 only; -R == SySMT trim)
             3 = uniform — NOT bSPARQ: plain uniform requantization of the
                         8-bit value to n bits (the A4W8-style baseline).
  round_flag  : 1 = round within the window by the residual LSBs (+R),
                0 = truncate (Trim).
  vsparq_flag : 1 = pair activations along the dot-product axis; a zero
                partner donates its budget (window of 2*n bits, full
                placement set). 0 = per-activation bSPARQ only (-vS).
  w_bits : 8 keeps the stored int8 weights; 4 requantizes them uniformly
           to 4 bits (the A8W4 baseline). Requantized weights are used at
           their reduced integer scale; callers must multiply the output
           dequant scale by `weight_rescale(cfg)`.

Paper mapping:
  Table 1  A8W8        = [8, 0, 0, 0, 8]
           A4W8        = [4, 3, 1, 0, 8]
           A8W4        = [8, 0, 0, 0, 4]
  Table 2  5opt Trim   = [4, 0, 0, 1, 8]
           5opt +R     = [4, 0, 1, 1, 8]
           5opt +R -vS = [4, 0, 1, 0, 8]
           3opt ...    = mode 1, 2opt ... = mode 2
  Table 4  3b 6opt     = [3, 0, 1, 1, 8]   (±vS via vsparq_flag)
           2b 7opt     = [2, 0, 1, 1, 8]
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

CFG_LEN = 5

# mode encoding (keep in sync with rust/src/quant/config.rs)
MODE_FULL = 0
MODE_3OPT = 1
MODE_2OPT = 2
MODE_UNIFORM = 3


def named_config(name: str) -> np.ndarray:
    """Convenience: config vectors by paper name."""
    table = {
        "a8w8": [8, MODE_FULL, 0, 0, 8],
        "a4w8": [4, MODE_UNIFORM, 1, 0, 8],
        "a3w8": [3, MODE_UNIFORM, 1, 0, 8],
        "a2w8": [2, MODE_UNIFORM, 1, 0, 8],
        "a8w4": [8, MODE_FULL, 0, 0, 4],
        "5opt": [4, MODE_FULL, 0, 1, 8],
        "5opt_r": [4, MODE_FULL, 1, 1, 8],
        "5opt_r_novs": [4, MODE_FULL, 1, 0, 8],
        "3opt": [4, MODE_3OPT, 0, 1, 8],
        "3opt_r": [4, MODE_3OPT, 1, 1, 8],
        "3opt_r_novs": [4, MODE_3OPT, 1, 0, 8],
        "2opt": [4, MODE_2OPT, 0, 1, 8],
        "2opt_r": [4, MODE_2OPT, 1, 1, 8],
        "2opt_r_novs": [4, MODE_2OPT, 1, 0, 8],
        "sysmt": [4, MODE_2OPT, 0, 1, 8],  # paper §5.1: SySMT ~ 2opt trim
        "6opt_r": [3, MODE_FULL, 1, 1, 8],
        "6opt_r_novs": [3, MODE_FULL, 1, 0, 8],
        "7opt_r": [2, MODE_FULL, 1, 1, 8],
        "7opt_r_novs": [2, MODE_FULL, 1, 0, 8],
    }
    return np.asarray(table[name], dtype=np.int32)


def weight_rescale(cfg) -> float:
    """Extra dequant factor when weights are requantized below 8 bits."""
    w_bits = int(cfg[4])
    if w_bits >= 8:
        return 1.0
    return 127.0 / float(2 ** (w_bits - 1) - 1)


# ---------------------------------------------------------------------------
# bit helpers (branch-free; everything is int32 and vectorized)
# ---------------------------------------------------------------------------


def msb_index(x: jnp.ndarray) -> jnp.ndarray:
    """Index of the most significant set bit for x in [0, 255].

    Returns 0 for x in {0, 1} (callers mask x == 0 separately).
    """
    x = x.astype(jnp.int32)
    idx = jnp.zeros_like(x)
    for b in range(1, 8):
        idx = idx + (x >= (1 << b)).astype(jnp.int32)
    return idx


def _select_shift(msb: jnp.ndarray, width, mode) -> jnp.ndarray:
    """Window shift: smallest allowed placement whose window covers `msb`.

    `width` and `mode` may be python ints or traced int32 scalars; the
    result is computed for all modes and selected, so the expression
    lowers branch-free into HLO.
    """
    msb = msb.astype(jnp.int32)
    width = jnp.asarray(width, dtype=jnp.int32)
    # full: s = max(0, msb - width + 1)
    s_full = jnp.maximum(0, msb - width + 1)
    # 3opt (width 4): allowed {0, 2, 4} -> round s_full up to even, cap 4
    s_3opt = jnp.minimum(((s_full + 1) // 2) * 2, 4)
    # 2opt (width 4): allowed {0, 4}
    s_2opt = jnp.where(s_full > 0, 4, 0)
    mode = jnp.asarray(mode, dtype=jnp.int32)
    return jnp.where(
        mode == MODE_3OPT, s_3opt, jnp.where(mode == MODE_2OPT, s_2opt, s_full)
    )


def bsparq_window(x: jnp.ndarray, width, mode, round_flag) -> jnp.ndarray:
    """Trim x in [0,255] to a `width`-bit window (bSPARQ §3.1).

    Window top is placed per `mode`; `round_flag` rounds by the residual
    LSBs and saturates within the window. Returns the *reconstructed*
    approximated value (q << shift), still in [0, 255].
    """
    x = x.astype(jnp.int32)
    width = jnp.asarray(width, dtype=jnp.int32)
    s = _select_shift(msb_index(x), width, mode)
    round_flag = jnp.asarray(round_flag, dtype=jnp.int32)
    # round-half-up by residual LSBs: q = (x + r*(1 << (s-1))) >> s, s > 0
    half = jnp.where(s > 0, (1 << jnp.maximum(s - 1, 0)) * round_flag, 0)
    q = (x + half) >> s
    qmax = (1 << width) - 1
    q = jnp.minimum(q, qmax)  # saturate the window on round-up overflow
    return q << s


def uniform_requant(x: jnp.ndarray, width) -> jnp.ndarray:
    """Uniform 8b -> width-bit requantization, reconstructed into [0,255].

    q = round(x * qmax / 255); reconstruction multiplies back by
    255 / qmax. To keep everything integer-exact we reconstruct as
    round(q * 255 / qmax). Used by the A4W8-style baselines (mode 3).
    """
    x = x.astype(jnp.int32)
    width = jnp.asarray(width, dtype=jnp.int32)
    qmax = (1 << width) - 1
    q = (x * qmax + 127) // 255  # round-half-up; exact in int32
    return (q * 255 + qmax // 2) // qmax


def _trim_one(x, n_bits, mode, round_flag):
    """Per-activation trim (no pairing): dispatch on mode."""
    n_bits_t = jnp.asarray(n_bits, dtype=jnp.int32)
    b = bsparq_window(x, n_bits_t, mode, round_flag)
    u = uniform_requant(x, n_bits_t)
    mode = jnp.asarray(mode, dtype=jnp.int32)
    y = jnp.where(mode == MODE_UNIFORM, u, b)
    # n_bits == 8 disables trimming entirely (A8 passthrough)
    return jnp.where(n_bits_t >= 8, x.astype(jnp.int32), y)


def sparq_trim(x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Full SPARQ activation transform along the last axis.

    x: int32 activations in [0, 255]; the last axis is the dot-product
    (reduction) axis and must have even length when vsparq is enabled.
    cfg: int32[5] (may be a traced array — fully branch-free).

    vSPARQ (§3.2, eq. 2): activations are paired (even, odd) along the
    last axis. If exactly one of the pair is zero, the other is trimmed
    with a doubled window (2*n bits, full placement set) — for n=4 that
    is a full 8-bit passthrough. Otherwise both are bSPARQ-trimmed.
    """
    cfg = jnp.asarray(cfg, dtype=jnp.int32)
    n_bits, mode, round_flag, vsparq, _ = (cfg[i] for i in range(CFG_LEN))
    x = x.astype(jnp.int32)

    single = _trim_one(x, n_bits, mode, round_flag)

    # paired path
    shp = x.shape
    xp = x.reshape(shp[:-1] + (shp[-1] // 2, 2))
    x0, x1 = xp[..., 0], xp[..., 1]
    wide = jnp.minimum(2 * n_bits, 8)
    w0 = bsparq_window(x0, wide, MODE_FULL, round_flag)
    w1 = bsparq_window(x1, wide, MODE_FULL, round_flag)
    s0 = _trim_one(x0, n_bits, mode, round_flag)
    s1 = _trim_one(x1, n_bits, mode, round_flag)
    y0 = jnp.where(x1 == 0, w0, s0)
    y1 = jnp.where(x0 == 0, w1, s1)
    paired = jnp.stack([y0, y1], axis=-1).reshape(shp)

    use_pair = (vsparq == 1) & (n_bits < 8)
    return jnp.where(use_pair, paired, single)


def requant_weights(w: jnp.ndarray, cfg) -> jnp.ndarray:
    """Optional A8W4-style weight requantization (signed, symmetric).

    w: int32 in [-127, 127]. For w_bits < 8, q = round(|w| * qmax / 127)
    with sign restored; the caller rescales dequant by weight_rescale().
    """
    cfg = jnp.asarray(cfg, dtype=jnp.int32)
    w_bits = cfg[4]
    w = w.astype(jnp.int32)
    qmax = (1 << (w_bits - 1)) - 1
    a = jnp.abs(w)
    q = (a * qmax + 63) // 127
    return jnp.where(w_bits >= 8, w, jnp.sign(w) * q)


def sparq_matmul_ref(a: jnp.ndarray, w: jnp.ndarray, cfg) -> jnp.ndarray:
    """Reference SPARQ GEMM: y[m,n] = sum_k trim(a)[m,k] * w[k,n], int32.

    a: int32 (M, K) in [0, 255]; w: int32 (K, N) in [-127, 127].
    The Pallas kernel (kernels/sparq.py) must equal this exactly.
    """
    at = sparq_trim(a, cfg)
    wq = requant_weights(w, cfg)
    return jnp.matmul(at, wq, preferred_element_type=jnp.int32)


# ---------------------------------------------------------------------------
# STC composition (§5.3): vSPARQ after 2:4 weight selection
# ---------------------------------------------------------------------------


def stc_pairdot_ref(a: jnp.ndarray, w: jnp.ndarray, cfg) -> jnp.ndarray:
    """SPARQ on top of a Sparse Tensor Core (paper Fig. 5, Table 6).

    w is 2:4 structured-sparse along K: in every group of 4 consecutive
    weights at most 2 are non-zero (per output column). The STC stores the
    two survivors plus coordinates; the coordinates mux-select the two
    matching activations, and *those two* form the vSPARQ pair.

    This reference materializes the gather (fine for test-sized shapes);
    the production path is the rust-native STC engine (rust/src/hw/stc.rs).

    a: int32 (M, K); w: int32 (K, N), K % 4 == 0. Returns int32 (M, N).
    """
    m_, k_, n_ = a.shape[0], a.shape[1], w.shape[1]
    g = k_ // 4
    wg = w.reshape(g, 4, n_)
    # Survivor indices per (group, column): indices of the 2 largest |w|;
    # with 2:4 sparsity those are exactly the non-zero positions (ties on
    # zeros are fine — a zero weight contributes nothing either way).
    order = jnp.argsort(-jnp.abs(wg), axis=1)  # (g, 4, n)
    idx = jnp.sort(order[:, :2, :], axis=1)  # keep K-order within the pair
    k_abs = idx + (jnp.arange(g) * 4)[:, None, None]  # absolute k (g, 2, n)
    # Gather activations / weights for the selected lanes.
    a_sel = a[:, k_abs]  # (m, g, 2, n)
    w_sel = jnp.take_along_axis(wg, idx, axis=1)  # (g, 2, n)
    cfg = jnp.asarray(cfg, dtype=jnp.int32)
    n_bits, mode, round_flag, vsparq, _ = (cfg[i] for i in range(CFG_LEN))
    a0, a1 = a_sel[:, :, 0, :], a_sel[:, :, 1, :]
    wide = jnp.minimum(2 * n_bits, 8)
    t0_w = bsparq_window(a0, wide, MODE_FULL, round_flag)
    t1_w = bsparq_window(a1, wide, MODE_FULL, round_flag)
    t0_s = _trim_one(a0, n_bits, mode, round_flag)
    t1_s = _trim_one(a1, n_bits, mode, round_flag)
    use_pair = (vsparq == 1) & (n_bits < 8)
    y0 = jnp.where(use_pair & (a1 == 0), t0_w, t0_s)
    y1 = jnp.where(use_pair & (a0 == 0), t1_w, t1_s)
    w_sel = requant_weights(w_sel, cfg)
    w0, w1 = w_sel[None, :, 0, :], w_sel[None, :, 1, :]
    acc = y0 * w0 + y1 * w1  # (m, g, n)
    return jnp.sum(acc, axis=1).astype(jnp.int32)


__all__ = [
    "CFG_LEN",
    "MODE_FULL",
    "MODE_3OPT",
    "MODE_2OPT",
    "MODE_UNIFORM",
    "named_config",
    "weight_rescale",
    "msb_index",
    "bsparq_window",
    "uniform_requant",
    "sparq_trim",
    "requant_weights",
    "sparq_matmul_ref",
    "stc_pairdot_ref",
]
