"""L1 — Pallas SPARQ GEMM kernel (DESIGN.md §4 Hardware adaptation).

The paper's compute hot-spot is an int8 GEMM whose reduction applies the
dynamic SPARQ requantization per activation pair. On TPU the trim
(leading-zero detect -> window placement -> round) is element-wise int32
bit arithmetic that maps to the VPU; the n-bit x 8-bit products are an
MXU-shaped `dot`. The kernel fuses trim + matmul per (TM, TN) output tile
so the trimmed activations never round-trip through HBM.

BlockSpec schedule (the TPU analogue of the paper's systolic dataflow):

  grid = (M/TM, N/TN); per step the kernel sees
    a_ref   (TM, K)  — activation rows, full reduction axis in VMEM
    w_ref   (K, TN)  — weight columns in VMEM
    cfg_ref (CFG_LEN,) — config scalars (n_bits, mode, round, vsparq, wbits)
    o_ref   (TM, TN) — int32 accumulator tile

  VMEM footprint = 4*(TM*K + K*TN + TM*TN) bytes; for the default
  TM=TN=128 and the zoo's largest K (=1152) that is ~1.3 MiB, comfortably
  inside a TensorCore's 16 MiB VMEM with room for double buffering
  (see EXPERIMENTS.md §Perf for the sweep).

Pallas is invoked with interpret=True everywhere in this repo: the CPU
PJRT plugin cannot execute Mosaic custom-calls, so interpret mode is the
correctness path and real-TPU efficiency is estimated analytically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import CFG_LEN


def _trim_tile(a, cfg):
    """SPARQ trim of one activation tile; mirrors ref.sparq_trim exactly.

    Runs on the VPU: pure element-wise int32 shifts/compares/selects over
    even/odd lanes of the reduction axis — no data-dependent control flow.
    """
    n_bits, mode, round_flag, vsparq = cfg[0], cfg[1], cfg[2], cfg[3]
    a = a.astype(jnp.int32)
    single = ref._trim_one(a, n_bits, mode, round_flag)

    tm, tk = a.shape
    ap = a.reshape(tm, tk // 2, 2)
    a0, a1 = ap[:, :, 0], ap[:, :, 1]
    wide = jnp.minimum(2 * n_bits, 8)
    w0 = ref.bsparq_window(a0, wide, ref.MODE_FULL, round_flag)
    w1 = ref.bsparq_window(a1, wide, ref.MODE_FULL, round_flag)
    s0 = ref._trim_one(a0, n_bits, mode, round_flag)
    s1 = ref._trim_one(a1, n_bits, mode, round_flag)
    y0 = jnp.where(a1 == 0, w0, s0)
    y1 = jnp.where(a0 == 0, w1, s1)
    paired = jnp.stack([y0, y1], axis=-1).reshape(tm, tk)

    use_pair = (vsparq == 1) & (n_bits < 8)
    return jnp.where(use_pair, paired, single)


def _sparq_gemm_kernel(a_ref, w_ref, cfg_ref, o_ref):
    """One (TM, TN) output tile: trim activations, requant weights, dot."""
    cfg = cfg_ref[...]
    at = _trim_tile(a_ref[...], cfg)
    wq = ref.requant_weights(w_ref[...], cfg)
    o_ref[...] = jax.lax.dot_general(
        at,
        wq,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def sparq_matmul(a, w, cfg, *, tm: int = 128, tn: int = 128):
    """Fused SPARQ GEMM: int32 (M, K) x (K, N) -> (M, N).

    a in [0, 255], w in [-127, 127], cfg int32[CFG_LEN]. Bit-exact equal
    to ref.sparq_matmul_ref (asserted by python/tests/test_kernel.py).

    Inputs are zero-padded up to the tile grid; zero activations trim to
    zero and contribute nothing, so padding never changes the result
    (property-tested). K is padded to an even length for vSPARQ pairing —
    a zero partner in the padded lane only *widens* the real lane's
    window, which is exact, so this too is value-preserving.
    """
    a = a.astype(jnp.int32)
    w = w.astype(jnp.int32)
    cfg = jnp.asarray(cfg, dtype=jnp.int32)
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, f"reduction mismatch {k} vs {k2}"

    a, _ = _pad_to(a, 1, 2)
    w, _ = _pad_to(w, 0, 2)
    a, m0 = _pad_to(a, 0, tm)
    w, n0 = _pad_to(w, 1, tn)
    kp = a.shape[1]
    grid = (a.shape[0] // tm, w.shape[1] // tn)

    out = pl.pallas_call(
        _sparq_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, tn), lambda i, j: (0, j)),
            pl.BlockSpec((CFG_LEN,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], w.shape[1]), jnp.int32),
        interpret=True,
    )(a, w, cfg)
    return out[:m0, :n0]


def _trim_only_kernel(a_ref, cfg_ref, o_ref):
    o_ref[...] = _trim_tile(a_ref[...], cfg_ref[...])


@jax.jit
def sparq_trim_pallas(a, cfg):
    """Standalone trim kernel (no GEMM) — used by tests and the stats path.

    a: int32 (M, K) in [0, 255]; K must be even when vsparq is enabled.
    """
    a = a.astype(jnp.int32)
    cfg = jnp.asarray(cfg, dtype=jnp.int32)
    m, k = a.shape
    return pl.pallas_call(
        _trim_only_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((CFG_LEN,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.int32),
        interpret=True,
    )(a, cfg)


def vmem_bytes(tm: int, tn: int, k: int) -> int:
    """Static VMEM footprint of one grid step (perf model, DESIGN.md §7)."""
    return 4 * (tm * k + k * tn + tm * tn)


__all__ = ["sparq_matmul", "sparq_trim_pallas", "vmem_bytes"]
