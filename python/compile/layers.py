"""Graph IR + interpreters for the mini CNN zoo (DESIGN.md S2/S3).

A model is a flat, topologically ordered list of nodes — a deliberately
boring IR that three consumers share:

  1. the float *training* interpreter (`forward_float`, with BatchNorm
     batch statistics) used by train.py,
  2. the integer *quantized* interpreter (`forward_quant`) that builds
     the SPARQ inference graph lowered to HLO by aot.py (calling the
     Pallas kernel for every quantized conv), and
  3. the rust-native engine (rust/src/model/graph.rs), which executes the
     same node list from the exported meta JSON bit-exactly.

Tensors are NHWC float32 except inside quantized convs, which run int32.
Conv weights are HWIO. The im2col feature order is (C, kh, kw) — the
order produced by lax.conv_general_dilated_patches — and the rust side
mirrors it (rust/src/tensor/im2col.rs).

Node schema (all plain JSON-serializable):
  {"name": str, "op": str, "inputs": [str, ...], ...attrs}

Ops:
  input                                   the image placeholder
  conv    k, stride, out_ch, relu, quant  conv (+folded BN) (+ReLU)
  pool    kind ("max"|"avg")              2x2 stride-2 window
  gap                                     global average pool -> (N, C)
  add                                     elementwise (residual)
  relu                                    standalone ReLU
  concat                                  channel concat
  fc      out                             final float linear on (N, C)

Convs with quant=True participate in SPARQ; quant=False (the first conv,
per paper §5) stays float. BatchNorm exists only during training; export
folds it into conv weights (`fold_batchnorm`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import ref as kref
from .kernels import sparq as ksparq

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# graph construction
# ---------------------------------------------------------------------------


class GraphBuilder:
    """Tiny helper that assigns unique names and keeps topo order."""

    def __init__(self, arch: str, num_classes: int):
        self.arch = arch
        self.num_classes = num_classes
        self.nodes: list[dict] = [{"name": "img", "op": "input", "inputs": []}]
        self._counter = 0

    def _fresh(self, prefix: str) -> str:
        self._counter += 1
        return f"{prefix}{self._counter}"

    def _push(self, node: dict) -> str:
        self.nodes.append(node)
        return node["name"]

    def conv(
        self,
        x: str,
        out_ch: int,
        k: int = 3,
        stride: int = 1,
        relu: bool = True,
        quant: bool = True,
        name: str | None = None,
    ) -> str:
        return self._push(
            {
                "name": name or self._fresh("conv"),
                "op": "conv",
                "inputs": [x],
                "k": k,
                "stride": stride,
                "out_ch": out_ch,
                "relu": relu,
                "quant": quant,
            }
        )

    def pool(self, x: str, kind: str = "max") -> str:
        return self._push(
            {"name": self._fresh("pool"), "op": "pool", "inputs": [x], "kind": kind}
        )

    def gap(self, x: str) -> str:
        return self._push({"name": self._fresh("gap"), "op": "gap", "inputs": [x]})

    def add(self, a: str, b: str) -> str:
        return self._push({"name": self._fresh("add"), "op": "add", "inputs": [a, b]})

    def relu(self, x: str) -> str:
        return self._push({"name": self._fresh("relu"), "op": "relu", "inputs": [x]})

    def concat(self, xs: list[str]) -> str:
        return self._push(
            {"name": self._fresh("cat"), "op": "concat", "inputs": list(xs)}
        )

    def fc(self, x: str) -> str:
        return self._push(
            {
                "name": "fc",
                "op": "fc",
                "inputs": [x],
                "out": self.num_classes,
            }
        )

    def graph(self) -> dict:
        return {
            "arch": self.arch,
            "num_classes": self.num_classes,
            "nodes": self.nodes,
        }


def conv_nodes(graph: dict) -> list[dict]:
    return [n for n in graph["nodes"] if n["op"] == "conv"]


def quant_conv_names(graph: dict) -> list[str]:
    """Order defines the activation-scale vector layout everywhere."""
    return [n["name"] for n in conv_nodes(graph) if n["quant"]]


# ---------------------------------------------------------------------------
# parameter initialization
# ---------------------------------------------------------------------------


def _he_init(key, shape):
    fan_in = int(np.prod(shape[:-1]))
    return jax.random.normal(key, shape, jnp.float32) * np.sqrt(2.0 / fan_in)


def init_params(graph: dict, key, in_ch: int = 3):
    """Returns (params, bn_state). Channel bookkeeping mirrors forward."""
    params: dict = {}
    state: dict = {}
    channels = {"img": in_ch}
    for node in graph["nodes"]:
        op = node["op"]
        name = node["name"]
        if op == "input":
            continue
        ins = [channels[i] for i in node["inputs"]]
        if op == "conv":
            key, k1 = jax.random.split(key)
            c_in, c_out, k = ins[0], node["out_ch"], node["k"]
            params[name] = {
                "w": _he_init(k1, (k, k, c_in, c_out)),
                "b": jnp.zeros((c_out,), jnp.float32),
                "gamma": jnp.ones((c_out,), jnp.float32),
                "beta": jnp.zeros((c_out,), jnp.float32),
            }
            state[name] = {
                "mean": jnp.zeros((c_out,), jnp.float32),
                "var": jnp.ones((c_out,), jnp.float32),
            }
            channels[name] = c_out
        elif op == "fc":
            key, k1 = jax.random.split(key)
            c_in = ins[0]
            params[name] = {
                "w": jax.random.normal(k1, (c_in, node["out"]), jnp.float32)
                * np.sqrt(1.0 / c_in),
                "b": jnp.zeros((node["out"],), jnp.float32),
            }
            channels[name] = node["out"]
        elif op == "concat":
            channels[name] = sum(ins)
        else:  # pool / gap / add / relu keep channel count
            channels[name] = ins[0]
    return params, state


# ---------------------------------------------------------------------------
# float interpreter (training / FP32 baseline / calibration)
# ---------------------------------------------------------------------------


def _conv_float(x, w, stride):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _pool2(x, kind: str):
    if kind == "max":
        return lax.reduce_window(
            x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    s = lax.reduce_window(x, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    return s / 4.0


def forward_float(graph, params, state, x, train: bool):
    """Float forward. Returns (logits, new_state, taps).

    `taps[name]` is the float input of each quantized conv — used for
    calibration (max and mean statistics per paper §5).
    """
    vals = {"img": x}
    new_state = {}
    taps = {}
    for node in graph["nodes"]:
        op, name = node["op"], node["name"]
        if op == "input":
            continue
        ins = [vals[i] for i in node["inputs"]]
        if op == "conv":
            p = params[name]
            if node["quant"]:
                taps[name] = ins[0]
            y = _conv_float(ins[0], p["w"], node["stride"]) + p["b"]
            if train:
                mu = jnp.mean(y, axis=(0, 1, 2))
                var = jnp.var(y, axis=(0, 1, 2))
                new_state[name] = {
                    "mean": BN_MOMENTUM * state[name]["mean"] + (1 - BN_MOMENTUM) * mu,
                    "var": BN_MOMENTUM * state[name]["var"] + (1 - BN_MOMENTUM) * var,
                }
            else:
                mu, var = state[name]["mean"], state[name]["var"]
                new_state[name] = state[name]
            y = p["gamma"] * (y - mu) * lax.rsqrt(var + BN_EPS) + p["beta"]
            if node["relu"]:
                y = jnp.maximum(y, 0.0)
            vals[name] = y
        elif op == "pool":
            vals[name] = _pool2(ins[0], node["kind"])
        elif op == "gap":
            vals[name] = jnp.mean(ins[0], axis=(1, 2))
        elif op == "add":
            vals[name] = ins[0] + ins[1]
        elif op == "relu":
            vals[name] = jnp.maximum(ins[0], 0.0)
        elif op == "concat":
            vals[name] = jnp.concatenate(ins, axis=-1)
        elif op == "fc":
            p = params[name]
            vals[name] = ins[0] @ p["w"] + p["b"]
        else:
            raise ValueError(f"unknown op {op}")
    return vals["fc"], new_state, taps


def fold_batchnorm(graph, params, state):
    """Fold BN into conv weights/bias: standard inference-time folding.

    Returns {conv_name: {"w": HWIO float, "b": float}} plus the untouched
    fc parameters.
    """
    folded = {}
    for node in conv_nodes(graph):
        p = params[node["name"]]
        s = state[node["name"]]
        scale = p["gamma"] * lax.rsqrt(s["var"] + BN_EPS)
        folded[node["name"]] = {
            "w": p["w"] * scale[None, None, None, :],
            "b": p["beta"] + (p["b"] - s["mean"]) * scale,
        }
    folded["fc"] = dict(params["fc"])
    return folded


def forward_folded(graph, folded, x):
    """Float forward on BN-folded weights — the FP32 reference the
    quantized paths are compared against (also lowered to HLO).

    Uses only export-safe ops (see the XLA-0.5.1 note above)."""
    vals = {"img": x}
    for node in graph["nodes"]:
        op, name = node["op"], node["name"]
        if op == "input":
            continue
        ins = [vals[i] for i in node["inputs"]]
        if op == "conv":
            p = folded[name]
            y = conv_float_export(ins[0], p["w"], p["b"], node["stride"])
            vals[name] = jnp.maximum(y, 0.0) if node["relu"] else y
        elif op == "pool":
            vals[name] = _pool2_export(ins[0], node["kind"])
        elif op == "gap":
            vals[name] = jnp.mean(ins[0], axis=(1, 2))
        elif op == "add":
            vals[name] = ins[0] + ins[1]
        elif op == "relu":
            vals[name] = jnp.maximum(ins[0], 0.0)
        elif op == "concat":
            vals[name] = jnp.concatenate(ins, axis=-1)
        elif op == "fc":
            p = folded[name]
            vals[name] = ins[0] @ p["w"] + p["b"]
    return vals["fc"]


def calib_forward(graph, folded, x):
    """Calibration pass on folded float weights (paper §5 preprocessing).

    Returns (maxes, mean_abs): per-quantized-conv input statistics, each a
    vector ordered by quant_conv_names(). mean_abs feeds the ACIQ-style
    analytic-clipping baseline (rust quant/baselines/aciq.rs).
    """
    vals = {"img": x}
    maxes, means = [], []
    for node in graph["nodes"]:
        op, name = node["op"], node["name"]
        if op == "input":
            continue
        ins = [vals[i] for i in node["inputs"]]
        if op == "conv":
            if node["quant"]:
                maxes.append(jnp.max(ins[0]))
                means.append(jnp.mean(ins[0]))  # inputs are post-ReLU (>= 0)
            p = folded[name]
            y = conv_float_export(ins[0], p["w"], p["b"], node["stride"])
            vals[name] = jnp.maximum(y, 0.0) if node["relu"] else y
        elif op == "pool":
            vals[name] = _pool2_export(ins[0], node["kind"])
        elif op == "gap":
            vals[name] = jnp.mean(ins[0], axis=(1, 2))
        elif op == "add":
            vals[name] = ins[0] + ins[1]
        elif op == "relu":
            vals[name] = jnp.maximum(ins[0], 0.0)
        elif op == "concat":
            vals[name] = jnp.concatenate(ins, axis=-1)
        elif op == "fc":
            p = folded[name]
            vals[name] = ins[0] @ p["w"] + p["b"]
    return jnp.stack(maxes), jnp.stack(means)


# ---------------------------------------------------------------------------
# weight quantization (per-kernel symmetric int8, paper §5)
# ---------------------------------------------------------------------------


def quantize_weights(graph, folded):
    """int8 per-output-channel symmetric weight quantization.

    Returns {name: {"wq": int32 HWIO in [-127,127], "scale": (O,) float,
                    "b": float bias}} for quantized convs; float entries
    for the first conv and fc.
    """
    out = {}
    for node in conv_nodes(graph):
        name = node["name"]
        p = folded[name]
        if not node["quant"]:
            out[name] = {"w": p["w"], "b": p["b"]}
            continue
        w = p["w"]
        amax = jnp.max(jnp.abs(w), axis=(0, 1, 2))  # per output channel
        scale = jnp.maximum(amax, 1e-12) / 127.0
        wq = jnp.clip(jnp.round(w / scale[None, None, None, :]), -127, 127)
        out[name] = {"wq": wq.astype(jnp.int32), "scale": scale, "b": p["b"]}
    out["fc"] = dict(folded["fc"])
    return out


# ---------------------------------------------------------------------------
# quantized interpreter (the L2 graph lowered by aot.py)
# ---------------------------------------------------------------------------


def _weight_rescale_graph(cfg):
    """In-graph float equivalent of ref.weight_rescale (branch-free)."""
    wb = cfg[4]
    r4 = 127.0 / 7.0
    r3 = 127.0 / 3.0
    r2 = 127.0 / 1.0
    return jnp.where(
        wb >= 8, 1.0, jnp.where(wb == 4, r4, jnp.where(wb == 3, r3, r2))
    ).astype(jnp.float32)


# --- XLA-0.5.1-safe lowering primitives -----------------------------------
#
# The rust side's xla_extension 0.5.1 silently mis-executes `convolution`
# and `reduce_window` parsed from HLO text (outputs all zeros; verified in
# rust/tests/integration.rs::debug_minimal_conv during bring-up). Every
# *exported* graph therefore lowers convs as slice-based im2col + `dot`
# and pools as strided slices + elementwise max/add — ops that round-trip
# correctly. Training (forward_float) keeps the fast lax.conv path; the
# equivalence of the two conv implementations is pytest-checked.


def _same_pad(x, k: int, stride: int):
    """Spatial SAME padding (matches XLA's pad split: low = total//2)."""
    n, h, w, c = x.shape
    oh, ow = -(-h // stride), -(-w // stride)
    th = max((oh - 1) * stride + k - h, 0)
    tw = max((ow - 1) * stride + k - w, 0)
    return (
        jnp.pad(x, ((0, 0), (th // 2, th - th // 2), (tw // 2, tw - tw // 2), (0, 0))),
        oh,
        ow,
    )


def _im2col(x, k: int, stride: int):
    """NHWC -> (N*OH*OW, C*k*k) patches, feature order (C, kh, kw).

    Built from pad + strided slices + stack + reshape only (see note
    above); ordering matches lax.conv_general_dilated_patches and
    rust/src/tensor/im2col.rs.
    """
    n, _, _, c = x.shape
    xp, oh, ow = _same_pad(x, k, stride)
    cols = []
    for ky in range(k):
        for kx in range(k):
            sl = xp[:, ky : ky + (oh - 1) * stride + 1 : stride,
                    kx : kx + (ow - 1) * stride + 1 : stride, :]
            cols.append(sl)  # (n, oh, ow, c)
    # stack -> (n, oh, ow, k*k, c); transpose -> (..., c, k*k) for the
    # (C, kh, kw) feature order
    p = jnp.stack(cols, axis=3)
    p = jnp.transpose(p, (0, 1, 2, 4, 3)).reshape(n, oh, ow, c * k * k)
    return p.reshape(n * oh * ow, c * k * k), (n, oh, ow)


def conv_float_export(x, w_hwio, b, stride: int):
    """Float conv as im2col + dot (export-safe; equals lax.conv)."""
    k = w_hwio.shape[0]
    patches, (n, oh, ow) = _im2col(x, k, stride)
    wf = jnp.transpose(w_hwio, (2, 0, 1, 3)).reshape(-1, w_hwio.shape[-1])
    y = patches @ wf
    return y.reshape(n, oh, ow, -1) + b


def _pool2_export(x, kind: str):
    """2x2 stride-2 pool via strided slices (export-safe)."""
    a = x[:, 0::2, 0::2, :]
    b = x[:, 0::2, 1::2, :]
    c = x[:, 1::2, 0::2, :]
    d = x[:, 1::2, 1::2, :]
    if kind == "max":
        return jnp.maximum(jnp.maximum(a, b), jnp.maximum(c, d))
    return (a + b + c + d) / 4.0


def _flatten_weights(wq):
    """HWIO int32 -> (C*k*k, O), feature order (C, kh, kw) to match im2col."""
    return jnp.transpose(wq, (2, 0, 1, 3)).reshape(-1, wq.shape[-1])


def quantized_conv(x, node, qp, a_scale, cfg, *, use_pallas: bool = True):
    """One SPARQ conv: quantize input, fused trim+GEMM, dequantize.

    x: float NHWC (non-negative); a_scale: scalar activation scale.
    Integer part is exactly the Pallas kernel / rust PE semantics.
    """
    aq = jnp.clip(jnp.round(x / a_scale), 0, 255).astype(jnp.int32)
    patches, (n, oh, ow) = _im2col(aq, node["k"], node["stride"])
    wflat = _flatten_weights(qp["wq"])
    if use_pallas:
        # Perf (EXPERIMENTS.md §Perf L2): on the CPU-interpret target the
        # BlockSpec grid only adds loop-emulation overhead — a single
        # whole-GEMM tile is ~10x faster and bit-identical. The 128x128
        # tiling remains the real-TPU schedule (kernels/sparq.py).
        acc = ksparq.sparq_matmul(
            patches, wflat, cfg, tm=patches.shape[0], tn=wflat.shape[1]
        )
    else:
        acc = kref.sparq_matmul_ref(patches, wflat, cfg)
    wrs = _weight_rescale_graph(cfg)
    y = acc.astype(jnp.float32) * (a_scale * wrs) * qp["scale"][None, :]
    y = y.reshape(n, oh, ow, -1) + qp["b"]
    return jnp.maximum(y, 0.0) if node["relu"] else y


def forward_quant(graph, qweights, act_scales, cfg, x, *, use_pallas: bool = True):
    """SPARQ-quantized forward (the artifact lowered per model).

    act_scales: float (L,) ordered by quant_conv_names(graph);
    cfg: int32[5] runtime config (see kernels/ref.py docstring).
    """
    qnames = quant_conv_names(graph)
    scale_of = {n: act_scales[i] for i, n in enumerate(qnames)}
    vals = {"img": x}
    for node in graph["nodes"]:
        op, name = node["op"], node["name"]
        if op == "input":
            continue
        ins = [vals[i] for i in node["inputs"]]
        if op == "conv":
            qp = qweights[name]
            if node["quant"]:
                vals[name] = quantized_conv(
                    ins[0], node, qp, scale_of[name], cfg, use_pallas=use_pallas
                )
            else:
                y = conv_float_export(ins[0], qp["w"], qp["b"], node["stride"])
                vals[name] = jnp.maximum(y, 0.0) if node["relu"] else y
        elif op == "pool":
            vals[name] = _pool2_export(ins[0], node["kind"])
        elif op == "gap":
            vals[name] = jnp.mean(ins[0], axis=(1, 2))
        elif op == "add":
            vals[name] = ins[0] + ins[1]
        elif op == "relu":
            vals[name] = jnp.maximum(ins[0], 0.0)
        elif op == "concat":
            vals[name] = jnp.concatenate(ins, axis=-1)
        elif op == "fc":
            qp = qweights[name]
            vals[name] = ins[0] @ qp["w"] + qp["b"]
    return vals["fc"]
