"""Build-time training of the mini zoo (DESIGN.md S2).

Hand-rolled Adam (no optax in this environment), cross-entropy, cosine
learning-rate decay, a short warmup, and post-training BatchNorm
recalibration (paper §5: running statistics are refreshed on calibration
data before export). Loss curves and final accuracies are appended to
artifacts/train_log.json and summarized in EXPERIMENTS.md.

Training runs exactly once per architecture (`make artifacts` is
idempotent); checkpoints are .npz files of the flattened param/state
pytrees.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as dataset
from . import layers, model

DEFAULT_STEPS = 500
BATCH = 128
LR = 2e-3
WARMUP = 50
RECALIB_BATCHES = 16  # BN recalibration passes (paper: preprocessing stage)


# ---------------------------------------------------------------------------
# pytree <-> flat npz helpers (shared checkpoint format)
# ---------------------------------------------------------------------------


def tree_to_flat(tree, prefix=""):
    """Nested dict of arrays -> {dotted.key: np.ndarray}."""
    flat = {}
    for k, v in tree.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            flat.update(tree_to_flat(v, prefix=key + "."))
        else:
            flat[key] = np.asarray(v)
    return flat


def flat_to_tree(flat):
    tree: dict = {}
    for key, v in flat.items():
        parts = key.split(".")
        d = tree
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = jnp.asarray(v)
    return tree


def save_checkpoint(path, params, state):
    flat = {f"p.{k}": v for k, v in tree_to_flat(params).items()}
    flat.update({f"s.{k}": v for k, v in tree_to_flat(state).items()})
    np.savez(path, **flat)


def load_checkpoint(path):
    d = np.load(path)
    pf = {k[2:]: d[k] for k in d.files if k.startswith("p.")}
    sf = {k[2:]: d[k] for k in d.files if k.startswith("s.")}
    return flat_to_tree(pf), flat_to_tree(sf)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8, wd=1e-4):
    t = opt["t"] + 1.0
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
    new = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ / (jnp.sqrt(v_) + eps) + wd * p),
        params,
        mh,
        vh,
    )
    return new, {"m": m, "v": v, "t": t}


def lr_schedule(step, total_steps):
    warm = jnp.minimum(1.0, (step + 1) / WARMUP)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(step / total_steps, 1.0)))
    return LR * warm * cos


# ---------------------------------------------------------------------------
# training loop
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_step(graph, total_steps, mask=None):
    """Returns a jitted SGD step. `mask` (optional) is a pytree of {0,1}
    multipliers applied to conv weights after each update — used by
    prune.py to keep 2:4 zeros pinned during fine-tuning."""

    def loss_fn(params, state, xb, yb):
        logits, new_state, _ = layers.forward_float(graph, params, state, xb, True)
        return cross_entropy(logits, yb), new_state

    @jax.jit
    def step(params, state, opt, xb, yb, it):
        (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, state, xb, yb
        )
        lr = lr_schedule(it, total_steps)
        params, opt = adam_update(params, grads, opt, lr)
        if mask is not None:
            params = jax.tree.map(lambda p, m: p * m, params, mask)
        return params, new_state, opt, loss

    return step


def evaluate(graph, params, state, x, y, batch=256):
    correct = 0
    for i in range(0, len(x), batch):
        xb = jnp.asarray(dataset.normalize(x[i : i + batch]))
        logits, _, _ = layers.forward_float(graph, params, state, xb, False)
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == jnp.asarray(y[i : i + batch])))
    return correct / len(x)


def recalibrate_bn(graph, params, state, x, batches=RECALIB_BATCHES, batch=BATCH):
    """Post-training BN recalibration (paper §5, refs [29,33,35,36]):
    refresh running mean/var with forward passes on calibration data."""
    rng = np.random.default_rng(123)
    for _ in range(batches):
        idx = rng.choice(len(x), size=batch, replace=False)
        xb = jnp.asarray(dataset.normalize(x[idx]))
        _, state, _ = layers.forward_float(graph, params, state, xb, True)
    return state


def train_model(
    arch: str,
    d: dict,
    steps: int = DEFAULT_STEPS,
    seed: int = 0,
    init_from=None,
    mask=None,
    log_every: int = 25,
):
    """Train one architecture; returns (params, state, log dict)."""
    graph = model.build(arch)
    if init_from is not None:
        params, state = init_from
    else:
        params, state = layers.init_params(graph, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    step = make_step(graph, steps, mask=mask)

    x_train, y_train = d["x_train"], d["y_train"]
    rng = np.random.default_rng(seed)
    losses = []
    t0 = time.time()
    for it in range(steps):
        idx = rng.integers(0, len(x_train), size=BATCH)
        xb = jnp.asarray(dataset.normalize(x_train[idx]))
        yb = jnp.asarray(y_train[idx].astype(np.int32))
        params, state, opt, loss = step(params, state, opt, xb, yb, it)
        if it % log_every == 0 or it == steps - 1:
            losses.append({"step": it, "loss": float(loss)})
    state = recalibrate_bn(graph, params, state, x_train)
    acc = evaluate(graph, params, state, d["x_test"], d["y_test"])
    log = {
        "arch": arch,
        "steps": steps,
        "seconds": round(time.time() - t0, 2),
        "losses": losses,
        "test_acc": acc,
    }
    return params, state, log


def train_all(out_dir: str, steps: int = DEFAULT_STEPS, archs=None):
    """Idempotent: skips architectures whose checkpoint already exists."""
    d = dataset.load_or_generate(out_dir)
    log_path = os.path.join(out_dir, "train_log.json")
    logs = []
    if os.path.exists(log_path):
        logs = json.load(open(log_path))
    for arch in archs or model.ZOO:
        ckpt = os.path.join(out_dir, f"ckpt_{arch}.npz")
        if os.path.exists(ckpt):
            continue
        params, state, log = train_model(arch, d, steps=steps)
        save_checkpoint(ckpt, params, state)
        logs = [l for l in logs if l["arch"] != arch] + [log]
        json.dump(logs, open(log_path, "w"), indent=1)
        print(f"[train] {arch}: acc={log['test_acc']:.4f} ({log['seconds']}s)")
    return logs


if __name__ == "__main__":
    import sys

    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    train_all(out)
