"""The mini CNN zoo (DESIGN.md S2) — torchvision-family analogues.

Each architecture family from the paper's Table 1 is represented by a
laptop-scale member built on the layers.py graph IR:

  resnet10 / resnet18m  <- ResNet-18/34/50/101 (residual family)
  vgg11m                <- plain-conv reference (no paper row; sanity)
  squeezem              <- SqueezeNet (fire modules; the paper's most
                           quantization-fragile model)
  inceptm               <- GoogLeNet / Inception-v3 (parallel branches)
  densem                <- DenseNet-121 (dense concatenation)

Input is 20x20x3 (data.py); all models end in GAP + float FC. The first
conv never quantizes (paper §5: image pixels carry no zero sparsity).
"""

from __future__ import annotations

from .layers import GraphBuilder


def _basic_block(g: GraphBuilder, x: str, ch: int, stride: int) -> str:
    """ResNet basic block: conv-bn-relu, conv-bn, (projection), add, relu."""
    y = g.conv(x, ch, k=3, stride=stride, relu=True)
    y = g.conv(y, ch, k=3, stride=1, relu=False)
    if stride != 1:
        x = g.conv(x, ch, k=1, stride=stride, relu=False)
    return g.relu(g.add(y, x))


def resnet10() -> dict:
    g = GraphBuilder("resnet10", 10)
    x = g.conv("img", 16, k=3, stride=1, relu=True, quant=False)  # stem
    x = _basic_block(g, x, 16, 1)
    x = _basic_block(g, x, 32, 2)
    x = _basic_block(g, x, 64, 2)
    return _head(g, x)


def resnet18m() -> dict:
    g = GraphBuilder("resnet18m", 10)
    x = g.conv("img", 16, k=3, stride=1, relu=True, quant=False)
    for ch, stride in [(16, 1), (16, 1), (32, 2), (32, 1), (64, 2), (64, 1)]:
        x = _basic_block(g, x, ch, stride)
    return _head(g, x)


def vgg11m() -> dict:
    g = GraphBuilder("vgg11m", 10)
    x = g.conv("img", 16, quant=False)
    x = g.conv(x, 16)
    x = g.pool(x)
    x = g.conv(x, 32)
    x = g.conv(x, 32)
    x = g.pool(x)
    x = g.conv(x, 64)
    x = g.conv(x, 64)
    return _head(g, x)


def _fire(g: GraphBuilder, x: str, s: int, e: int) -> str:
    """SqueezeNet fire module: 1x1 squeeze, 1x1 + 3x3 expand, concat."""
    sq = g.conv(x, s, k=1)
    e1 = g.conv(sq, e, k=1)
    e3 = g.conv(sq, e, k=3)
    return g.concat([e1, e3])


def squeezem() -> dict:
    g = GraphBuilder("squeezem", 10)
    x = g.conv("img", 24, quant=False)
    x = _fire(g, x, 8, 16)
    x = _fire(g, x, 8, 16)
    x = g.pool(x)
    x = _fire(g, x, 12, 24)
    x = _fire(g, x, 12, 24)
    x = g.pool(x)
    x = _fire(g, x, 16, 32)
    return _head(g, x)


def _inception(g: GraphBuilder, x: str, b1: int, b3: int, b5: int, bp: int) -> str:
    """Inception block: 1x1 | 1x1->3x3 | 1x1->3x3->3x3 | pool-proj."""
    br1 = g.conv(x, b1, k=1)
    br3 = g.conv(g.conv(x, max(b3 // 2, 4), k=1), b3, k=3)
    br5a = g.conv(x, max(b5 // 2, 4), k=1)
    br5 = g.conv(g.conv(br5a, b5, k=3), b5, k=3)
    brp = g.conv(x, bp, k=1)  # 1x1 projection (pooling branch sans pool)
    return g.concat([br1, br3, br5, brp])


def inceptm() -> dict:
    g = GraphBuilder("inceptm", 10)
    x = g.conv("img", 16, quant=False)
    x = _inception(g, x, 8, 12, 4, 4)
    x = g.pool(x)
    x = _inception(g, x, 16, 24, 8, 8)
    x = g.pool(x)
    x = _inception(g, x, 24, 32, 12, 12)
    return _head(g, x)


def _dense_block(g: GraphBuilder, x: str, layers: int, growth: int) -> str:
    for _ in range(layers):
        y = g.conv(x, growth, k=3)
        x = g.concat([x, y])
    return x


def densem() -> dict:
    g = GraphBuilder("densem", 10)
    x = g.conv("img", 16, quant=False)
    x = _dense_block(g, x, 4, 8)
    x = g.conv(x, 24, k=1)  # transition
    x = g.pool(x, kind="avg")
    x = _dense_block(g, x, 4, 12)
    x = g.conv(x, 48, k=1)
    x = g.pool(x, kind="avg")
    x = _dense_block(g, x, 2, 16)
    return _head(g, x)


def _head(g: GraphBuilder, x: str) -> dict:
    x = g.gap(x)
    g.fc(x)
    return g.graph()


ZOO = {
    "resnet10": resnet10,
    "resnet18m": resnet18m,
    "vgg11m": vgg11m,
    "squeezem": squeezem,
    "inceptm": inceptm,
    "densem": densem,
}

# Models retrained with 2:4 structured pruning for the STC study (§5.3,
# Table 6). The paper uses ResNet-18/50/101; we use the residual family
# plus densem for a non-residual point.
STC_ZOO = ["resnet10", "resnet18m", "densem"]


def build(arch: str) -> dict:
    return ZOO[arch]()


__all__ = ["ZOO", "STC_ZOO", "build"]
