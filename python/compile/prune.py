"""2:4 structured pruning + fine-tuning for the STC study (paper §5.3).

NVIDIA Ampere's Sparse Tensor Cores require every group of 4 adjacent
weights along the reduction axis to contain >= 2 zeros. The paper prunes
pretrained ImageNet models and retrains for 90 epochs; at our scale we
magnitude-prune the trained mini-zoo checkpoints and fine-tune briefly
with the mask pinned (prune-and-tune), which restores baseline accuracy
on the synthetic task.

Group layout: the reduction axis of the im2col GEMM orders features as
(C, kh, kw) — see layers._im2col — so the "4 adjacent weights" of the STC
are 4 adjacent *rows* of the flattened (C*k*k, O) weight matrix. We prune
in exactly that layout so the rust STC engine (rust/src/hw/stc.rs) sees
genuine 2:4 structure without re-ordering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, model, train

FINETUNE_STEPS = 250


def prune_mask_24(w_hwio: jnp.ndarray) -> jnp.ndarray:
    """2:4 magnitude mask for an HWIO conv weight, grouped along the
    flattened (C, kh, kw) reduction order. Keeps the 2 largest |w| of
    every group of 4; trailing partial groups (K % 4 != 0) are kept."""
    kh, kw, c, o = w_hwio.shape
    flat = jnp.transpose(w_hwio, (2, 0, 1, 3)).reshape(-1, o)  # (K, O)
    k = flat.shape[0]
    kg = (k // 4) * 4
    head, tail = flat[:kg], flat[kg:]
    g = head.reshape(-1, 4, o)
    order = jnp.argsort(jnp.abs(g), axis=1)  # ascending
    ranks = jnp.argsort(order, axis=1)  # rank of each weight in its group
    mask_g = (ranks >= 2).astype(jnp.float32)  # keep top-2 by magnitude
    mask = jnp.concatenate([mask_g.reshape(kg, o), jnp.ones_like(tail)], axis=0)
    return jnp.transpose(mask.reshape(c, kh, kw, o), (1, 2, 0, 3))


def build_mask(graph, params):
    """Pytree of multiplicative masks: 2:4 on quantized conv weights,
    all-ones elsewhere (biases, BN, first conv, fc)."""
    quant = {n["name"] for n in layers.conv_nodes(graph) if n["quant"]}
    mask = {}
    for name, p in params.items():
        mask[name] = {k: jnp.ones_like(v) for k, v in p.items()}
        if name in quant:
            mask[name]["w"] = prune_mask_24(p["w"])
    return mask


def check_24(w_hwio: np.ndarray, tol: float = 0.0) -> bool:
    """Verify 2:4 structure in the (C, kh, kw) reduction layout."""
    kh, kw, c, o = w_hwio.shape
    flat = np.transpose(w_hwio, (2, 0, 1, 3)).reshape(-1, o)
    kg = (flat.shape[0] // 4) * 4
    g = flat[:kg].reshape(-1, 4, o)
    nz = (np.abs(g) > tol).sum(axis=1)
    return bool((nz <= 2).all())


def sparsity(params, graph) -> float:
    quant = {n["name"] for n in layers.conv_nodes(graph) if n["quant"]}
    zeros = total = 0
    for name in quant:
        w = np.asarray(params[name]["w"])
        zeros += int((w == 0).sum())
        total += w.size
    return zeros / max(total, 1)


def prune_and_finetune(arch: str, d: dict, params, state, steps: int = FINETUNE_STEPS):
    """Magnitude-prune to 2:4 and fine-tune with the mask pinned."""
    graph = model.build(arch)
    mask = build_mask(graph, params)
    params = jax.tree.map(lambda p, m: p * m, params, mask)
    return train.train_model(
        arch, d, steps=steps, init_from=(params, state), mask=mask
    )
