"""Synthetic "shapes" dataset — the ImageNet substitute (DESIGN.md S1).

Ten procedurally generated pattern classes over HxWx3 uint8 images with
randomized geometry, color, background and noise. The task is easy enough
for mini-CNNs to reach high accuracy in a few hundred steps, yet the
trained activations show the two properties SPARQ exploits:

  * bell-shaped (post-ReLU, zero-inflated) activation distributions, and
  * substantial dynamic zero-value sparsity.

Both are asserted by tests (python/tests/test_data.py checks the dataset,
test_model.py checks trained-activation sparsity) and re-measured at the
rust layer (`sparq-cli stats`, experiment F2).

The dataset is written both as .npz (python/training side) and as a flat
.bin (rust side; see rust/src/data/loader.rs for the mirrored format).
"""

from __future__ import annotations

import os
import struct

import numpy as np

H = W = 20
C = 3
NUM_CLASSES = 10
MAGIC = b"SPRQDS1\x00"

_TRAIN_N = 12000
_TEST_N = 2000


def _grid(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-sample coordinate grids, shape (n, H, W), in [0, 1]."""
    ys, xs = np.meshgrid(np.arange(H), np.arange(W), indexing="ij")
    ys = np.broadcast_to(ys[None], (n, H, W)).astype(np.float32) / (H - 1)
    xs = np.broadcast_to(xs[None], (n, H, W)).astype(np.float32) / (W - 1)
    return ys, xs


def _stripes(rng, n, vertical: bool) -> np.ndarray:
    ys, xs = _grid(n)
    coord = xs if vertical else ys
    period = rng.uniform(0.18, 0.4, size=(n, 1, 1)).astype(np.float32)
    phase = rng.uniform(0, 1, size=(n, 1, 1)).astype(np.float32)
    return (np.sin(2 * np.pi * (coord / period + phase)) > 0).astype(np.float32)


def _checker(rng, n) -> np.ndarray:
    ys, xs = _grid(n)
    period = rng.uniform(0.22, 0.45, size=(n, 1, 1)).astype(np.float32)
    phase_y = rng.uniform(0, 1, size=(n, 1, 1)).astype(np.float32)
    phase_x = rng.uniform(0, 1, size=(n, 1, 1)).astype(np.float32)
    a = np.sin(2 * np.pi * (ys / period + phase_y)) > 0
    b = np.sin(2 * np.pi * (xs / period + phase_x)) > 0
    return (a ^ b).astype(np.float32)


def _center_radius(rng, n):
    cy = rng.uniform(0.35, 0.65, size=(n, 1, 1)).astype(np.float32)
    cx = rng.uniform(0.35, 0.65, size=(n, 1, 1)).astype(np.float32)
    r = rng.uniform(0.18, 0.32, size=(n, 1, 1)).astype(np.float32)
    return cy, cx, r


def _disk(rng, n) -> np.ndarray:
    ys, xs = _grid(n)
    cy, cx, r = _center_radius(rng, n)
    d = np.sqrt((ys - cy) ** 2 + (xs - cx) ** 2)
    return (d < r).astype(np.float32)


def _ring(rng, n) -> np.ndarray:
    ys, xs = _grid(n)
    cy, cx, r = _center_radius(rng, n)
    d = np.sqrt((ys - cy) ** 2 + (xs - cx) ** 2)
    wdt = rng.uniform(0.05, 0.1, size=(n, 1, 1)).astype(np.float32)
    return (np.abs(d - r) < wdt).astype(np.float32)


def _cross(rng, n) -> np.ndarray:
    ys, xs = _grid(n)
    cy, cx, _ = _center_radius(rng, n)
    wdt = rng.uniform(0.06, 0.12, size=(n, 1, 1)).astype(np.float32)
    return ((np.abs(ys - cy) < wdt) | (np.abs(xs - cx) < wdt)).astype(np.float32)


def _diag(rng, n) -> np.ndarray:
    ys, xs = _grid(n)
    slope = rng.uniform(0.6, 1.6, size=(n, 1, 1)).astype(np.float32)
    sign = np.where(rng.random(size=(n, 1, 1)) < 0.5, 1.0, -1.0).astype(np.float32)
    off = rng.uniform(-0.2, 0.2, size=(n, 1, 1)).astype(np.float32)
    wdt = rng.uniform(0.05, 0.11, size=(n, 1, 1)).astype(np.float32)
    d = ys - (0.5 + sign * slope * (xs - 0.5) + off)
    return (np.abs(d) < wdt).astype(np.float32)


def _square(rng, n) -> np.ndarray:
    ys, xs = _grid(n)
    cy, cx, r = _center_radius(rng, n)
    wdt = rng.uniform(0.05, 0.09, size=(n, 1, 1)).astype(np.float32)
    dy, dx = np.abs(ys - cy), np.abs(xs - cx)
    outer = np.maximum(dy, dx) < r
    inner = np.maximum(dy, dx) < (r - wdt)
    return (outer & ~inner).astype(np.float32)


def _dots(rng, n) -> np.ndarray:
    ys, xs = _grid(n)
    out = np.zeros((n, H, W), dtype=np.float32)
    for _ in range(2):
        cy = rng.uniform(0.2, 0.8, size=(n, 1, 1)).astype(np.float32)
        cx = rng.uniform(0.2, 0.8, size=(n, 1, 1)).astype(np.float32)
        r = rng.uniform(0.08, 0.16, size=(n, 1, 1)).astype(np.float32)
        d = np.sqrt((ys - cy) ** 2 + (xs - cx) ** 2)
        out = np.maximum(out, (d < r).astype(np.float32))
    return out


def _blob(rng, n) -> np.ndarray:
    """Soft anisotropic gradient blob (the only non-binary mask class)."""
    ys, xs = _grid(n)
    cy, cx, r = _center_radius(rng, n)
    ay = rng.uniform(0.6, 1.6, size=(n, 1, 1)).astype(np.float32)
    ax = rng.uniform(0.6, 1.6, size=(n, 1, 1)).astype(np.float32)
    d2 = ay * (ys - cy) ** 2 + ax * (xs - cx) ** 2
    return np.clip(1.0 - d2 / (r**2 + 1e-6), 0.0, 1.0).astype(np.float32)


_GENERATORS = [
    lambda rng, n: _stripes(rng, n, vertical=False),  # 0 horizontal stripes
    lambda rng, n: _stripes(rng, n, vertical=True),  # 1 vertical stripes
    _checker,  # 2 checkerboard
    _disk,  # 3 filled disk
    _ring,  # 4 ring
    _cross,  # 5 cross
    _diag,  # 6 diagonal bar
    _square,  # 7 square outline
    _dots,  # 8 two dots
    _blob,  # 9 gradient blob
]


def _colorize(rng, mask: np.ndarray) -> np.ndarray:
    """Mask (n,H,W) in [0,1] -> uint8 image batch (n,H,W,3)."""
    n = mask.shape[0]
    fg = rng.uniform(0.55, 1.0, size=(n, 1, 1, 3)).astype(np.float32)
    bg = rng.uniform(0.0, 0.3, size=(n, 1, 1, 3)).astype(np.float32)
    # mild background gradient so the background is not constant
    ys, xs = _grid(n)
    gdir = rng.uniform(-1, 1, size=(n, 1, 1, 2)).astype(np.float32)
    grad = 0.1 * (gdir[..., 0] * (ys - 0.5) + gdir[..., 1] * (xs - 0.5))
    img = bg + grad[..., None] + mask[..., None] * (fg - bg)
    img = img + rng.normal(0, 0.035, size=img.shape).astype(np.float32)
    return (np.clip(img, 0, 1) * 255.0).round().astype(np.uint8)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` labelled images. Returns (images u8 (n,H,W,3), labels u8)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.uint8)
    images = np.zeros((n, H, W, C), dtype=np.uint8)
    for cls in range(NUM_CLASSES):
        idx = np.nonzero(labels == cls)[0]
        if idx.size == 0:
            continue
        mask = _GENERATORS[cls](rng, idx.size)
        images[idx] = _colorize(rng, mask)
    return images, labels


def write_bin(path: str, images: np.ndarray, labels: np.ndarray) -> None:
    """Flat binary format shared with rust/src/data/loader.rs.

    Layout: MAGIC(8) | n u32 | h u32 | w u32 | c u32 | nclasses u32
            | images u8[n*h*w*c] | labels u8[n]      (all little-endian)
    """
    n, h, w, c = images.shape
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<5I", n, h, w, c, NUM_CLASSES))
        f.write(images.tobytes(order="C"))
        f.write(labels.tobytes(order="C"))


def load_or_generate(out_dir: str) -> dict[str, np.ndarray]:
    """Idempotent dataset materialization into `out_dir`."""
    npz_path = os.path.join(out_dir, "dataset.npz")
    if os.path.exists(npz_path):
        d = np.load(npz_path)
        return {k: d[k] for k in d.files}
    os.makedirs(out_dir, exist_ok=True)
    xtr, ytr = generate(_TRAIN_N, seed=2021)
    xte, yte = generate(_TEST_N, seed=7)
    np.savez_compressed(
        npz_path, x_train=xtr, y_train=ytr, x_test=xte, y_test=yte
    )
    write_bin(os.path.join(out_dir, "train.bin"), xtr, ytr)
    write_bin(os.path.join(out_dir, "test.bin"), xte, yte)
    return {"x_train": xtr, "y_train": ytr, "x_test": xte, "y_test": yte}


def normalize(images_u8: np.ndarray) -> np.ndarray:
    """uint8 -> float32 in [0,1]; the only input preprocessing used anywhere."""
    return images_u8.astype(np.float32) / 255.0


if __name__ == "__main__":
    import sys

    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    d = load_or_generate(out)
    print({k: v.shape for k, v in d.items()})
