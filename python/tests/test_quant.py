"""L2 quantization-path tests: im2col/GEMM conv equivalence, weight
quantization, STC reference, config encoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax import lax

from compile import layers
from compile.kernels import ref


def test_im2col_conv_equals_lax_conv():
    """Quantized-path conv (patches @ flattened weights) must equal
    lax.conv for float inputs — validates the (C, kh, kw) ordering."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 9, 9, 5)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 5, 7)).astype(np.float32))
    for stride in [1, 2]:
        y_conv = lax.conv_general_dilated(
            x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        p, (n, oh, ow) = layers._im2col(x, 3, stride)
        wf = jnp.transpose(w, (2, 0, 1, 3)).reshape(-1, 7)
        y_gemm = (p @ wf).reshape(n, oh, ow, 7)
        np.testing.assert_allclose(np.asarray(y_conv), np.asarray(y_gemm), atol=1e-4)


def test_weight_quantization_per_channel():
    rng = np.random.default_rng(1)
    graph = {"nodes": [], "arch": "t", "num_classes": 2}
    w = rng.normal(size=(3, 3, 4, 6)).astype(np.float32)
    w[..., 0] *= 100  # one big channel must not crush the others
    folded = {"c": {"w": jnp.asarray(w), "b": jnp.zeros(6)}, "fc": {"w": jnp.zeros((6, 2)), "b": jnp.zeros(2)}}
    graph["nodes"] = [
        {"name": "c", "op": "conv", "inputs": ["img"], "k": 3, "stride": 1,
         "out_ch": 6, "relu": True, "quant": True}
    ]
    q = layers.quantize_weights(graph, folded)
    wq = np.asarray(q["c"]["wq"])
    scale = np.asarray(q["c"]["scale"])
    assert wq.min() >= -127 and wq.max() <= 127
    # per-channel max must hit the grid end
    for c in range(6):
        assert abs(np.abs(wq[..., c]).max() - 127) <= 1
    recon = wq * scale
    np.testing.assert_allclose(recon, w, atol=np.abs(w).max() / 127 + 1e-6)


@given(seed=st.integers(0, 2**16), name=st.sampled_from(["5opt_r", "2opt", "7opt_r", "a8w8"]))
@settings(max_examples=20, deadline=None)
def test_stc_pairdot_zero_weights_drop_out(seed, name):
    """STC reference: output only depends on activations at surviving
    (non-zero-weight) coordinates."""
    rng = np.random.default_rng(seed)
    k, n, m = 16, 3, 4
    w = np.zeros((k, n), dtype=np.int32)
    for g in range(k // 4):
        for col in range(n):
            picks = rng.choice(4, size=2, replace=False)
            for p in picks:
                w[4 * g + p, col] = int(rng.integers(1, 127))
    a = rng.integers(0, 256, size=(m, k)).astype(np.int32)
    cfg = ref.named_config(name)
    base = np.asarray(ref.stc_pairdot_ref(jnp.asarray(a), jnp.asarray(w), cfg))
    # perturb activations at dead coordinates only -> output unchanged
    a2 = a.copy()
    for g in range(k // 4):
        col_dead = set(range(4))
        for col in range(n):
            col_dead &= {s for s in range(4) if w[4 * g + s, col] == 0}
        for s in col_dead:
            a2[:, 4 * g + s] = rng.integers(0, 256, size=m)
    out2 = np.asarray(ref.stc_pairdot_ref(jnp.asarray(a2), jnp.asarray(w), cfg))
    np.testing.assert_array_equal(base, out2)


def test_stc_a8w8_equals_dense():
    rng = np.random.default_rng(5)
    k, n, m = 12, 4, 3
    w = np.zeros((k, n), dtype=np.int32)
    for g in range(k // 4):
        for col in range(n):
            for p in rng.choice(4, size=2, replace=False):
                w[4 * g + p, col] = int(rng.integers(-126, 127)) or 1
    a = rng.integers(0, 256, size=(m, k)).astype(np.int32)
    out = np.asarray(ref.stc_pairdot_ref(jnp.asarray(a), jnp.asarray(w), ref.named_config("a8w8")))
    np.testing.assert_array_equal(out, a @ w)


def test_uniform_requant_grid_spacing():
    x = jnp.arange(256, dtype=jnp.int32)
    y4 = np.asarray(ref.uniform_requant(x, 4))
    assert set(np.unique(y4 % 17)) == {0}
    assert y4[0] == 0 and y4[255] == 255
    y8 = np.asarray(ref.uniform_requant(x, 8))
    np.testing.assert_array_equal(y8, np.arange(256))


def test_weight_rescale_consistency():
    for name in ["a8w8", "a8w4"]:
        cfg = ref.named_config(name)
        w = jnp.asarray(np.arange(-127, 128, dtype=np.int32))
        wq = np.asarray(ref.requant_weights(w, cfg))
        recon = wq * ref.weight_rescale(cfg)
        assert np.abs(recon - np.asarray(w)).max() <= (ref.weight_rescale(cfg) / 2 + 0.5)


def test_named_configs_roundtrip_all():
    for name in ["a8w8", "5opt", "3opt_r", "2opt_r_novs", "6opt_r", "7opt_r", "a4w8", "a8w4"]:
        cfg = ref.named_config(name)
        assert cfg.shape == (ref.CFG_LEN,)
        assert cfg.dtype == np.int32
