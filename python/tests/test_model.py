"""L2 model-zoo tests: shapes, training step, export-safe forward
equivalence, BN folding, quantized-path integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, layers, model, train
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny_batch():
    imgs, labels = data.generate(16, seed=11)
    return jnp.asarray(data.normalize(imgs)), jnp.asarray(labels.astype(np.int32))


@pytest.mark.parametrize("arch", list(model.ZOO))
def test_forward_shapes(arch, tiny_batch):
    x, _ = tiny_batch
    graph = model.build(arch)
    params, state = layers.init_params(graph, jax.random.PRNGKey(0))
    logits, new_state, taps = layers.forward_float(graph, params, state, x, train=True)
    assert logits.shape == (16, 10)
    assert set(taps) == set(layers.quant_conv_names(graph))
    # BN state updated for every conv
    assert set(new_state) == {n["name"] for n in layers.conv_nodes(graph)}
    assert not np.isnan(np.asarray(logits)).any()


@pytest.mark.parametrize("arch", ["resnet10", "squeezem"])
def test_one_train_step_reduces_loss_eventually(arch, tiny_batch):
    x, y = tiny_batch
    graph = model.build(arch)
    params, state = layers.init_params(graph, jax.random.PRNGKey(1))
    opt = train.adam_init(params)
    step = train.make_step(graph, total_steps=50)
    losses = []
    for it in range(12):
        params, state, opt, loss = step(params, state, opt, x, y, it)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_bn_fold_matches_inference_forward(tiny_batch):
    """Folded forward == unfolded inference forward (same BN stats)."""
    x, _ = tiny_batch
    graph = model.build("resnet10")
    params, state = layers.init_params(graph, jax.random.PRNGKey(2))
    # make running stats non-trivial
    _, state, _ = layers.forward_float(graph, params, state, x, train=True)
    logits_ref, _, _ = layers.forward_float(graph, params, state, x, train=False)
    folded = layers.fold_batchnorm(graph, params, state)
    logits_fold = layers.forward_folded(graph, folded, x)
    np.testing.assert_allclose(
        np.asarray(logits_ref), np.asarray(logits_fold), rtol=2e-3, atol=2e-3
    )


def test_export_safe_ops_match_lax(tiny_batch):
    """conv_float_export / _pool2_export == lax.conv / reduce_window."""
    x, _ = tiny_batch
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 8)).astype(np.float32) * 0.2)
    b = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    for stride in [1, 2]:
        safe = layers.conv_float_export(x, w, b, stride)
        fast = layers._conv_float(x, w, stride) + b
        np.testing.assert_allclose(np.asarray(safe), np.asarray(fast), atol=1e-4)
    for kind in ["max", "avg"]:
        np.testing.assert_allclose(
            np.asarray(layers._pool2_export(x, kind)),
            np.asarray(layers._pool2(x, kind)),
            atol=1e-6,
        )


@pytest.mark.parametrize("arch", ["resnet10", "inceptm", "densem"])
def test_quant_forward_runs_all_archs(arch, tiny_batch):
    x, _ = tiny_batch
    graph = model.build(arch)
    params, state = layers.init_params(graph, jax.random.PRNGKey(3))
    folded = layers.fold_batchnorm(graph, params, state)
    qw = layers.quantize_weights(graph, folded)
    nq = len(layers.quant_conv_names(graph))
    maxes, means = layers.calib_forward(graph, folded, x)
    assert maxes.shape == (nq,) and means.shape == (nq,)
    assert float(jnp.min(means)) >= 0.0  # post-ReLU inputs
    cfg = jnp.asarray(ref.named_config("5opt_r"))
    logits = layers.forward_quant(graph, qw, maxes / 255.0, cfg, x, use_pallas=False)
    assert logits.shape == (16, 10)
    assert not np.isnan(np.asarray(logits)).any()


def test_a8w8_quant_close_to_float(tiny_batch):
    """8-bit min-max quantization must track the float forward closely
    (the paper's Table 1 A8W8 ~ FP32 premise)."""
    x, _ = tiny_batch
    graph = model.build("vgg11m")
    params, state = layers.init_params(graph, jax.random.PRNGKey(4))
    _, state, _ = layers.forward_float(graph, params, state, x, train=True)
    folded = layers.fold_batchnorm(graph, params, state)
    qw = layers.quantize_weights(graph, folded)
    maxes, _ = layers.calib_forward(graph, folded, x)
    cfg = jnp.asarray(ref.named_config("a8w8"))
    lf = np.asarray(layers.forward_folded(graph, folded, x))
    lq = np.asarray(layers.forward_quant(graph, qw, maxes / 255.0, cfg, x, use_pallas=False))
    # logits agree to a tight relative scale
    denom = np.abs(lf).max()
    assert np.abs(lf - lq).max() / denom < 0.05


def test_checkpoint_roundtrip(tmp_path, tiny_batch):
    x, _ = tiny_batch
    graph = model.build("resnet10")
    params, state = layers.init_params(graph, jax.random.PRNGKey(5))
    path = tmp_path / "ckpt.npz"
    train.save_checkpoint(path, params, state)
    p2, s2 = train.load_checkpoint(path)
    l1, _, _ = layers.forward_float(graph, params, state, x, train=False)
    l2, _, _ = layers.forward_float(graph, p2, s2, x, train=False)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
