"""2:4 structured pruning tests (paper §5.3 substrate)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import layers, model, prune


@given(
    kh=st.sampled_from([1, 3]),
    c=st.integers(1, 12),
    o=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=15, deadline=None)
def test_mask_is_24_structured(kh, c, o, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(kh, kh, c, o)).astype(np.float32))
    mask = prune.prune_mask_24(w)
    assert mask.shape == w.shape
    pruned = np.asarray(w * mask)
    assert prune.check_24(pruned)
    # exactly half kept in every complete group
    flat = np.transpose(np.asarray(mask), (2, 0, 1, 3)).reshape(-1, o)
    kg = flat.shape[0] // 4 * 4
    if kg:
        g = flat[:kg].reshape(-1, 4, o)
        np.testing.assert_array_equal(g.sum(axis=1), np.full((kg // 4, o), 2.0))


def test_mask_keeps_largest_magnitudes():
    w = jnp.asarray(
        np.array([10.0, -9.0, 0.1, 0.2]).reshape(1, 1, 4, 1).astype(np.float32)
    )
    mask = np.asarray(prune.prune_mask_24(w)).reshape(4)
    np.testing.assert_array_equal(mask, [1, 1, 0, 0])


def test_build_mask_covers_only_quant_convs():
    graph = model.build("resnet10")
    params, _ = layers.init_params(graph, jax.random.PRNGKey(0))
    mask = prune.build_mask(graph, params)
    for node in layers.conv_nodes(graph):
        m = np.asarray(mask[node["name"]]["w"])
        if node["quant"]:
            assert m.mean() < 1.0  # pruned
        else:
            assert m.mean() == 1.0  # first conv untouched
    # non-weight params never masked
    assert np.asarray(mask["fc"]["w"]).mean() == 1.0


def test_sparsity_metric():
    graph = model.build("resnet10")
    params, _ = layers.init_params(graph, jax.random.PRNGKey(1))
    mask = prune.build_mask(graph, params)
    pruned = jax.tree.map(lambda p, m: p * m, params, mask)
    s = prune.sparsity(pruned, graph)
    assert 0.45 <= s <= 0.55, s
