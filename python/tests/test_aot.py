"""AOT export contract tests — validate what the rust side will consume.

These run against the real artifacts/ directory when it exists (CI runs
them after `make artifacts`); the pure-function tests always run.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, layers, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


def test_hlo_text_contract():
    """Exported text must contain full constants and none of the ops
    xla_extension 0.5.1 mis-executes (see aot.to_hlo_text docstring)."""
    w = jnp.asarray(np.random.default_rng(0).normal(size=(3, 3, 3, 4)).astype(np.float32))
    b = jnp.zeros((4,), jnp.float32)
    f = lambda x: (layers.conv_float_export(x, w, b, 2),)
    spec = jax.ShapeDtypeStruct((2, 8, 8, 3), jnp.float32)
    text = aot.to_hlo_text(jax.jit(f).lower(spec))
    assert "ENTRY" in text
    assert "constant({...})" not in text, "elided constants"
    assert " convolution(" not in text, "convolution op leaked"
    assert " reduce-window(" not in text, "reduce-window op leaked"


@needs_artifacts
def test_manifest_complete():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    tags = {row["tag"] for row in man}
    assert set(model.ZOO) <= tags
    for arch in model.STC_ZOO:
        assert f"{arch}_p24" in tags
    for row in man:
        for f in list(row["files"].values()) + [row["weights"], row["meta"]]:
            assert os.path.exists(os.path.join(ART, f)), f
        meta = json.load(open(os.path.join(ART, row["meta"])))
        assert meta["quant_convs"], row["tag"]
        assert row["quant_convs"] == len(meta["quant_convs"])


@needs_artifacts
def test_no_bad_ops_in_exported_artifacts():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    for row in man:
        for f in row["files"].values():
            text = open(os.path.join(ART, f)).read()
            assert "constant({...})" not in text, f
            assert " convolution(" not in text, f
            assert " reduce-window(" not in text, f


@needs_artifacts
def test_weights_npz_layout():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    row = next(r for r in man if r["tag"] == "resnet10")
    w = np.load(os.path.join(ART, row["weights"]))
    meta = json.load(open(os.path.join(ART, row["meta"])))
    for conv in meta["quant_convs"]:
        wq = w[f"{conv}.wq"]
        assert wq.dtype == np.int8 and wq.ndim == 2
        assert w[f"{conv}.scale"].shape == (wq.shape[1],)
        assert w[f"{conv}.bias"].shape == (wq.shape[1],)
        assert np.abs(wq).max() <= 127
        # per-channel quantization used the full grid somewhere
        assert np.abs(wq).max(axis=0).min() >= 100
    assert w["fc.w"].ndim == 2


@needs_artifacts
def test_pruned_weights_are_24_structured():
    from compile import prune

    man = json.load(open(os.path.join(ART, "manifest.json")))
    for row in man:
        if not row["pruned"]:
            continue
        w = np.load(os.path.join(ART, row["weights"]))
        meta = json.load(open(os.path.join(ART, row["meta"])))
        for conv in meta["quant_convs"]:
            wq = w[f"{conv}.wq"]  # (K, O) flattened, already (C,kh,kw)
            k = wq.shape[0] // 4 * 4
            g = wq[:k].reshape(-1, 4, wq.shape[1])
            nz = (g != 0).sum(axis=1)
            assert (nz <= 2).all(), f"{row['tag']}:{conv} not 2:4"
