"""Dataset generator tests: determinism, format, class separability
preconditions."""

import io
import struct

import numpy as np

from compile import data


def test_deterministic():
    a, la = data.generate(64, seed=42)
    b, lb = data.generate(64, seed=42)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)
    c, _ = data.generate(64, seed=43)
    assert not np.array_equal(a, c)


def test_shapes_and_ranges():
    imgs, labels = data.generate(128, seed=0)
    assert imgs.shape == (128, data.H, data.W, data.C)
    assert imgs.dtype == np.uint8
    assert labels.dtype == np.uint8
    assert labels.max() < data.NUM_CLASSES
    assert set(np.unique(labels)).issubset(set(range(10)))


def test_all_classes_generated():
    _, labels = data.generate(500, seed=1)
    assert len(np.unique(labels)) == data.NUM_CLASSES


def test_classes_visually_distinct():
    """Mean intra-class pixel correlation must exceed inter-class —
    the weak separability precondition for training."""
    imgs, labels = data.generate(400, seed=3)
    f = imgs.reshape(len(imgs), -1).astype(np.float32)
    f = (f - f.mean(axis=1, keepdims=True)) / (f.std(axis=1, keepdims=True) + 1e-6)
    means = np.stack([f[labels == c].mean(axis=0) for c in range(10)])
    sims = means @ means.T / f.shape[1]
    intra = np.diag(sims).mean()
    inter = (sims.sum() - np.trace(sims)) / 90
    assert intra > inter + 0.02, (intra, inter)


def test_bin_roundtrip(tmp_path):
    imgs, labels = data.generate(10, seed=9)
    path = tmp_path / "ds.bin"
    data.write_bin(str(path), imgs, labels)
    raw = path.read_bytes()
    assert raw[:8] == data.MAGIC
    n, h, w, c, k = struct.unpack("<5I", raw[8:28])
    assert (n, h, w, c, k) == (10, data.H, data.W, data.C, data.NUM_CLASSES)
    body = np.frombuffer(raw[28 : 28 + imgs.size], dtype=np.uint8).reshape(imgs.shape)
    np.testing.assert_array_equal(body, imgs)
    np.testing.assert_array_equal(
        np.frombuffer(raw[28 + imgs.size :], dtype=np.uint8), labels
    )


def test_normalize():
    imgs, _ = data.generate(4, seed=0)
    x = data.normalize(imgs)
    assert x.dtype == np.float32
    assert x.min() >= 0.0 and x.max() <= 1.0
