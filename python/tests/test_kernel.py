"""L1 correctness: Pallas kernel vs the pure-jnp oracle — the CORE
integer-exactness signal of the whole stack (DESIGN.md S4/S5).

hypothesis sweeps shapes, sparsity levels and every named configuration;
all comparisons are exact equality (integer arithmetic end to end).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sparq

CONFIG_NAMES = [
    "a8w8", "a4w8", "a8w4", "5opt", "5opt_r", "5opt_r_novs",
    "3opt", "3opt_r", "3opt_r_novs", "2opt", "2opt_r", "2opt_r_novs",
    "6opt_r", "6opt_r_novs", "7opt_r", "7opt_r_novs",
]


def rand_operands(rng, m, k, n, sparsity):
    a = rng.integers(0, 256, size=(m, k)).astype(np.int32)
    a[rng.random((m, k)) < sparsity] = 0
    w = rng.integers(-127, 128, size=(k, n)).astype(np.int32)
    return a, w


@pytest.mark.parametrize("name", CONFIG_NAMES)
def test_matmul_exact_vs_ref(name):
    rng = np.random.default_rng(hash(name) % 2**32)
    a, w = rand_operands(rng, 33, 54, 17, 0.4)
    cfg = ref.named_config(name)
    got = np.asarray(sparq.sparq_matmul(jnp.asarray(a), jnp.asarray(w), cfg, tm=16, tn=16))
    want = np.asarray(ref.sparq_matmul_ref(jnp.asarray(a), jnp.asarray(w), cfg))
    np.testing.assert_array_equal(got, want, err_msg=name)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(2, 80),
    n=st.integers(1, 24),
    sparsity=st.sampled_from([0.0, 0.3, 0.7, 0.95]),
    name=st.sampled_from(["5opt_r", "3opt", "2opt_r", "6opt_r", "7opt_r_novs", "a4w8"]),
    seed=st.integers(0, 2**16),
)
def test_matmul_hypothesis_sweep(m, k, n, sparsity, name, seed):
    rng = np.random.default_rng(seed)
    a, w = rand_operands(rng, m, k, n, sparsity)
    if k % 2 == 1:
        k += 1  # vSPARQ pairing requires even K for the pure-jnp oracle
        a = np.pad(a, ((0, 0), (0, 1)))
        w = np.pad(w, ((0, 1), (0, 0)))
    cfg = ref.named_config(name)
    got = np.asarray(sparq.sparq_matmul(jnp.asarray(a), jnp.asarray(w), cfg, tm=8, tn=8))
    want = np.asarray(ref.sparq_matmul_ref(jnp.asarray(a), jnp.asarray(w), cfg))
    np.testing.assert_array_equal(got, want)


def test_tile_size_invariance():
    """Same inputs, different BlockSpec tilings -> identical results."""
    rng = np.random.default_rng(7)
    a, w = rand_operands(rng, 50, 36, 20, 0.5)
    cfg = ref.named_config("5opt_r")
    outs = [
        np.asarray(sparq.sparq_matmul(jnp.asarray(a), jnp.asarray(w), cfg, tm=tm, tn=tn))
        for tm, tn in [(8, 8), (16, 32), (64, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_trim_kernel_matches_ref():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 256, size=(16, 64)).astype(np.int32)
    a[rng.random(a.shape) < 0.4] = 0
    for name in ["5opt_r", "2opt", "7opt_r"]:
        cfg = ref.named_config(name)
        got = np.asarray(sparq.sparq_trim_pallas(jnp.asarray(a), cfg))
        want = np.asarray(ref.sparq_trim(jnp.asarray(a), cfg))
        np.testing.assert_array_equal(got, want, err_msg=name)


def test_paper_figure1_values():
    """27 = 00011011b: 5opt->26, 3opt->24, 2opt->16 (paper §3.1)."""
    x = jnp.array([27], dtype=jnp.int32)
    assert int(ref.bsparq_window(x, 4, ref.MODE_FULL, 0)[0]) == 26
    assert int(ref.bsparq_window(x, 4, ref.MODE_3OPT, 0)[0]) == 24
    assert int(ref.bsparq_window(x, 4, ref.MODE_2OPT, 0)[0]) == 16
    assert int(ref.bsparq_window(x, 4, ref.MODE_FULL, 1)[0]) == 28


@given(x=st.integers(0, 255), width=st.sampled_from([2, 3, 4]))
@settings(max_examples=60, deadline=None)
def test_trim_error_bound(x, width):
    """|trim(x) - x| < 2^shift; rounding never increases the error."""
    xa = jnp.array([x], dtype=jnp.int32)
    for mode in [ref.MODE_FULL, ref.MODE_3OPT, ref.MODE_2OPT]:
        if width != 4 and mode != ref.MODE_FULL:
            continue
        t = int(ref.bsparq_window(xa, width, mode, 0)[0])
        r = int(ref.bsparq_window(xa, width, mode, 1)[0])
        assert abs(r - x) <= abs(t - x)
        # reconstructed value fits the window
        msb = max(x.bit_length() - 1, 0)
        if mode == ref.MODE_FULL:
            shift = max(0, msb - width + 1)
            assert abs(t - x) < (1 << max(shift, 1))


@given(
    k=st.integers(1, 64),
    seed=st.integers(0, 2**16),
    name=st.sampled_from(["5opt_r", "6opt_r", "7opt_r"]),
)
@settings(max_examples=15, deadline=None)
def test_zero_partner_preserves_wide_window(k, seed, name):
    """With vSPARQ, a zero partner must not lose more than the 2n-bit
    window allows; for n=4 the survivor is bit-exact."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 256, size=k).astype(np.int32)
    a = np.zeros((1, 2 * k), dtype=np.int32)
    a[0, 1::2] = vals  # partners (even lanes) all zero
    cfg = ref.named_config(name)
    out = np.asarray(ref.sparq_trim(jnp.asarray(a), cfg))[0, 1::2]
    n_bits = int(cfg[0])
    if n_bits == 4:
        np.testing.assert_array_equal(out, vals)
    else:
        wide = 2 * n_bits
        for v, o in zip(vals, out):
            msb = max(int(v).bit_length() - 1, 0)
            shift = max(0, msb - wide + 1)
            assert abs(int(o) - int(v)) <= (1 << max(shift, 1)) // 2 + (1 << shift) // 2


def test_vmem_budget():
    """Default tiling fits a TPU core's VMEM with double buffering."""
    assert sparq.vmem_bytes(128, 128, 1152) * 2 < 16 * 1024 * 1024
