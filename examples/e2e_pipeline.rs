//! End-to-end driver (DESIGN.md experiment E2E): exercises every layer
//! of the system on the real workload and reports the paper's headline
//! metric — accuracy degradation per SPARQ configuration.
//!
//! Pipeline stages (artifacts were produced by `make artifacts`, which
//! trained the zoo — the loss curves it logged are summarized here):
//!
//!  1. dataset + trained-model artifacts (L2/L1 build products)
//!  2. PJRT calibration pass per model (L3 coordinator)
//!  3. SPARQ accuracy sweep through the lowered HLO (L1 Pallas kernel
//!     semantics inside), vs the FP32 baseline
//!  4. native-engine cross-check on one model (bit-exact integer path)
//!  5. hardware cycle + area summary for the swept configs
//!
//! ```bash
//! cargo run --release --example e2e_pipeline [artifacts-dir] [eval-limit]
//! ```

use std::path::PathBuf;

use anyhow::Result;
use sparq::coordinator::{calibrate, evaluate_native, evaluate_pjrt};
use sparq::data::Dataset;
use sparq::hw::area;
use sparq::hw::systolic::SystolicArray;
use sparq::json::JsonValue;
use sparq::model::{EngineMode, Graph, Weights};
use sparq::quant::SparqConfig;
use sparq::runtime::{Manifest, PjrtRuntime};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let dir = PathBuf::from(args.get(1).map(String::as_str).unwrap_or("artifacts"));
    let limit: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(512);

    // --- stage 1: artifacts + training log -------------------------------
    let manifest = Manifest::load(&dir)?;
    let eval = Dataset::load(&dir.join("test.bin"))?;
    let calib_ds = Dataset::load(&dir.join("train.bin"))?;
    println!("== stage 1: artifacts ==");
    println!("{} model variants, eval set n={}", manifest.models.len(), eval.n);
    if let Ok(log) = std::fs::read_to_string(dir.join("train_log.json")) {
        let log = JsonValue::parse(&log)?;
        for entry in log.as_array().unwrap_or(&[]) {
            let arch = entry.get("arch").and_then(JsonValue::as_str).unwrap_or("?");
            let acc = entry.get("test_acc").and_then(JsonValue::as_f64).unwrap_or(0.0);
            let losses = entry.get("losses").and_then(JsonValue::as_array).unwrap_or(&[]);
            let first = losses.first().and_then(|l| l.get("loss")).and_then(JsonValue::as_f64);
            let last = losses.last().and_then(|l| l.get("loss")).and_then(JsonValue::as_f64);
            println!(
                "  {arch:<14} loss {:.3} -> {:.3}   test acc {:.2}%",
                first.unwrap_or(f64::NAN),
                last.unwrap_or(f64::NAN),
                100.0 * acc
            );
        }
    }

    let rt = PjrtRuntime::cpu()?;
    let tag = "resnet18m";
    let model = manifest.get(tag)?;

    // --- stage 2: calibration --------------------------------------------
    println!("\n== stage 2: calibration ({tag}) ==");
    let t0 = std::time::Instant::now();
    let stats = calibrate(&rt, model, &calib_ds, 64, 2048)?;
    let scales = stats.scales();
    println!(
        "  {} layers calibrated on 2048 images in {:.2}s",
        scales.len(),
        t0.elapsed().as_secs_f64()
    );

    // --- stage 3: SPARQ sweep through PJRT --------------------------------
    println!("\n== stage 3: SPARQ sweep ({tag}, {limit} images) ==");
    let fp32 = evaluate_pjrt(&rt, model, &eval, 64, &[], None, limit)?;
    println!(
        "  FP32       {:.2}%   ({:.1} img/s)",
        100.0 * fp32.accuracy(),
        fp32.total as f64 / fp32.seconds
    );
    let sweep = ["a8w8", "5opt_r", "3opt_r", "2opt_r", "6opt_r", "7opt_r", "a4w8"];
    for name in sweep {
        let cfg = SparqConfig::named(name).unwrap();
        let rep = evaluate_pjrt(&rt, model, &eval, 64, &scales, Some(cfg), limit)?;
        println!(
            "  {:<10} {:.2}%   (delta {:+.2}%, {:.1} img/s)",
            cfg.to_string(),
            100.0 * rep.accuracy(),
            100.0 * (rep.accuracy() - fp32.accuracy()),
            rep.total as f64 / rep.seconds
        );
    }

    // --- stage 4: native-engine cross-check -------------------------------
    println!("\n== stage 4: native integer engine cross-check ==");
    let graph = Graph::load(&model.meta_path())?;
    let weights = Weights::load(&model.weights_path())?;
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let native = evaluate_native(
        &graph, &weights, &eval, 64, &scales, cfg, EngineMode::Dense, limit.min(256),
    )?;
    let pjrt = evaluate_pjrt(&rt, model, &eval, 64, &scales, Some(cfg), limit.min(256))?;
    println!(
        "  native {}/{} vs pjrt {}/{} correct -> {}",
        native.correct,
        native.total,
        pjrt.correct,
        pjrt.total,
        if native.correct == pjrt.correct { "MATCH" } else { "MISMATCH" }
    );

    // --- stage 5: hardware summary ----------------------------------------
    println!("\n== stage 5: hardware (16x16 SA, first quantized conv GEMM) ==");
    let qc = weights.quant_conv(&graph.quant_convs[0])?;
    let (m, k, n) = (400, qc.k, qc.o);
    let a: Vec<u8> = (0..m * k)
        .map(|i| {
            let h = (i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33;
            if h % 5 == 0 {
                0
            } else {
                (h % 256) as u8
            }
        })
        .collect();
    for name in ["5opt_r", "3opt_r", "2opt_r"] {
        let cfg = SparqConfig::named(name).unwrap();
        let sa = SystolicArray::new(16, 16, cfg);
        let run = sa.gemm(&a, &qc.wq, m, k, n);
        let ratio = area::sa_sparq(cfg).per_mac() / area::sa_baseline().per_mac();
        println!(
            "  {:<8} cycles {:>7} (baseline {:>7})  area/MAC {:.2}",
            cfg.to_string(),
            run.cycles,
            sa.baseline_cycles(m, k, n),
            ratio
        );
    }
    println!("\nE2E pipeline complete.");
    Ok(())
}
