//! Serving benchmark: the dynamically batched SPARQ inference service
//! under concurrent client load — latency/throughput for the paper's
//! "increase execution performance" motivation.
//!
//! ```bash
//! cargo run --release --example serve_bench [artifacts-dir] [clients] [requests-per-client]
//! ```
//!
//! With exported artifacts + a real PJRT backend the bench drives the
//! single-model `InferenceServer` over the compiled HLO. Without them
//! (this image's default) it falls back to the **native sharded
//! router**: a synthetic model served by N replica shards that share
//! one `Arc<ModelParams>` parameter copy, printing per-shard and
//! aggregate metrics — queue depth, shed/rejected counts included.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use sparq::coordinator::{calibrate, BatchPolicy, InferenceRouter, InferenceServer};
use sparq::data::Dataset;
use sparq::model::demo::synth_model;
use sparq::model::{EngineMode, Graph, ModelParams};
use sparq::quant::SparqConfig;
use sparq::runtime::{Manifest, PjrtRuntime};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let dir = PathBuf::from(args.get(1).map(String::as_str).unwrap_or("artifacts"));
    let clients: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(16);
    let per_client: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(32);

    // Probe *availability* only (backend + manifest). A failure here
    // means the PJRT path can't run at all and the native router demo
    // is the right fallback; a failure later — mid-serving, on an
    // artifacts dir that does exist — is a real error and must
    // propagate, not be silently downgraded to the synthetic bench.
    let probe = || -> Result<(Arc<PjrtRuntime>, Manifest)> {
        Ok((Arc::new(PjrtRuntime::cpu()?), Manifest::load(&dir)?))
    };
    match probe() {
        Ok((rt, manifest)) => pjrt_serving(rt, &manifest, &dir, clients, per_client),
        Err(e) => {
            eprintln!(
                "PJRT serving path unavailable ({e}); \
                 running the native sharded-router benchmark instead\n"
            );
            native_router_bench(clients, per_client)
        }
    }
}

/// The original artifact-backed path: one PJRT-executed model behind
/// the dynamic batcher.
fn pjrt_serving(
    rt: Arc<PjrtRuntime>,
    manifest: &Manifest,
    dir: &Path,
    clients: usize,
    per_client: usize,
) -> Result<()> {
    let model = manifest.get("resnet10")?;
    let graph = Graph::load(&model.meta_path())?;
    let eval = Arc::new(Dataset::load(&dir.join("test.bin"))?);
    let calib_ds = Dataset::load(&dir.join("train.bin"))?;
    let scales = calibrate(&rt, model, &calib_ds, 64, 512)?.scales();

    let server = Arc::new(InferenceServer::start(
        rt,
        model,
        graph.input_hwc,
        graph.num_classes,
        scales,
        SparqConfig::named("5opt_r").unwrap(),
        BatchPolicy {
            max_batch: graph.eval_batch,
            max_wait: Duration::from_millis(4),
            ..BatchPolicy::default()
        },
    )?);

    println!(
        "serving resnet10 (SPARQ 5opt+R) to {clients} clients x {per_client} requests, \
         batch up to {} ...",
        graph.eval_batch
    );
    // warmup: first request triggers nothing extra (exe precompiled), but
    // prime the pipeline anyway
    let _ = server.infer(eval.image_f32(0))?;

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let s = server.clone();
            let d = eval.clone();
            std::thread::spawn(move || -> (usize, usize) {
                let mut correct = 0;
                for r in 0..per_client {
                    let idx = (c * per_client + r) % d.n;
                    let reply = s.infer(d.image_f32(idx)).unwrap();
                    let pred = reply
                        .logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if pred == d.label(idx) {
                        correct += 1;
                    }
                }
                (correct, per_client)
            })
        })
        .collect();
    let mut correct = 0;
    let mut total = 0;
    for h in handles {
        let (c, t) = h.join().unwrap();
        correct += c;
        total += t;
    }
    let wall = t0.elapsed().as_secs_f64();

    let metrics = server.metrics();
    let m = metrics.lock().unwrap();
    let b = m.batcher.snapshot();
    println!("\nresults:");
    let pct = 100.0 * correct as f64 / total as f64;
    println!("  requests        {total}  ({correct} correct = {pct:.2}%)");
    println!("  wall time       {wall:.2}s");
    println!("  throughput      {:.1} req/s", total as f64 / wall);
    println!("  latency mean    {:.1} ms", m.e2e.mean_us() / 1000.0);
    println!("  latency p50     {:.1} ms", m.e2e.quantile_us(0.50) as f64 / 1000.0);
    println!("  latency p99     {:.1} ms", m.e2e.quantile_us(0.99) as f64 / 1000.0);
    println!("  latency max     {:.1} ms", m.e2e.max_us() as f64 / 1000.0);
    println!("  queue mean      {:.1} ms", m.queue.mean_us() / 1000.0);
    println!(
        "  batches         {}  (full: {}, exec errors: {})",
        b.batches, b.full_batches, b.exec_errors
    );
    println!(
        "  peak queue      {}  (shed: {}, rejected: {})",
        b.peak_queue_depth, b.shed, b.rejected
    );
    Ok(())
}

/// Artifact-free path: a synthetic model served by the sharded router,
/// 1 replica vs all-cores replicas, parameters Arc-shared throughout.
fn native_router_bench(clients: usize, per_client: usize) -> Result<()> {
    let (graph, weights, scales) = synth_model();
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let params = Arc::new(ModelParams::new(
        Arc::new(graph),
        Arc::new(weights),
        cfg,
        &scales,
        EngineMode::Dense,
    )?);
    let [h, w, c] = params.graph.input_hwc;
    let image: Vec<f32> = (0..h * w * c)
        .map(|i| ((i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33) as f32 % 251.0 / 251.0)
        .collect();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let replicas = cores.max(2);
    println!(
        "native router: synthetic model (SPARQ 5opt+R), {} parameter bytes shared by \
         every replica; {clients} clients x {per_client} requests",
        params.weights.param_bytes()
    );

    for nrep in [1usize, replicas] {
        let router = Arc::new(
            InferenceRouter::builder()
                .model_with_threads(
                    "synth",
                    params.clone(),
                    nrep,
                    BatchPolicy {
                        max_batch: 8,
                        max_wait: Duration::from_micros(500),
                        ..BatchPolicy::default()
                    },
                    1,
                )
                .build()?,
        );
        let _ = router.infer("synth", image.clone())?; // warmup
        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let r = router.clone();
                let im = image.clone();
                std::thread::spawn(move || {
                    for _ in 0..per_client {
                        r.infer("synth", im.clone()).unwrap();
                    }
                })
            })
            .collect();
        for hd in handles {
            hd.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = clients * per_client;
        let m = router.metrics("synth")?;
        println!("\n{nrep} replica shard(s):");
        println!(
            "  throughput      {:.1} req/s ({total} requests in {wall:.2}s)",
            total as f64 / wall
        );
        for s in &m.shards {
            println!(
                "  shard {}        {} reqs, {} batches (full: {}), mean {:.1} ms, p99 {:.1} ms, \
                 peak queue {}",
                s.shard,
                s.batcher.requests,
                s.batcher.batches,
                s.batcher.full_batches,
                s.mean_latency_us / 1000.0,
                s.p99_latency_us as f64 / 1000.0,
                s.batcher.peak_queue_depth,
            );
        }
        println!(
            "  aggregate       {} reqs, {} exec errors, {} shed, {} rejected",
            m.total.requests, m.total.exec_errors, m.total.shed, m.total.rejected
        );
    }
    Ok(())
}
