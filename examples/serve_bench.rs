//! Serving benchmark + the repo's continuous-perf entry point.
//!
//! ```bash
//! cargo run --release --example serve_bench [artifacts-dir] [clients] [requests-per-client]
//! cargo run --release --example serve_bench -- --http [clients] [requests-per-client]
//! cargo run --release --example serve_bench -- --http-smoke [--poll-backend]
//! cargo run --release --example serve_bench -- --reload-smoke [--poll-backend]
//! cargo run --release --example serve_bench -- --degrade-smoke [--poll-backend]
//! cargo run --release --example serve_bench -- --autosearch-smoke [--poll-backend]
//! cargo run --release --example serve_bench -- --bench-json BENCH_sparq.json [--tiny]
//! cargo run --release --example serve_bench -- --validate-report BENCH_sparq.json
//! cargo run --release --example serve_bench -- --check-budgets \
//!     [--report BENCH_sparq.json] [--baseline BENCH_BASELINE.json]
//! ```
//!
//! With exported artifacts + a real PJRT backend the default mode
//! drives the single-model `InferenceServer` over the compiled HLO.
//! Without them (this image's default) it falls back to the **native
//! sharded router**: a synthetic model served by N replica shards that
//! share one `Arc<ModelParams>` parameter copy, printing per-shard and
//! aggregate metrics — queue depth, shed/rejected counts included.
//!
//! `--http` serves the native demo router — three policy variants
//! (`5opt_r` default, `a8w8`, `first8`) sharing one weights allocation
//! — through the HTTP/1.1 front door on an ephemeral loopback port and
//! benchmarks it with keep-alive `std::net::TcpStream` clients;
//! `--http-smoke` drives the same stack end-to-end and exits non-zero
//! on any mismatch (the CI smoke job). `--reload-smoke` exercises the
//! deployment lifecycle on that stack: a perturbed-weights canary that
//! auto-promotes (served logits switch generations), then a provably
//! disagreeing policy canary that auto-rolls-back — zero 5xx allowed.
//! `--degrade-smoke` exercises load-adaptive precision serving: a slow
//! "full" rung over an instant "cheap" rung behind an SLO ladder,
//! hammered past its queue-depth trigger — the overload must degrade
//! to the cheap rung (zero non-2xx) and the default must resume once
//! the load clears. `--autosearch-smoke` exercises calibration-driven
//! policy auto-search (`sparq::search`): a tiny ranked sweep on the
//! 3-conv demo model whose emitted policy must validate, hold its
//! agreement floor under independent re-measurement, and strictly beat
//! uniform A4W4; then the same search dispatched asynchronously through
//! `POST /v1/models/{name}/autosearch` with `install: true`, asserting
//! progress on `/v1/metrics` and search provenance on the installed
//! variant. `--poll-backend` forces minipoll's portable `poll(2)`
//! event-loop backend for any of them.
//!
//! `--bench-json <path>` runs the machine-readable perf suite — kernel
//! (naive / blocked 1-thread / blocked parallel), engine forward,
//! per-layer policy variants, sharded router, HTTP edge — and writes a
//! schema-validated `sparq-bench/1` report (`sparq::observability`).
//! `--tiny` shrinks every shape for CI smoke runs. `--check-budgets`
//! compares a report against `BENCH_BASELINE.json` and
//! `--validate-report` checks schema only.
//!
//! Exit codes are distinct so CI can tell failure classes apart:
//! `0` success, `1` benchmark/infrastructure failure, `2` budget
//! regression, `3` schema-invalid report.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};
use sparq::coordinator::{
    calibrate, evaluate_policy_vs_reference, BatchPolicy, HttpConfig, HttpServer, InferenceRouter,
    InferenceServer, LatencyHist, ReferenceTop1,
};
use sparq::data::Dataset;
use sparq::json::JsonValue;
use sparq::json_obj;
use sparq::model::demo::{synth_dataset, synth_model};
use sparq::model::{threadpool, Engine, EngineMode, Graph, ModelParams, QuantGemm, Scratch};
use sparq::observability::{
    check, http_get_json, http_post_json, time_iters, BenchReport, BenchSection, BudgetFile,
    QueueStats, Timing, SCHEMA_VERSION,
};
use sparq::quant::footprint::{policy_bits_per_activation, report_bits};
use sparq::quant::{QuantPolicy, SparqConfig};
use sparq::runtime::{Manifest, PjrtRuntime};
use sparq::search::{run as search_run, SearchConfig, AGREE_EPS};

/// Everything worked.
const EXIT_OK: i32 = 0;
/// The benchmark (or its serving infrastructure) itself failed.
const EXIT_BENCH_FAILED: i32 = 1;
/// The run completed but breached the perf budget baseline.
const EXIT_BUDGET_REGRESSION: i32 = 2;
/// A report file failed `sparq-bench/1` schema validation.
const EXIT_INVALID_REPORT: i32 = 3;

struct Cli {
    http: bool,
    smoke: bool,
    reload_smoke: bool,
    degrade_smoke: bool,
    autosearch_smoke: bool,
    poll_backend: bool,
    tiny: bool,
    check_budgets: bool,
    bench_json: Option<PathBuf>,
    validate_report: Option<PathBuf>,
    report: PathBuf,
    baseline: PathBuf,
    positional: Vec<String>,
}

fn parse_cli() -> Result<Cli> {
    fn path_after(args: &[String], i: &mut usize, flag: &str) -> Result<PathBuf> {
        *i += 1;
        args.get(*i)
            .map(PathBuf::from)
            .with_context(|| format!("`{flag}` needs a path argument"))
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cli = Cli {
        http: false,
        smoke: false,
        reload_smoke: false,
        degrade_smoke: false,
        autosearch_smoke: false,
        poll_backend: false,
        tiny: false,
        check_budgets: false,
        bench_json: None,
        validate_report: None,
        report: PathBuf::from("BENCH_sparq.json"),
        baseline: PathBuf::from("BENCH_BASELINE.json"),
        positional: Vec::new(),
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--http" => cli.http = true,
            "--http-smoke" => cli.smoke = true,
            "--reload-smoke" => cli.reload_smoke = true,
            "--degrade-smoke" => cli.degrade_smoke = true,
            "--autosearch-smoke" => cli.autosearch_smoke = true,
            "--poll-backend" => cli.poll_backend = true,
            "--tiny" => cli.tiny = true,
            "--check-budgets" => cli.check_budgets = true,
            "--bench-json" => cli.bench_json = Some(path_after(&args, &mut i, "--bench-json")?),
            "--validate-report" => {
                cli.validate_report = Some(path_after(&args, &mut i, "--validate-report")?)
            }
            "--report" => cli.report = path_after(&args, &mut i, "--report")?,
            "--baseline" => cli.baseline = path_after(&args, &mut i, "--baseline")?,
            flag if flag.starts_with("--") => anyhow::bail!("unknown flag `{flag}`"),
            other => cli.positional.push(other.to_string()),
        }
        i += 1;
    }
    Ok(cli)
}

fn main() {
    std::process::exit(run());
}

fn run() -> i32 {
    let cli = match parse_cli() {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            return EXIT_BENCH_FAILED;
        }
    };
    // Artifact-level commands first: they exit on their own codes and
    // never start a server.
    if let Some(path) = &cli.validate_report {
        return validate_report(path);
    }
    if cli.check_budgets {
        return check_budgets(&cli.report, &cli.baseline);
    }
    let res = if let Some(path) = &cli.bench_json {
        bench_json(path, cli.tiny, cli.poll_backend)
    } else if cli.reload_smoke {
        reload_smoke(cli.poll_backend)
    } else if cli.degrade_smoke {
        degrade_smoke(cli.poll_backend)
    } else if cli.autosearch_smoke {
        autosearch_smoke(cli.poll_backend)
    } else if cli.smoke {
        http_smoke(cli.poll_backend)
    } else if cli.http {
        let parsed = || -> Result<(usize, usize)> {
            let clients = cli.positional.first().map(|s| s.parse()).transpose()?.unwrap_or(16);
            let per = cli.positional.get(1).map(|s| s.parse()).transpose()?.unwrap_or(32);
            Ok((clients, per))
        };
        parsed().and_then(|(clients, per)| http_bench(clients, per, cli.poll_backend))
    } else {
        default_mode(&cli.positional)
    };
    match res {
        Ok(()) => EXIT_OK,
        Err(e) => {
            eprintln!("benchmark failed: {e:#}");
            EXIT_BENCH_FAILED
        }
    }
}

/// The original default: PJRT serving over exported artifacts when
/// available, the native sharded-router benchmark otherwise.
fn default_mode(positional: &[String]) -> Result<()> {
    let dir = PathBuf::from(positional.first().map(String::as_str).unwrap_or("artifacts"));
    let clients: usize = positional.get(1).map(|s| s.parse()).transpose()?.unwrap_or(16);
    let per_client: usize = positional.get(2).map(|s| s.parse()).transpose()?.unwrap_or(32);

    // Probe *availability* only (backend + manifest). A failure here
    // means the PJRT path can't run at all and the native router demo
    // is the right fallback; a failure later — mid-serving, on an
    // artifacts dir that does exist — is a real error and must
    // propagate, not be silently downgraded to the synthetic bench.
    let probe = || -> Result<(Arc<PjrtRuntime>, Manifest)> {
        Ok((Arc::new(PjrtRuntime::cpu()?), Manifest::load(&dir)?))
    };
    match probe() {
        Ok((rt, manifest)) => pjrt_serving(rt, &manifest, &dir, clients, per_client),
        Err(e) => {
            eprintln!(
                "PJRT serving path unavailable ({e}); \
                 running the native sharded-router benchmark instead\n"
            );
            native_router_bench(clients, per_client)
        }
    }
}

/// `--validate-report`: schema check only; exit 0 or 3.
fn validate_report(path: &Path) -> i32 {
    match BenchReport::load(path) {
        Ok(r) => {
            println!(
                "valid {SCHEMA_VERSION} report: {} section(s), host {} core(s), sha {}",
                r.sections.len(),
                r.host.cores,
                r.host.git_sha
            );
            EXIT_OK
        }
        Err(e) => {
            eprintln!("invalid bench report: {e:#}");
            EXIT_INVALID_REPORT
        }
    }
}

/// `--check-budgets`: gate a report on the committed baseline. An
/// unreadable/invalid report is a schema failure (exit 3), a broken
/// baseline file is an infrastructure failure (exit 1), and any budget
/// breach is the regression exit (2) — CI tells these apart.
fn check_budgets(report_path: &Path, baseline_path: &Path) -> i32 {
    let report = match BenchReport::load(report_path) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("invalid bench report: {e:#}");
            return EXIT_INVALID_REPORT;
        }
    };
    let budgets = match BudgetFile::load(baseline_path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("cannot load budget baseline: {e:#}");
            return EXIT_BENCH_FAILED;
        }
    };
    let violations = check(&report, &budgets);
    if violations.is_empty() {
        println!(
            "budgets OK: {} section(s) of {} within {}'s tolerances",
            report.sections.len(),
            report_path.display(),
            baseline_path.display()
        );
        return EXIT_OK;
    }
    eprintln!("budget regression: {} violation(s)", violations.len());
    for v in &violations {
        eprintln!("  {v}");
    }
    EXIT_BUDGET_REGRESSION
}

/// Deterministic activation operands with ~`sparsity_pct`% zeros (the
/// regime SPARQ exploits) — same generator the benches use.
fn synth_acts(n: usize, sparsity_pct: u64) -> Vec<u8> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33;
            if h % 100 < sparsity_pct {
                0
            } else {
                (h % 256) as u8
            }
        })
        .collect()
}

fn push_kernel(report: &mut BenchReport, name: &str, t: &Timing, macs: f64, bits: f64) {
    let gmac = t.throughput(macs) / 1e9;
    println!(
        "  {name:<18} {gmac:>9.2} GMAC/s   p50 {:>9.1} us   p99 {:>9.1} us",
        t.p50_us, t.p99_us
    );
    report.push(BenchSection {
        gmac_per_s: gmac,
        p50_us: t.p50_us,
        p99_us: t.p99_us,
        bits_per_act: bits,
        ..BenchSection::new(name)
    });
}

/// `--bench-json`: the continuous-perf suite. Every section lands in
/// one `sparq-bench/1` report that is self-validated before it is
/// written, so the emitter can never produce a file `--check-budgets`
/// would then reject.
fn bench_json(path: &Path, tiny: bool, poll_backend: bool) -> Result<()> {
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let nt = threadpool::max_threads();
    let max_replicas = nt.max(2);
    let mut report = BenchReport::new();
    println!(
        "{SCHEMA_VERSION} suite -> {} ({} shapes, {nt} thread(s), sha {})",
        path.display(),
        if tiny { "tiny" } else { "full" },
        report.host.git_sha
    );

    // --- kernel sections: the quantized GEMM, seed vs blocked ---
    let (m, k, n) = if tiny { (64, 576, 32) } else { (400, 1152, 64) };
    let (warm, iters) = if tiny { (2, 8) } else { (3, 20) };
    let a = synth_acts(m * k, 40);
    let w = sparq::model::demo::synth_weights(k * n);
    let gemm = QuantGemm::new(cfg);
    let wt = gemm.prepare_weights(&w, k, n);
    let mut rows = a.clone();
    let mut out = vec![0i32; m * n];
    let mut pack = Vec::new();
    let macs = (m * k * n) as f64;
    let bits = report_bits(cfg);

    let t = time_iters(warm, iters, || {
        rows.copy_from_slice(&a);
        gemm.gemm_naive(&mut rows, m, k, &wt, n, &mut out);
        std::hint::black_box(&out);
    });
    let reference = out.clone();
    push_kernel(&mut report, "kernel_naive", &t, macs, bits);

    let t = time_iters(warm, iters, || {
        rows.copy_from_slice(&a);
        gemm.gemm_with(&mut rows, m, k, &wt, n, &mut out, &mut pack, 1);
        std::hint::black_box(&out);
    });
    anyhow::ensure!(out == reference, "blocked serial GEMM diverged from naive");
    push_kernel(&mut report, "kernel_blocked_1t", &t, macs, bits);

    let t = time_iters(warm, iters, || {
        rows.copy_from_slice(&a);
        gemm.gemm_with(&mut rows, m, k, &wt, n, &mut out, &mut pack, nt);
        std::hint::black_box(&out);
    });
    anyhow::ensure!(out == reference, "blocked parallel GEMM diverged from naive");
    push_kernel(&mut report, "kernel_blocked_mt", &t, macs, bits);

    // --- engine sections: end-to-end native forward, 1 vs N threads ---
    let (graph, wts, scales) = synth_model();
    let [h, wd, c] = graph.input_hwc;
    let batch = if tiny { 8 } else { 32 };
    let e_iters = if tiny { 8 } else { 15 };
    let img: Vec<f32> = (0..batch * h * wd * c)
        .map(|i| ((i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33) as f32 % 251.0 / 251.0)
        .collect();
    let mut engine = Engine::new(&graph, &wts, cfg, &scales, EngineMode::Dense)?;
    let mut scratch = Scratch::default();
    for (name, threads) in [("engine_fwd_1t", 1), ("engine_fwd_mt", nt)] {
        engine.set_threads(threads);
        let t = time_iters(2, e_iters, || {
            std::hint::black_box(engine.forward_scratch(&img, batch, &mut scratch).unwrap());
        });
        let img_s = t.throughput(batch as f64);
        println!(
            "  {name:<18} {img_s:>9.1} img/s    p50 {:>9.1} us   p99 {:>9.1} us",
            t.p50_us, t.p99_us
        );
        report.push(BenchSection {
            img_per_s: img_s,
            p50_us: t.p50_us,
            p99_us: t.p99_us,
            bits_per_act: bits,
            ..BenchSection::new(name)
        });
    }

    // --- policy sections: per-layer quantization variants, with the
    // §5.1 footprint each one pays per activation ---
    for (name, pname) in
        [("policy_a8w8", "a8w8"), ("policy_a4w8", "a4w8"), ("policy_edge8", "edge8")]
    {
        let policy = QuantPolicy::named(pname).expect("registry preset");
        let mut e = Engine::with_policy(&graph, &wts, policy, &scales, EngineMode::Dense)?;
        e.set_threads(nt);
        let pbits = e.params().footprint_bits(1);
        let mut sc = Scratch::default();
        let t = time_iters(2, e_iters, || {
            std::hint::black_box(e.forward_scratch(&img, batch, &mut sc).unwrap());
        });
        let img_s = t.throughput(batch as f64);
        println!("  {name:<18} {img_s:>9.1} img/s    {pbits:.2} bits/act");
        report.push(BenchSection {
            img_per_s: img_s,
            p50_us: t.p50_us,
            p99_us: t.p99_us,
            bits_per_act: pbits,
            ..BenchSection::new(name)
        });
    }

    // --- search section: calibration-driven auto-search on the demo
    // model, budget-bounded so the section tracks sweep throughput
    // (calibration rows evaluated per second across all evals), not
    // full-search wall time. bits_per_act is the chosen policy's
    // footprint — a quality trajectory next to the speed one. ---
    {
        let sgraph = Arc::new(graph.clone());
        let swts = Arc::new(wts.clone());
        let srows = if tiny { 32 } else { 128 };
        let ds = synth_dataset(&sgraph, &swts, &scales, srows);
        let scfg = SearchConfig {
            eval_budget: if tiny { 4 } else { 12 },
            ladder: None,
            ..SearchConfig::default()
        };
        let outcome = search_run(&sgraph, &swts, &ds, &scales, &scfg)?;
        let evals = outcome.report.evals.total();
        let secs = outcome.report.seconds;
        let img_s = if secs > 0.0 { (evals * srows) as f64 / secs } else { 0.0 };
        println!(
            "  {:<18} {img_s:>9.1} rows/s   {evals} eval(s) -> {} @ {:.2} bits/act",
            "search_sweep", outcome.policy, outcome.footprint_bits
        );
        report.push(BenchSection {
            img_per_s: img_s,
            bits_per_act: outcome.footprint_bits,
            ..BenchSection::new("search_sweep")
        });
    }

    // --- router sections: 1 vs N single-thread replica shards over one
    // shared Arc'd parameter copy; latency from the shards' own merged
    // histograms, queue health from the aggregate snapshot ---
    let params = Arc::new(ModelParams::new(
        Arc::new(graph.clone()),
        Arc::new(wts.clone()),
        cfg,
        &scales,
        EngineMode::Dense,
    )?);
    let single = img[..h * wd * c].to_vec();
    let (clients, per) = if tiny {
        (4, 12)
    } else {
        (max_replicas * 2, 48)
    };
    for (name, nrep) in [("router_1shard", 1), ("router_mshard", max_replicas)] {
        let router = Arc::new(
            InferenceRouter::builder()
                .model_with_threads(
                    "synth",
                    params.clone(),
                    nrep,
                    BatchPolicy {
                        max_batch: 8,
                        max_wait: Duration::from_micros(500),
                        ..BatchPolicy::default()
                    },
                    1,
                )
                .build()?,
        );
        let _ = router.infer("synth", single.clone())?; // warmup
        let t0 = Instant::now();
        let mut client_err = None;
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..clients)
                .map(|_| {
                    let r = router.clone();
                    let im = single.clone();
                    s.spawn(move || -> Result<()> {
                        for _ in 0..per {
                            r.infer("synth", im.clone())?;
                        }
                        Ok(())
                    })
                })
                .collect();
            for hd in handles {
                if let Err(e) = hd.join().expect("router client thread panicked") {
                    client_err = Some(e);
                }
            }
        });
        if let Some(e) = client_err {
            return Err(e.context(format!("{name} client failed")));
        }
        let wall = t0.elapsed().as_secs_f64();
        let metrics = router.metrics("synth")?;
        let mut hist = LatencyHist::default();
        for shard in &metrics.shards {
            hist.merge(&shard.hist);
        }
        let img_s = (clients * per) as f64 / wall;
        println!(
            "  {name:<18} {img_s:>9.1} img/s    p50 {:>9} us   p99 {:>9} us   peak queue {}",
            hist.quantile_us(0.50),
            hist.quantile_us(0.99),
            metrics.total.peak_queue_depth
        );
        report.push(BenchSection {
            img_per_s: img_s,
            p50_us: hist.quantile_us(0.50) as f64,
            p99_us: hist.quantile_us(0.99) as f64,
            queue: QueueStats::from_snapshot(&metrics.total),
            bits_per_act: bits,
            ..BenchSection::new(name)
        });
    }

    // --- HTTP edge: the same stack behind the front door; latency is
    // measured client-side (it includes the network edge) ---
    {
        let (server, router, _engine, image_len) = demo_http_stack(max_replicas, poll_backend)?;
        let addr = server.addr();
        let image = http_image(image_len);
        let body = json_obj! {
            "image" => image.iter().map(|&v| f64::from(v)).collect::<Vec<f64>>()
        }
        .to_string();
        let raw = Arc::new(infer_request("synth", &body));
        let (hclients, hper) = if tiny { (2, 8) } else { (max_replicas * 2, 32) };
        let (status, resp) = MiniClient::connect(addr)?.request(&raw)?;
        anyhow::ensure!(status == 200, "http warmup request failed: {status} {resp}");
        let t0 = Instant::now();
        let handles: Vec<_> = (0..hclients)
            .map(|_| {
                let raw = raw.clone();
                std::thread::spawn(move || -> Result<LatencyHist> {
                    let mut client = MiniClient::connect(addr)?;
                    let mut hist = LatencyHist::default();
                    for _ in 0..hper {
                        let q0 = Instant::now();
                        let (status, resp) = client.request(&raw)?;
                        anyhow::ensure!(status == 200, "request failed: {status} {resp}");
                        hist.record(q0.elapsed());
                    }
                    Ok(hist)
                })
            })
            .collect();
        let mut hist = LatencyHist::default();
        for hd in handles {
            let client_hist = hd.join().expect("http client thread panicked")?;
            hist.merge(&client_hist);
        }
        let wall = t0.elapsed().as_secs_f64();
        let metrics = router.metrics("synth")?;
        let img_s = (hclients * hper) as f64 / wall;
        println!(
            "  {:<18} {img_s:>9.1} req/s    p50 {:>9} us   p99 {:>9} us{}",
            "http_edge",
            hist.quantile_us(0.50),
            hist.quantile_us(0.99),
            if poll_backend {
                "   (poll backend)"
            } else {
                ""
            }
        );
        report.push(BenchSection {
            img_per_s: img_s,
            p50_us: hist.quantile_us(0.50) as f64,
            p99_us: hist.quantile_us(0.99) as f64,
            queue: QueueStats::from_snapshot(&metrics.total),
            bits_per_act: bits,
            ..BenchSection::new("http_edge")
        });
    }

    // Self-validate before writing: an emitter that drifts from its own
    // schema must fail here, not later in --check-budgets.
    BenchReport::parse(&report.to_json().to_string())
        .context("emitter produced a schema-invalid report (bug)")?;
    report.save(path)?;
    println!("wrote {} section(s) to {}", report.sections.len(), path.display());
    Ok(())
}

/// The original artifact-backed path: one PJRT-executed model behind
/// the dynamic batcher.
fn pjrt_serving(
    rt: Arc<PjrtRuntime>,
    manifest: &Manifest,
    dir: &Path,
    clients: usize,
    per_client: usize,
) -> Result<()> {
    let model = manifest.get("resnet10")?;
    let graph = Graph::load(&model.meta_path())?;
    let eval = Arc::new(Dataset::load(&dir.join("test.bin"))?);
    let calib_ds = Dataset::load(&dir.join("train.bin"))?;
    let scales = calibrate(&rt, model, &calib_ds, 64, 512)?.scales();

    let server = Arc::new(InferenceServer::start(
        rt,
        model,
        graph.input_hwc,
        graph.num_classes,
        scales,
        SparqConfig::named("5opt_r").unwrap(),
        BatchPolicy {
            max_batch: graph.eval_batch,
            max_wait: Duration::from_millis(4),
            ..BatchPolicy::default()
        },
    )?);

    println!(
        "serving resnet10 (SPARQ 5opt+R) to {clients} clients x {per_client} requests, \
         batch up to {} ...",
        graph.eval_batch
    );
    // warmup: first request triggers nothing extra (exe precompiled), but
    // prime the pipeline anyway
    let _ = server.infer(eval.image_f32(0))?;

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let s = server.clone();
            let d = eval.clone();
            std::thread::spawn(move || -> (usize, usize) {
                let mut correct = 0;
                for r in 0..per_client {
                    let idx = (c * per_client + r) % d.n;
                    let reply = s.infer(d.image_f32(idx)).unwrap();
                    let pred = reply
                        .logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if pred == d.label(idx) {
                        correct += 1;
                    }
                }
                (correct, per_client)
            })
        })
        .collect();
    let mut correct = 0;
    let mut total = 0;
    for h in handles {
        let (c, t) = h.join().unwrap();
        correct += c;
        total += t;
    }
    let wall = t0.elapsed().as_secs_f64();

    let metrics = server.metrics();
    let m = metrics.lock().unwrap();
    let b = m.batcher.snapshot();
    println!("\nresults:");
    let pct = 100.0 * correct as f64 / total as f64;
    println!("  requests        {total}  ({correct} correct = {pct:.2}%)");
    println!("  wall time       {wall:.2}s");
    println!("  throughput      {:.1} req/s", total as f64 / wall);
    println!("  latency mean    {:.1} ms", m.e2e.mean_us() / 1000.0);
    println!("  latency p50     {:.1} ms", m.e2e.quantile_us(0.50) as f64 / 1000.0);
    println!("  latency p99     {:.1} ms", m.e2e.quantile_us(0.99) as f64 / 1000.0);
    println!("  latency max     {:.1} ms", m.e2e.max_us() as f64 / 1000.0);
    println!("  queue mean      {:.1} ms", m.queue.mean_us() / 1000.0);
    println!(
        "  batches         {}  (full: {}, exec errors: {})",
        b.batches, b.full_batches, b.exec_errors
    );
    println!(
        "  peak queue      {}  (shed: {}, rejected: {})",
        b.peak_queue_depth, b.shed, b.rejected
    );
    Ok(())
}

/// Artifact-free path: a synthetic model served by the sharded router,
/// 1 replica vs all-cores replicas, parameters Arc-shared throughout.
fn native_router_bench(clients: usize, per_client: usize) -> Result<()> {
    let (graph, weights, scales) = synth_model();
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let params = Arc::new(ModelParams::new(
        Arc::new(graph),
        Arc::new(weights),
        cfg,
        &scales,
        EngineMode::Dense,
    )?);
    let [h, w, c] = params.graph.input_hwc;
    let image: Vec<f32> = (0..h * w * c)
        .map(|i| ((i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33) as f32 % 251.0 / 251.0)
        .collect();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let replicas = cores.max(2);
    println!(
        "native router: synthetic model (SPARQ 5opt+R), {} parameter bytes shared by \
         every replica; {clients} clients x {per_client} requests",
        params.weights.param_bytes()
    );

    for nrep in [1usize, replicas] {
        let router = Arc::new(
            InferenceRouter::builder()
                .model_with_threads(
                    "synth",
                    params.clone(),
                    nrep,
                    BatchPolicy {
                        max_batch: 8,
                        max_wait: Duration::from_micros(500),
                        ..BatchPolicy::default()
                    },
                    1,
                )
                .build()?,
        );
        let _ = router.infer("synth", image.clone())?; // warmup
        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let r = router.clone();
                let im = image.clone();
                std::thread::spawn(move || {
                    for _ in 0..per_client {
                        r.infer("synth", im.clone()).unwrap();
                    }
                })
            })
            .collect();
        for hd in handles {
            hd.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = clients * per_client;
        let m = router.metrics("synth")?;
        println!("\n{nrep} replica shard(s):");
        println!(
            "  throughput      {:.1} req/s ({total} requests in {wall:.2}s)",
            total as f64 / wall
        );
        for s in &m.shards {
            println!(
                "  shard {}        {} reqs, {} batches (full: {}), mean {:.1} ms, p99 {:.1} ms, \
                 peak queue {}",
                s.shard,
                s.batcher.requests,
                s.batcher.batches,
                s.batcher.full_batches,
                s.mean_latency_us / 1000.0,
                s.p99_latency_us as f64 / 1000.0,
                s.batcher.peak_queue_depth,
            );
        }
        println!(
            "  aggregate       {} reqs, {} exec errors, {} shed, {} rejected",
            m.total.requests, m.total.exec_errors, m.total.shed, m.total.rejected
        );
    }
    Ok(())
}

/// Minimal blocking HTTP/1.1 client over one keep-alive connection —
/// `std::net::TcpStream` only, no curl in the image.
struct MiniClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl MiniClient {
    fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting to the http front door")?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        stream.set_nodelay(true)?;
        Ok(Self { stream, buf: Vec::new() })
    }

    /// Send one raw request and read one response: (status, body).
    fn request(&mut self, raw: &[u8]) -> Result<(u16, String)> {
        self.stream.write_all(raw)?;
        let find = |buf: &[u8]| buf.windows(4).position(|w| w == b"\r\n\r\n");
        let head_end = loop {
            if let Some(i) = find(&self.buf) {
                break i;
            }
            let mut chunk = [0u8; 4096];
            let n = self.stream.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "server closed the connection mid-response");
            self.buf.extend_from_slice(&chunk[..n]);
        };
        let head = std::str::from_utf8(&self.buf[..head_end])?.to_string();
        let status: u16 = head
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("unparseable status line `{head}`"))?;
        let mut content_length = 0usize;
        for line in head.split("\r\n").skip(1) {
            if let Some((name, value)) = line.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse()?;
                }
            }
        }
        let total = head_end + 4 + content_length;
        while self.buf.len() < total {
            let mut chunk = [0u8; 4096];
            let n = self.stream.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "server closed the connection mid-body");
            self.buf.extend_from_slice(&chunk[..n]);
        }
        let body = String::from_utf8(self.buf[head_end + 4..total].to_vec())?;
        self.buf.drain(..total);
        Ok((status, body))
    }
}

/// Demo router + front door on an ephemeral loopback port; returns the
/// server (keep it alive!), router, reference engine (for the default
/// `5opt_r` variant) and input width. `poll_backend` forces minipoll's
/// portable `poll(2)` event loop (the CI matrix's third leg).
///
/// Three policy variants share ONE graph+weights allocation:
/// `"5opt_r"` (default, the paper's headline config), `"a8w8"`
/// (uniform 8-bit reference) and `"first8"` (first quantized conv at 8
/// bits, rest uniform 4-bit) — the multi-operating-point serving shape
/// the policy API exists for.
fn demo_http_stack(
    replicas: usize,
    poll_backend: bool,
) -> Result<(HttpServer, Arc<InferenceRouter>, Engine, usize)> {
    let (graph, weights, scales) = synth_model();
    let (graph, weights) = (Arc::new(graph), Arc::new(weights));
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        ..BatchPolicy::default()
    };
    let mk = |p: QuantPolicy| -> Result<Arc<ModelParams>> {
        Ok(Arc::new(ModelParams::with_policy(
            graph.clone(),
            weights.clone(),
            p,
            &scales,
            EngineMode::Dense,
        )?))
    };
    let sparq = mk(QuantPolicy::uniform(SparqConfig::named("5opt_r").unwrap()))?;
    let a8w8 = mk(QuantPolicy::named("a8w8").expect("registry preset"))?;
    let first8 = mk(QuantPolicy::named("first8").expect("policy preset"))?;
    let engine = Engine::from_params(sparq.clone());
    let [h, w, c] = graph.input_hwc;
    let router = Arc::new(
        InferenceRouter::builder()
            .model_variant_with_threads("synth", "5opt_r", sparq, replicas, policy, 1)
            .model_variant_with_threads("synth", "a8w8", a8w8, 1, policy, 1)
            .model_variant_with_threads("synth", "first8", first8, 1, policy, 1)
            .build()?,
    );
    let config = HttpConfig { use_poll_fallback: poll_backend, ..HttpConfig::default() };
    let server = HttpServer::bind("127.0.0.1:0", router.clone(), config)?;
    Ok((server, router, engine, h * w * c))
}

/// Deterministic image whose values survive the f32 -> JSON -> f32
/// round trip bit-exactly (24-bit fractions).
fn http_image(image_len: usize) -> Vec<f32> {
    (0..image_len)
        .map(|j| {
            let h = (j as u64).wrapping_mul(0x9e3779b97f4a7c15);
            (h >> 40) as f32 / 16_777_216.0
        })
        .collect()
}

/// `target` is `synth` or `synth@{variant}`.
fn infer_request(target: &str, body: &str) -> Vec<u8> {
    format!(
        "POST /v1/infer/{target} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn logits_from(resp: &str) -> Result<Vec<f32>> {
    Ok(JsonValue::parse(resp)?
        .get("logits")
        .and_then(|l| l.as_array().map(|a| a.to_vec()))
        .context("no logits in response")?
        .iter()
        .map(|x| x.as_f64().unwrap_or(f64::NAN) as f32)
        .collect())
}

/// `--http`: benchmark the front door with keep-alive TCP clients.
fn http_bench(clients: usize, per_client: usize, poll_backend: bool) -> Result<()> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let replicas = cores.max(2);
    let (server, router, engine, image_len) = demo_http_stack(replicas, poll_backend)?;
    let addr = server.addr();
    let image = http_image(image_len);
    let want = engine.forward(&image, 1)?;
    let body = json_obj! {
        "image" => image.iter().map(|&v| f64::from(v)).collect::<Vec<f64>>()
    }
    .to_string();
    let raw = Arc::new(infer_request("synth", &body));
    println!(
        "http front door on {addr}: {replicas} replica shard(s), \
         {clients} keep-alive clients x {per_client} requests"
    );
    // Warmup + correctness gate before timing anything.
    let (status, resp) = MiniClient::connect(addr)?.request(&raw)?;
    anyhow::ensure!(status == 200, "warmup request failed: {status} {resp}");

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let raw = raw.clone();
            std::thread::spawn(move || -> Result<()> {
                let mut client = MiniClient::connect(addr)?;
                for _ in 0..per_client {
                    let (status, resp) = client.request(&raw)?;
                    anyhow::ensure!(status == 200, "request failed: {status} {resp}");
                }
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread panicked")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = clients * per_client;
    println!("\nresults:");
    println!(
        "  throughput      {:.1} req/s ({total} requests in {wall:.2}s, one event-loop thread)",
        total as f64 / wall
    );
    // Spot-check the served answer and print the served metrics.
    let (_, resp) = MiniClient::connect(addr)?.request(&raw)?;
    let logits = logits_from(&resp)?;
    anyhow::ensure!(logits == want, "HTTP logits diverge from direct Engine::forward");
    let m = router.metrics("synth")?;
    println!(
        "  aggregate       {} reqs, peak queue {}, {} shed, {} rejected, {} expired",
        m.total.requests, m.total.peak_queue_depth, m.total.shed, m.total.rejected,
        m.total.expired
    );
    let (status, metrics) =
        MiniClient::connect(addr)?.request(b"GET /v1/metrics HTTP/1.1\r\nHost: bench\r\n\r\n")?;
    anyhow::ensure!(status == 200, "metrics endpoint failed: {status}");
    println!("  GET /v1/metrics ({} bytes of JSON) OK", metrics.len());
    Ok(())
}

/// `--http-smoke`: end-to-end front-door check CI runs on every push —
/// one default-variant request bit-identical to `Engine::forward`,
/// `GET /v1/models` introspection naming every variant, and an infer
/// against a non-default variant whose logits differ from the uniform
/// A8W8 variant's. Non-zero exit on any mismatch. With
/// `--poll-backend` the same assertions run over minipoll's `poll(2)`
/// event loop instead of the platform-native one.
fn http_smoke(poll_backend: bool) -> Result<()> {
    let (server, _router, engine, image_len) = demo_http_stack(2, poll_backend)?;
    let addr = server.addr();
    let image = http_image(image_len);
    let body = json_obj! {
        "image" => image.iter().map(|&v| f64::from(v)).collect::<Vec<f64>>()
    }
    .to_string();
    let mut client = MiniClient::connect(addr)?;
    let (status, resp) = client.request(&infer_request("synth", &body))?;
    anyhow::ensure!(status == 200, "smoke request failed: {status} {resp}");
    let logits = logits_from(&resp).context("default-variant response")?;
    let want = engine.forward(&image, 1)?;
    anyhow::ensure!(
        logits == want,
        "HTTP logits diverge from direct Engine::forward: {logits:?} vs {want:?}"
    );
    // Policy introspection: /v1/models must name every variant and
    // report a parseable policy for each.
    let (status, models) =
        client.request(b"GET /v1/models HTTP/1.1\r\nHost: smoke\r\n\r\n")?;
    anyhow::ensure!(status == 200, "/v1/models failed: {status} {models}");
    let parsed = JsonValue::parse(&models).context("/v1/models body is not JSON")?;
    let synth = parsed
        .get("models")
        .and_then(|m| m.get("synth"))
        .context("/v1/models lists no `synth` model")?;
    anyhow::ensure!(
        synth.get("default_variant").and_then(|v| v.as_str()) == Some("5opt_r"),
        "wrong default variant in {models}"
    );
    for v in ["5opt_r", "a8w8", "first8"] {
        let var = synth
            .get("variants")
            .and_then(|vs| vs.get(v))
            .with_context(|| format!("/v1/models missing variant `{v}`"))?;
        anyhow::ensure!(
            var.get("policy").is_some() && var.get("layers").is_some(),
            "variant `{v}` lacks policy introspection: {models}"
        );
    }
    // Variant serving: the non-default `first8` variant must answer and
    // differ numerically from the uniform A8W8 variant.
    let (status, resp_a8) = client.request(&infer_request("synth@a8w8", &body))?;
    anyhow::ensure!(status == 200, "a8w8 variant failed: {status} {resp_a8}");
    let (status, resp_f8) = client.request(&infer_request("synth@first8", &body))?;
    anyhow::ensure!(status == 200, "first8 variant failed: {status} {resp_f8}");
    let (l_a8, l_f8) = (logits_from(&resp_a8)?, logits_from(&resp_f8)?);
    // Finite-ness first: logits_from maps non-numeric elements to NaN,
    // and NaN != NaN would make the distinctness check pass vacuously.
    anyhow::ensure!(
        l_a8.iter().all(|v| v.is_finite()) && l_f8.iter().all(|v| v.is_finite()),
        "variant responses contain non-finite logits: {resp_a8} / {resp_f8}"
    );
    anyhow::ensure!(
        l_a8 != l_f8,
        "first8 variant served logits identical to uniform A8W8 — variants are not \
         actually per-layer distinct"
    );
    // The live metrics view the ops dashboard polls: per-shard bucketed
    // histograms must be present for the default variant's shards.
    let (status, metrics) =
        client.request(b"GET /v1/metrics HTTP/1.1\r\nHost: smoke\r\n\r\n")?;
    anyhow::ensure!(status == 200, "/v1/metrics failed: {status} {metrics}");
    let mv = JsonValue::parse(&metrics).context("/v1/metrics body is not JSON")?;
    let shards = mv
        .get("models")
        .and_then(|m| m.get("synth"))
        .and_then(|m| m.get("shards"))
        .and_then(JsonValue::as_array)
        .map(<[JsonValue]>::to_vec)
        .context("/v1/metrics lacks per-shard entries for synth")?;
    anyhow::ensure!(!shards.is_empty(), "no shards reported in {metrics}");
    for s in &shards {
        anyhow::ensure!(
            s.get("hist").and_then(|hh| hh.get("buckets")).is_some()
                && s.get("p50_latency_us").is_some(),
            "shard entry lacks bucketed histogram: {metrics}"
        );
    }
    // Same keep-alive connection: healthz must answer too.
    let (status, health) = client.request(b"GET /healthz HTTP/1.1\r\nHost: smoke\r\n\r\n")?;
    anyhow::ensure!(status == 200 && health.contains("ok"), "healthz failed: {status} {health}");
    println!(
        "HTTP smoke OK ({}): 200 with {} logits bit-identical to Engine::forward; \
         /v1/models lists 3 variants; first8 != a8w8 logits; {} shard histogram(s); \
         healthz {health}",
        if poll_backend {
            "poll backend"
        } else {
            "native backend"
        },
        logits.len(),
        shards.len()
    );
    Ok(())
}

fn top1(logits: &[f32]) -> usize {
    // Mirrors the eval machinery's argmax (total_cmp, last max wins).
    logits.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map_or(0, |(i, _)| i)
}

/// `--reload-smoke`: the deployment-lifecycle CI leg. Boots the same
/// 3-variant demo stack as `--http-smoke`, then proves both canary
/// verdicts over the front door with zero 5xx responses:
///
/// 1. **promote** — `POST /v1/models/synth/reload` with deterministically
///    perturbed weights behind a 1-in-1 canary; drives traffic until the
///    canary auto-promotes, then asserts the served logits switched
///    generations (bit-different from generation 1 on every probe).
/// 2. **rollback** — stages a policy candidate that provably flips top-1
///    on a locally-verified probe image (restaging is deterministic, so
///    `restage_policy` over the live params is an exact oracle), drives
///    exactly that image, and asserts the canary auto-rolls-back with
///    the promoted generation still serving.
///
/// Every HTTP status is checked (200 for infers and polls, 202 for the
/// reload accepts), so any 5xx — or any torn/stale response — is a
/// non-zero exit for CI.
fn reload_smoke(poll_backend: bool) -> Result<()> {
    let (server, router, _engine, image_len) = demo_http_stack(2, poll_backend)?;
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(10);
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut client = MiniClient::connect(server.addr())?;

    let probe = |i: usize| -> Vec<f32> {
        (0..image_len)
            .map(|j| {
                let h = ((i * 131 + j) as u64).wrapping_mul(0x9e3779b97f4a7c15);
                (h >> 40) as f32 / 16_777_216.0
            })
            .collect()
    };
    let infer = |client: &mut MiniClient, image: &[f32]| -> Result<Vec<f32>> {
        let body = json_obj! {
            "image" => image.iter().map(|&v| f64::from(v)).collect::<Vec<f64>>()
        }
        .to_string();
        let (status, resp) = client.request(&infer_request("synth", &body))?;
        anyhow::ensure!(status == 200, "infer failed: {status} {resp}");
        logits_from(&resp)
    };
    let variant_json = |key: &str| -> Result<JsonValue> {
        let v = http_get_json(&addr, "/v1/models", timeout)?;
        Ok(v.get("models")
            .and_then(|m| m.get("synth"))
            .and_then(|s| s.get("variants"))
            .and_then(|vs| vs.get("5opt_r"))
            .and_then(|v| v.get(key))
            .cloned()
            .unwrap_or(JsonValue::Null))
    };
    let generation = |v: &JsonValue| v.as_usize().unwrap_or(0);

    let probes: Vec<Vec<f32>> = (0..8).map(probe).collect();
    let before: Vec<Vec<f32>> = probes
        .iter()
        .map(|im| infer(&mut client, im))
        .collect::<Result<_>>()
        .context("generation-1 probe traffic")?;

    // --- Leg 1: perturbed-weights canary → auto-promote. ------------ //
    let spec = json_obj! {
        "source" => "perturb",
        "seed" => 42usize,
        "amplitude" => 3usize,
        "canary_share" => 1usize,
        "promote_threshold" => 0.0,
        "min_requests" => 4usize,
    };
    let reply = http_post_json(&addr, "/v1/models/synth/reload", &spec, timeout)
        .context("perturb reload not accepted")?;
    anyhow::ensure!(
        reply.get("status").and_then(JsonValue::as_str) == Some("accepted")
            && reply.get("serving_generation").and_then(JsonValue::as_usize) == Some(1),
        "unexpected reload reply: {}",
        reply.to_string()
    );
    loop {
        anyhow::ensure!(
            Instant::now() < deadline,
            "canary never promoted: {}",
            variant_json("rollout")?.to_string()
        );
        for im in &probes {
            infer(&mut client, im)?;
        }
        if generation(&variant_json("generation")?) == 2
            && variant_json("state")?.as_str() == Some("serving")
        {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let after: Vec<Vec<f32>> = probes
        .iter()
        .map(|im| infer(&mut client, im))
        .collect::<Result<_>>()
        .context("generation-2 probe traffic")?;
    anyhow::ensure!(
        before.iter().zip(&after).all(|(b, a)| b != a),
        "perturbed reload served logits identical to generation 1 — weights did not switch"
    );
    let rollout = variant_json("rollout")?;
    let promote_agreement = rollout
        .get("last_outcome")
        .and_then(|o| o.get("agreement"))
        .and_then(JsonValue::as_f64)
        .context("promote outcome lacks measured agreement")?;

    // --- Leg 2: provably disagreeing policy canary → auto-rollback. - //
    let live = router
        .variant_params("synth", "5opt_r")?
        .context("5opt_r must be a versioned (params-built) variant")?;
    let live_engine = Engine::from_params(live.clone());
    let mut flip = None;
    'search: for name in ["a8w8", "a4w8", "first8"] {
        let policy = QuantPolicy::named(name).context("known policy preset")?;
        let candidate = Engine::from_params(Arc::new(live.restage_policy(policy)?));
        for i in 0..256 {
            let im = probe(i);
            if top1(&live_engine.forward(&im, 1)?) != top1(&candidate.forward(&im, 1)?) {
                flip = Some((name, im));
                break 'search;
            }
        }
    }
    let (candidate_policy, flip_image) =
        flip.context("no probe image flips top-1 under any candidate policy")?;
    let gen2_flip_logits = live_engine.forward(&flip_image, 1)?;
    let spec = json_obj! {
        "source" => "policy",
        "policy" => QuantPolicy::named(candidate_policy).context("known policy preset")?.to_json(),
        "canary_share" => 1usize,
        "promote_threshold" => 1.0,
        "min_requests" => 1usize,
    };
    let reply = http_post_json(&addr, "/v1/models/synth/reload", &spec, timeout)
        .context("policy reload not accepted")?;
    anyhow::ensure!(
        reply.get("serving_generation").and_then(JsonValue::as_usize) == Some(2),
        "rollback leg must start from generation 2: {}",
        reply.to_string()
    );
    loop {
        anyhow::ensure!(
            Instant::now() < deadline,
            "canary never rolled back: {}",
            variant_json("rollout")?.to_string()
        );
        infer(&mut client, &flip_image)?;
        let rollout = variant_json("rollout")?;
        let decided = rollout
            .get("last_outcome")
            .and_then(|o| o.get("generation"))
            .and_then(JsonValue::as_usize)
            == Some(3);
        if decided && variant_json("state")?.as_str() == Some("serving") {
            anyhow::ensure!(
                rollout
                    .get("last_outcome")
                    .and_then(|o| o.get("promoted"))
                    .and_then(JsonValue::as_bool)
                    == Some(false),
                "disagreeing canary was promoted: {}",
                rollout.to_string()
            );
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    anyhow::ensure!(
        generation(&variant_json("generation")?) == 2,
        "rollback must keep generation 2 serving"
    );
    // Post-rollback traffic serves generation-2 numerics again.
    let settled = infer(&mut client, &flip_image)?;
    anyhow::ensure!(
        settled == gen2_flip_logits,
        "post-rollback logits diverge from the promoted generation"
    );

    let served = variant_json("rollout")?
        .get("served_rows_by_generation")
        .map(JsonValue::to_string)
        .unwrap_or_default();
    println!(
        "reload smoke OK ({}): perturb canary promoted gen 2 (agreement {promote_agreement:.2}), \
         logits switched generations on all {} probes; `{candidate_policy}` canary rolled back \
         (gen 2 still serving); zero 5xx; served rows {served}",
        if poll_backend {
            "poll backend"
        } else {
            "native backend"
        },
        probes.len()
    );
    Ok(())
}

/// `--degrade-smoke`: the load-adaptive serving CI leg. Builds a
/// dedicated two-rung executor-backed model — a deliberately slow
/// "full" rung (~3 ms per request, one single-request shard) over an
/// instant "cheap" rung — installs a queue-depth SLO ladder through
/// `POST /v1/models/{model}/slo`, then hammers the front door with
/// concurrent keep-alive clients. The overload must *degrade*, not
/// shed: zero non-2xx across the whole run, at least one response
/// echoing the cheap rung, `/v1/metrics` reporting nonzero
/// time-in-degraded-mode and transition counters, and the default rung
/// resuming once the load stops and the dwell window expires.
fn degrade_smoke(poll_backend: bool) -> Result<()> {
    use sparq::coordinator::batcher::ExecuteFn;
    let slow: Box<ExecuteFn> = Box::new(|_buf: &[f32], bsz: usize| {
        std::thread::sleep(Duration::from_millis(3));
        Ok(vec![1.0; bsz])
    });
    let instant: Box<ExecuteFn> = Box::new(|_buf: &[f32], bsz: usize| Ok(vec![2.0; bsz]));
    let policy = BatchPolicy {
        max_batch: 1,
        max_wait: Duration::from_micros(50),
        ..BatchPolicy::default()
    };
    let router = Arc::new(
        InferenceRouter::builder()
            .model_variant_from_executors("ladder", "full", 1, 1, vec![slow], policy)
            .model_variant_from_executors("ladder", "cheap", 1, 1, vec![instant], policy)
            .build()?,
    );
    let config = HttpConfig { use_poll_fallback: poll_backend, ..HttpConfig::default() };
    let server = HttpServer::bind("127.0.0.1:0", router, config)?;
    let sock = server.addr();
    let addr = sock.to_string();
    let timeout = Duration::from_secs(10);
    let body = r#"{"image": [0.5]}"#;

    let spec = json_obj! {
        "ladder" => vec![JsonValue::from("full"), JsonValue::from("cheap")],
        "max_queue_depth" => 4usize,
        "dwell_us" => 200_000usize,
        "recover_margin" => 1.0,
    };
    let reply = http_post_json(&addr, "/v1/models/ladder/slo", &spec, timeout)
        .context("SLO policy not accepted over the front door")?;
    anyhow::ensure!(
        reply.get("status").and_then(JsonValue::as_str) == Some("installed"),
        "unexpected /slo reply: {}",
        reply.to_string()
    );

    // Concurrent load: the slow rung backs up past the depth trigger
    // within a few requests, so the bulk of the run must come back from
    // the cheap rung — and every single response must be a 2xx.
    let (clients, per) = (8usize, 30usize);
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            std::thread::spawn(move || -> Result<(usize, usize)> {
                let mut client = MiniClient::connect(sock)?;
                let (mut full, mut cheap) = (0usize, 0usize);
                for _ in 0..per {
                    let (status, resp) = client.request(&infer_request("ladder", body))?;
                    anyhow::ensure!(
                        status == 200,
                        "overload must degrade, not shed: got {status} {resp}"
                    );
                    match JsonValue::parse(&resp)?.get("variant").and_then(JsonValue::as_str) {
                        Some("full") => full += 1,
                        Some("cheap") => cheap += 1,
                        other => anyhow::bail!("unknown variant echo {other:?} in {resp}"),
                    }
                }
                Ok((full, cheap))
            })
        })
        .collect();
    let (mut full, mut cheap) = (0usize, 0usize);
    for h in handles {
        let (f, c) = h.join().expect("load client panicked")?;
        full += f;
        cheap += c;
    }
    anyhow::ensure!(
        cheap >= 1,
        "overload never reached the cheap rung (full {full}, cheap {cheap})"
    );
    let slo_of = |v: &JsonValue| -> JsonValue {
        v.get("models")
            .and_then(|m| m.get("ladder"))
            .and_then(|s| s.get("slo"))
            .cloned()
            .unwrap_or(JsonValue::Null)
    };
    let slo = slo_of(&http_get_json(&addr, "/v1/metrics", timeout)?);
    anyhow::ensure!(
        slo.get("transitions_down").and_then(JsonValue::as_usize).unwrap_or(0) >= 1
            && slo.get("time_degraded_us").and_then(JsonValue::as_usize).unwrap_or(0) > 0,
        "metrics never recorded a degraded period: {}",
        slo.to_string()
    );

    // Load is gone: the cheap rung's queue is empty, so once dwell
    // expires the ladder must step back to the default.
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut client = MiniClient::connect(sock)?;
    loop {
        anyhow::ensure!(Instant::now() < deadline, "ladder never recovered to the full rung");
        let (status, resp) = client.request(&infer_request("ladder", body))?;
        anyhow::ensure!(status == 200, "recovery traffic failed: {status} {resp}");
        if JsonValue::parse(&resp)?.get("variant").and_then(JsonValue::as_str) == Some("full") {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let slo = slo_of(&http_get_json(&addr, "/v1/metrics", timeout)?);
    let time_degraded = slo.get("time_degraded_us").and_then(JsonValue::as_usize).unwrap_or(0);
    let downs = slo.get("transitions_down").and_then(JsonValue::as_usize).unwrap_or(0);
    let ups = slo.get("transitions_up").and_then(JsonValue::as_usize).unwrap_or(0);
    anyhow::ensure!(
        slo.get("rung").and_then(JsonValue::as_usize) == Some(0)
            && slo.get("degraded").and_then(JsonValue::as_bool) == Some(false)
            && ups >= 1,
        "post-recovery SLO status is wrong: {}",
        slo.to_string()
    );
    println!(
        "degrade smoke OK ({}): {} requests, zero non-2xx, {cheap} served by the cheap rung \
         ({full} by full); {time_degraded} us degraded, {downs} down / {ups} up transition(s); \
         default rung resumed",
        if poll_backend {
            "poll backend"
        } else {
            "native backend"
        },
        clients * per
    );
    Ok(())
}

/// `--autosearch-smoke`: the policy auto-search CI leg, two halves.
///
/// **Library half** — a ranked `sparq::search::run` on the 3-conv demo
/// model with the agreement floor set to uniform A4W4's own measured
/// agreement. The emitted policy must (a) validate (`layer_plan` over
/// the live graph), (b) hold the floor when re-measured through the
/// independent `coordinator::eval` path, and (c) strictly beat uniform
/// A4W4: lower footprint at no-worse agreement, or higher agreement at
/// no-worse footprint.
///
/// **HTTP half** — the same subsystem dispatched asynchronously through
/// `POST /v1/models/synth/autosearch` with `install: true` on the live
/// demo stack: the accept is a 202, progress and the terminal outcome
/// surface on `/v1/metrics`, and the installed default variant's
/// `/v1/models` entry must carry `"provenance": {"origin": "search"}`
/// with the report sha the search announced — while the front door
/// keeps serving 200s.
fn autosearch_smoke(poll_backend: bool) -> Result<()> {
    let (graph, weights, scales) = synth_model();
    let graph = Arc::new(graph);
    let weights = Arc::new(weights);
    let ds = synth_dataset(&graph, &weights, &scales, 512);

    // Floor + comparison point: uniform A4W4, measured against the same
    // A8W8 reference predictions the search itself uses.
    let a8 = Engine::with_policy(
        &graph,
        &weights,
        QuantPolicy::uniform(SparqConfig::A8W8),
        &scales,
        EngineMode::Dense,
    )?;
    let reference = ReferenceTop1::from_engine(&a8, &ds, graph.eval_batch, ds.n)?;
    let run_vs_ref = |policy: QuantPolicy| -> Result<f64> {
        Ok(evaluate_policy_vs_reference(
            &graph,
            &weights,
            &ds,
            graph.eval_batch,
            &scales,
            policy,
            EngineMode::Dense,
            &reference,
        )?
        .accuracy())
    };
    let a4w4 = QuantPolicy::named("a4w4").expect("a4w4 is a registry preset");
    let a4_agreement = run_vs_ref(a4w4.clone())?;
    let vols = graph.quant_act_volumes()?;
    let fp_of = |p: &QuantPolicy| -> Result<f64> {
        Ok(policy_bits_per_activation(&p.layer_plan(&graph)?, &vols, 1))
    };
    let a4_fp = fp_of(&a4w4)?;

    let cfg = SearchConfig { agreement_floor: a4_agreement, ..SearchConfig::default() };
    let out = search_run(&graph, &weights, &ds, &scales, &cfg)?;

    // (a) the emitted policy validates against the live graph.
    let plan = out.policy.layer_plan(&graph)?;
    anyhow::ensure!(
        plan.len() == graph.quant_convs.len(),
        "plan covers {} of {} quantized convs",
        plan.len(),
        graph.quant_convs.len()
    );

    // (b) the floor holds under an independent re-measurement.
    let re = run_vs_ref(out.policy.clone())?;
    anyhow::ensure!(
        re >= cfg.agreement_floor - AGREE_EPS,
        "re-measured agreement {re:.4} fell below the floor {:.4}",
        cfg.agreement_floor
    );

    // (c) strictly beats uniform A4W4 on one axis at no loss on the
    // other: cheaper at no-worse agreement, or better-agreeing at
    // no-worse footprint.
    let searched_fp = fp_of(&out.policy)?;
    anyhow::ensure!(
        (searched_fp - out.footprint_bits).abs() < 1e-9,
        "report footprint {:.4} disagrees with recomputed {searched_fp:.4}",
        out.footprint_bits
    );
    let beats = (searched_fp < a4_fp - 1e-9 && re >= a4_agreement - AGREE_EPS)
        || (searched_fp <= a4_fp + 1e-9 && re > a4_agreement + AGREE_EPS);
    anyhow::ensure!(
        beats,
        "searched {} ({searched_fp:.3} bits/act, agreement {re:.4}) does not strictly beat \
         uniform A4W4 ({a4_fp:.3} bits/act, agreement {a4_agreement:.4})",
        out.policy
    );

    // --- HTTP half: async dispatch, metrics progress, provenance. ---
    let (server, _router, _engine, image_len) = demo_http_stack(2, poll_backend)?;
    let sock = server.addr();
    let addr = sock.to_string();
    let timeout = Duration::from_secs(10);
    let spec = json_obj! {
        "floor" => cfg.agreement_floor,
        "rows" => 64usize,
        "install" => true,
    };
    let accepted = http_post_json(&addr, "/v1/models/synth/autosearch", &spec, timeout)
        .context("autosearch not accepted over the front door")?;
    anyhow::ensure!(
        accepted.get("status").and_then(JsonValue::as_str) == Some("accepted")
            && accepted.get("install").and_then(JsonValue::as_bool) == Some(true),
        "unexpected /autosearch reply: {}",
        accepted.to_string()
    );
    let variant = accepted
        .get("variant")
        .and_then(JsonValue::as_str)
        .context("accept reply names no variant")?
        .to_string();

    // Poll /v1/metrics until the progress cell reaches a terminal
    // phase; the terminal snapshot carries the outcome (report sha).
    let deadline = Instant::now() + Duration::from_secs(120);
    let snapshot = loop {
        anyhow::ensure!(Instant::now() < deadline, "autosearch never reached a terminal phase");
        let metrics = http_get_json(&addr, "/v1/metrics", timeout)?;
        let cell = metrics
            .get("models")
            .and_then(|m| m.get("synth"))
            .and_then(|m| m.get("autosearch"))
            .cloned()
            .unwrap_or(JsonValue::Null);
        match cell.get("phase").and_then(JsonValue::as_str) {
            Some("done") => break cell,
            Some("failed") => anyhow::bail!("autosearch failed: {}", cell.to_string()),
            _ => std::thread::sleep(Duration::from_millis(50)),
        }
    };
    let announced_sha = snapshot
        .get("outcome")
        .and_then(|o| o.get("report_sha"))
        .and_then(JsonValue::as_str)
        .context("terminal autosearch snapshot carries no outcome.report_sha")?
        .to_string();

    // The worker installs after publishing Done, so poll briefly for
    // the provenance-tagged version to land on /v1/models.
    let deadline = Instant::now() + Duration::from_secs(30);
    let provenance = loop {
        anyhow::ensure!(Instant::now() < deadline, "searched policy was never installed");
        let models = http_get_json(&addr, "/v1/models", timeout)?;
        let p = models
            .get("models")
            .and_then(|m| m.get("synth"))
            .and_then(|m| m.get("variants"))
            .and_then(|v| v.get(&variant))
            .and_then(|v| v.get("provenance"))
            .cloned()
            .unwrap_or(JsonValue::Null);
        if p.get("origin").and_then(JsonValue::as_str) == Some("search") {
            break p;
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    anyhow::ensure!(
        provenance.get("report_sha").and_then(JsonValue::as_str) == Some(announced_sha.as_str()),
        "installed provenance {} does not carry the announced report sha {announced_sha}",
        provenance.to_string()
    );
    let installed_agreement = provenance
        .get("agreement")
        .and_then(JsonValue::as_f64)
        .context("search provenance carries no measured agreement")?;
    anyhow::ensure!(
        installed_agreement >= cfg.agreement_floor - AGREE_EPS,
        "installed agreement {installed_agreement:.4} below the requested floor"
    );

    // The front door still serves the searched generation.
    let body = json_obj! {
        "image" => http_image(image_len).iter().map(|&v| f64::from(v)).collect::<Vec<f64>>()
    }
    .to_string();
    let (status, resp) = MiniClient::connect(sock)?.request(&infer_request("synth", &body))?;
    anyhow::ensure!(status == 200, "post-install infer failed: {status} {resp}");

    println!(
        "autosearch smoke OK ({}): {} @ {searched_fp:.2} bits/act, agreement {re:.4} \
         (uniform A4W4: {a4_fp:.2} bits/act @ {a4_agreement:.4}); HTTP search installed \
         `{variant}` with provenance sha {announced_sha}",
        if poll_backend {
            "poll backend"
        } else {
            "native backend"
        },
        out.policy
    );
    Ok(())
}
