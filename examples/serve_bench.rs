//! Serving benchmark: the dynamically batched SPARQ inference service
//! under concurrent client load — latency/throughput for the paper's
//! "increase execution performance" motivation.
//!
//! ```bash
//! cargo run --release --example serve_bench [artifacts-dir] [clients] [requests-per-client]
//! cargo run --release --example serve_bench -- --http [clients] [requests-per-client]
//! cargo run --release --example serve_bench -- --http-smoke
//! ```
//!
//! With exported artifacts + a real PJRT backend the default mode
//! drives the single-model `InferenceServer` over the compiled HLO.
//! Without them (this image's default) it falls back to the **native
//! sharded router**: a synthetic model served by N replica shards that
//! share one `Arc<ModelParams>` parameter copy, printing per-shard and
//! aggregate metrics — queue depth, shed/rejected counts included.
//!
//! `--http` serves the native demo router — three policy variants
//! (`5opt_r` default, `a8w8`, `first8`) sharing one weights allocation
//! — through the HTTP/1.1 front door on an ephemeral loopback port and
//! benchmarks it with keep-alive `std::net::TcpStream` clients;
//! `--http-smoke` drives the same stack end-to-end: a default-variant
//! request bit-identical to `Engine::forward`, `GET /v1/models` policy
//! introspection, and a non-default-variant request whose logits must
//! differ from the uniform-A8W8 variant's. Exits non-zero on any
//! mismatch (the CI smoke job).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};
use sparq::coordinator::{
    calibrate, BatchPolicy, HttpConfig, HttpServer, InferenceRouter, InferenceServer,
};
use sparq::data::Dataset;
use sparq::json::JsonValue;
use sparq::json_obj;
use sparq::model::demo::synth_model;
use sparq::model::{Engine, EngineMode, Graph, ModelParams};
use sparq::quant::{QuantPolicy, SparqConfig};
use sparq::runtime::{Manifest, PjrtRuntime};

fn main() -> Result<()> {
    let mut http_mode = false;
    let mut smoke = false;
    let mut positional: Vec<String> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--http" => http_mode = true,
            "--http-smoke" => smoke = true,
            other => positional.push(other.to_string()),
        }
    }
    if smoke {
        return http_smoke();
    }
    if http_mode {
        let clients: usize = positional.first().map(|s| s.parse()).transpose()?.unwrap_or(16);
        let per_client: usize = positional.get(1).map(|s| s.parse()).transpose()?.unwrap_or(32);
        return http_bench(clients, per_client);
    }
    let dir = PathBuf::from(positional.first().map(String::as_str).unwrap_or("artifacts"));
    let clients: usize = positional.get(1).map(|s| s.parse()).transpose()?.unwrap_or(16);
    let per_client: usize = positional.get(2).map(|s| s.parse()).transpose()?.unwrap_or(32);

    // Probe *availability* only (backend + manifest). A failure here
    // means the PJRT path can't run at all and the native router demo
    // is the right fallback; a failure later — mid-serving, on an
    // artifacts dir that does exist — is a real error and must
    // propagate, not be silently downgraded to the synthetic bench.
    let probe = || -> Result<(Arc<PjrtRuntime>, Manifest)> {
        Ok((Arc::new(PjrtRuntime::cpu()?), Manifest::load(&dir)?))
    };
    match probe() {
        Ok((rt, manifest)) => pjrt_serving(rt, &manifest, &dir, clients, per_client),
        Err(e) => {
            eprintln!(
                "PJRT serving path unavailable ({e}); \
                 running the native sharded-router benchmark instead\n"
            );
            native_router_bench(clients, per_client)
        }
    }
}

/// The original artifact-backed path: one PJRT-executed model behind
/// the dynamic batcher.
fn pjrt_serving(
    rt: Arc<PjrtRuntime>,
    manifest: &Manifest,
    dir: &Path,
    clients: usize,
    per_client: usize,
) -> Result<()> {
    let model = manifest.get("resnet10")?;
    let graph = Graph::load(&model.meta_path())?;
    let eval = Arc::new(Dataset::load(&dir.join("test.bin"))?);
    let calib_ds = Dataset::load(&dir.join("train.bin"))?;
    let scales = calibrate(&rt, model, &calib_ds, 64, 512)?.scales();

    let server = Arc::new(InferenceServer::start(
        rt,
        model,
        graph.input_hwc,
        graph.num_classes,
        scales,
        SparqConfig::named("5opt_r").unwrap(),
        BatchPolicy {
            max_batch: graph.eval_batch,
            max_wait: Duration::from_millis(4),
            ..BatchPolicy::default()
        },
    )?);

    println!(
        "serving resnet10 (SPARQ 5opt+R) to {clients} clients x {per_client} requests, \
         batch up to {} ...",
        graph.eval_batch
    );
    // warmup: first request triggers nothing extra (exe precompiled), but
    // prime the pipeline anyway
    let _ = server.infer(eval.image_f32(0))?;

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let s = server.clone();
            let d = eval.clone();
            std::thread::spawn(move || -> (usize, usize) {
                let mut correct = 0;
                for r in 0..per_client {
                    let idx = (c * per_client + r) % d.n;
                    let reply = s.infer(d.image_f32(idx)).unwrap();
                    let pred = reply
                        .logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if pred == d.label(idx) {
                        correct += 1;
                    }
                }
                (correct, per_client)
            })
        })
        .collect();
    let mut correct = 0;
    let mut total = 0;
    for h in handles {
        let (c, t) = h.join().unwrap();
        correct += c;
        total += t;
    }
    let wall = t0.elapsed().as_secs_f64();

    let metrics = server.metrics();
    let m = metrics.lock().unwrap();
    let b = m.batcher.snapshot();
    println!("\nresults:");
    let pct = 100.0 * correct as f64 / total as f64;
    println!("  requests        {total}  ({correct} correct = {pct:.2}%)");
    println!("  wall time       {wall:.2}s");
    println!("  throughput      {:.1} req/s", total as f64 / wall);
    println!("  latency mean    {:.1} ms", m.e2e.mean_us() / 1000.0);
    println!("  latency p50     {:.1} ms", m.e2e.quantile_us(0.50) as f64 / 1000.0);
    println!("  latency p99     {:.1} ms", m.e2e.quantile_us(0.99) as f64 / 1000.0);
    println!("  latency max     {:.1} ms", m.e2e.max_us() as f64 / 1000.0);
    println!("  queue mean      {:.1} ms", m.queue.mean_us() / 1000.0);
    println!(
        "  batches         {}  (full: {}, exec errors: {})",
        b.batches, b.full_batches, b.exec_errors
    );
    println!(
        "  peak queue      {}  (shed: {}, rejected: {})",
        b.peak_queue_depth, b.shed, b.rejected
    );
    Ok(())
}

/// Artifact-free path: a synthetic model served by the sharded router,
/// 1 replica vs all-cores replicas, parameters Arc-shared throughout.
fn native_router_bench(clients: usize, per_client: usize) -> Result<()> {
    let (graph, weights, scales) = synth_model();
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let params = Arc::new(ModelParams::new(
        Arc::new(graph),
        Arc::new(weights),
        cfg,
        &scales,
        EngineMode::Dense,
    )?);
    let [h, w, c] = params.graph.input_hwc;
    let image: Vec<f32> = (0..h * w * c)
        .map(|i| ((i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33) as f32 % 251.0 / 251.0)
        .collect();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let replicas = cores.max(2);
    println!(
        "native router: synthetic model (SPARQ 5opt+R), {} parameter bytes shared by \
         every replica; {clients} clients x {per_client} requests",
        params.weights.param_bytes()
    );

    for nrep in [1usize, replicas] {
        let router = Arc::new(
            InferenceRouter::builder()
                .model_with_threads(
                    "synth",
                    params.clone(),
                    nrep,
                    BatchPolicy {
                        max_batch: 8,
                        max_wait: Duration::from_micros(500),
                        ..BatchPolicy::default()
                    },
                    1,
                )
                .build()?,
        );
        let _ = router.infer("synth", image.clone())?; // warmup
        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let r = router.clone();
                let im = image.clone();
                std::thread::spawn(move || {
                    for _ in 0..per_client {
                        r.infer("synth", im.clone()).unwrap();
                    }
                })
            })
            .collect();
        for hd in handles {
            hd.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = clients * per_client;
        let m = router.metrics("synth")?;
        println!("\n{nrep} replica shard(s):");
        println!(
            "  throughput      {:.1} req/s ({total} requests in {wall:.2}s)",
            total as f64 / wall
        );
        for s in &m.shards {
            println!(
                "  shard {}        {} reqs, {} batches (full: {}), mean {:.1} ms, p99 {:.1} ms, \
                 peak queue {}",
                s.shard,
                s.batcher.requests,
                s.batcher.batches,
                s.batcher.full_batches,
                s.mean_latency_us / 1000.0,
                s.p99_latency_us as f64 / 1000.0,
                s.batcher.peak_queue_depth,
            );
        }
        println!(
            "  aggregate       {} reqs, {} exec errors, {} shed, {} rejected",
            m.total.requests, m.total.exec_errors, m.total.shed, m.total.rejected
        );
    }
    Ok(())
}

/// Minimal blocking HTTP/1.1 client over one keep-alive connection —
/// `std::net::TcpStream` only, no curl in the image.
struct MiniClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl MiniClient {
    fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting to the http front door")?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        stream.set_nodelay(true)?;
        Ok(Self { stream, buf: Vec::new() })
    }

    /// Send one raw request and read one response: (status, body).
    fn request(&mut self, raw: &[u8]) -> Result<(u16, String)> {
        self.stream.write_all(raw)?;
        let find = |buf: &[u8]| buf.windows(4).position(|w| w == b"\r\n\r\n");
        let head_end = loop {
            if let Some(i) = find(&self.buf) {
                break i;
            }
            let mut chunk = [0u8; 4096];
            let n = self.stream.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "server closed the connection mid-response");
            self.buf.extend_from_slice(&chunk[..n]);
        };
        let head = std::str::from_utf8(&self.buf[..head_end])?.to_string();
        let status: u16 = head
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("unparseable status line `{head}`"))?;
        let mut content_length = 0usize;
        for line in head.split("\r\n").skip(1) {
            if let Some((name, value)) = line.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse()?;
                }
            }
        }
        let total = head_end + 4 + content_length;
        while self.buf.len() < total {
            let mut chunk = [0u8; 4096];
            let n = self.stream.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "server closed the connection mid-body");
            self.buf.extend_from_slice(&chunk[..n]);
        }
        let body = String::from_utf8(self.buf[head_end + 4..total].to_vec())?;
        self.buf.drain(..total);
        Ok((status, body))
    }
}

/// Demo router + front door on an ephemeral loopback port; returns the
/// server (keep it alive!), router, reference engine (for the default
/// `5opt_r` variant) and input width.
///
/// Three policy variants share ONE graph+weights allocation:
/// `"5opt_r"` (default, the paper's headline config), `"a8w8"`
/// (uniform 8-bit reference) and `"first8"` (first quantized conv at 8
/// bits, rest uniform 4-bit) — the multi-operating-point serving shape
/// the policy API exists for.
fn demo_http_stack(replicas: usize) -> Result<(HttpServer, Arc<InferenceRouter>, Engine, usize)> {
    let (graph, weights, scales) = synth_model();
    let (graph, weights) = (Arc::new(graph), Arc::new(weights));
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        ..BatchPolicy::default()
    };
    let mk = |p: QuantPolicy| -> Result<Arc<ModelParams>> {
        Ok(Arc::new(ModelParams::with_policy(
            graph.clone(),
            weights.clone(),
            p,
            &scales,
            EngineMode::Dense,
        )?))
    };
    let sparq = mk(QuantPolicy::uniform(SparqConfig::named("5opt_r").unwrap()))?;
    let a8w8 = mk(QuantPolicy::named("a8w8").expect("registry preset"))?;
    let first8 = mk(QuantPolicy::named("first8").expect("policy preset"))?;
    let engine = Engine::from_params(sparq.clone());
    let [h, w, c] = graph.input_hwc;
    let router = Arc::new(
        InferenceRouter::builder()
            .model_variant_with_threads("synth", "5opt_r", sparq, replicas, policy, 1)
            .model_variant_with_threads("synth", "a8w8", a8w8, 1, policy, 1)
            .model_variant_with_threads("synth", "first8", first8, 1, policy, 1)
            .build()?,
    );
    let server = HttpServer::bind("127.0.0.1:0", router.clone(), HttpConfig::default())?;
    Ok((server, router, engine, h * w * c))
}

/// Deterministic image whose values survive the f32 -> JSON -> f32
/// round trip bit-exactly (24-bit fractions).
fn http_image(image_len: usize) -> Vec<f32> {
    (0..image_len)
        .map(|j| {
            let h = (j as u64).wrapping_mul(0x9e3779b97f4a7c15);
            (h >> 40) as f32 / 16_777_216.0
        })
        .collect()
}

/// `target` is `synth` or `synth@{variant}`.
fn infer_request(target: &str, body: &str) -> Vec<u8> {
    format!(
        "POST /v1/infer/{target} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn logits_from(resp: &str) -> Result<Vec<f32>> {
    Ok(JsonValue::parse(resp)?
        .get("logits")
        .and_then(|l| l.as_array().map(|a| a.to_vec()))
        .context("no logits in response")?
        .iter()
        .map(|x| x.as_f64().unwrap_or(f64::NAN) as f32)
        .collect())
}

/// `--http`: benchmark the front door with keep-alive TCP clients.
fn http_bench(clients: usize, per_client: usize) -> Result<()> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let replicas = cores.max(2);
    let (server, router, engine, image_len) = demo_http_stack(replicas)?;
    let addr = server.addr();
    let image = http_image(image_len);
    let want = engine.forward(&image, 1)?;
    let body = json_obj! {
        "image" => image.iter().map(|&v| f64::from(v)).collect::<Vec<f64>>()
    }
    .to_string();
    let raw = Arc::new(infer_request("synth", &body));
    println!(
        "http front door on {addr}: {replicas} replica shard(s), \
         {clients} keep-alive clients x {per_client} requests"
    );
    // Warmup + correctness gate before timing anything.
    let (status, resp) = MiniClient::connect(addr)?.request(&raw)?;
    anyhow::ensure!(status == 200, "warmup request failed: {status} {resp}");

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let raw = raw.clone();
            std::thread::spawn(move || -> Result<()> {
                let mut client = MiniClient::connect(addr)?;
                for _ in 0..per_client {
                    let (status, resp) = client.request(&raw)?;
                    anyhow::ensure!(status == 200, "request failed: {status} {resp}");
                }
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread panicked")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = clients * per_client;
    println!("\nresults:");
    println!(
        "  throughput      {:.1} req/s ({total} requests in {wall:.2}s, one event-loop thread)",
        total as f64 / wall
    );
    // Spot-check the served answer and print the served metrics.
    let (_, resp) = MiniClient::connect(addr)?.request(&raw)?;
    let logits = logits_from(&resp)?;
    anyhow::ensure!(logits == want, "HTTP logits diverge from direct Engine::forward");
    let m = router.metrics("synth")?;
    println!(
        "  aggregate       {} reqs, peak queue {}, {} shed, {} rejected, {} expired",
        m.total.requests, m.total.peak_queue_depth, m.total.shed, m.total.rejected,
        m.total.expired
    );
    let (status, metrics) =
        MiniClient::connect(addr)?.request(b"GET /v1/metrics HTTP/1.1\r\nHost: bench\r\n\r\n")?;
    anyhow::ensure!(status == 200, "metrics endpoint failed: {status}");
    println!("  GET /v1/metrics ({} bytes of JSON) OK", metrics.len());
    Ok(())
}

/// `--http-smoke`: end-to-end front-door check CI runs on every push —
/// one default-variant request bit-identical to `Engine::forward`,
/// `GET /v1/models` introspection naming every variant, and an infer
/// against a non-default variant whose logits differ from the uniform
/// A8W8 variant's. Non-zero exit on any mismatch.
fn http_smoke() -> Result<()> {
    let (server, _router, engine, image_len) = demo_http_stack(2)?;
    let addr = server.addr();
    let image = http_image(image_len);
    let body = json_obj! {
        "image" => image.iter().map(|&v| f64::from(v)).collect::<Vec<f64>>()
    }
    .to_string();
    let mut client = MiniClient::connect(addr)?;
    let (status, resp) = client.request(&infer_request("synth", &body))?;
    anyhow::ensure!(status == 200, "smoke request failed: {status} {resp}");
    let logits = logits_from(&resp).context("default-variant response")?;
    let want = engine.forward(&image, 1)?;
    anyhow::ensure!(
        logits == want,
        "HTTP logits diverge from direct Engine::forward: {logits:?} vs {want:?}"
    );
    // Policy introspection: /v1/models must name every variant and
    // report a parseable policy for each.
    let (status, models) =
        client.request(b"GET /v1/models HTTP/1.1\r\nHost: smoke\r\n\r\n")?;
    anyhow::ensure!(status == 200, "/v1/models failed: {status} {models}");
    let parsed = JsonValue::parse(&models).context("/v1/models body is not JSON")?;
    let synth = parsed
        .get("models")
        .and_then(|m| m.get("synth"))
        .context("/v1/models lists no `synth` model")?;
    anyhow::ensure!(
        synth.get("default_variant").and_then(|v| v.as_str()) == Some("5opt_r"),
        "wrong default variant in {models}"
    );
    for v in ["5opt_r", "a8w8", "first8"] {
        let var = synth
            .get("variants")
            .and_then(|vs| vs.get(v))
            .with_context(|| format!("/v1/models missing variant `{v}`"))?;
        anyhow::ensure!(
            var.get("policy").is_some() && var.get("layers").is_some(),
            "variant `{v}` lacks policy introspection: {models}"
        );
    }
    // Variant serving: the non-default `first8` variant must answer and
    // differ numerically from the uniform A8W8 variant.
    let (status, resp_a8) = client.request(&infer_request("synth@a8w8", &body))?;
    anyhow::ensure!(status == 200, "a8w8 variant failed: {status} {resp_a8}");
    let (status, resp_f8) = client.request(&infer_request("synth@first8", &body))?;
    anyhow::ensure!(status == 200, "first8 variant failed: {status} {resp_f8}");
    let (l_a8, l_f8) = (logits_from(&resp_a8)?, logits_from(&resp_f8)?);
    // Finite-ness first: logits_from maps non-numeric elements to NaN,
    // and NaN != NaN would make the distinctness check pass vacuously.
    anyhow::ensure!(
        l_a8.iter().all(|v| v.is_finite()) && l_f8.iter().all(|v| v.is_finite()),
        "variant responses contain non-finite logits: {resp_a8} / {resp_f8}"
    );
    anyhow::ensure!(
        l_a8 != l_f8,
        "first8 variant served logits identical to uniform A8W8 — variants are not \
         actually per-layer distinct"
    );
    // Same keep-alive connection: healthz must answer too.
    let (status, health) = client.request(b"GET /healthz HTTP/1.1\r\nHost: smoke\r\n\r\n")?;
    anyhow::ensure!(status == 200 && health.contains("ok"), "healthz failed: {status} {health}");
    println!(
        "HTTP smoke OK: 200 with {} logits bit-identical to Engine::forward; \
         /v1/models lists 3 variants; first8 != a8w8 logits; healthz {health}",
        logits.len()
    );
    Ok(())
}
