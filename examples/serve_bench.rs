//! Serving benchmark: the dynamically batched SPARQ inference service
//! under concurrent client load — latency/throughput for the paper's
//! "increase execution performance" motivation, on the real artifacts.
//!
//! ```bash
//! cargo run --release --example serve_bench [artifacts-dir] [clients] [requests-per-client]
//! ```

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use sparq::coordinator::{calibrate, BatchPolicy, InferenceServer};
use sparq::data::Dataset;
use sparq::model::Graph;
use sparq::quant::SparqConfig;
use sparq::runtime::{Manifest, PjrtRuntime};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let dir = PathBuf::from(args.get(1).map(String::as_str).unwrap_or("artifacts"));
    let clients: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(16);
    let per_client: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(32);

    let rt = Arc::new(PjrtRuntime::cpu()?);
    let manifest = Manifest::load(&dir)?;
    let model = manifest.get("resnet10")?;
    let graph = Graph::load(&model.meta_path())?;
    let eval = Arc::new(Dataset::load(&dir.join("test.bin"))?);
    let calib_ds = Dataset::load(&dir.join("train.bin"))?;
    let scales = calibrate(&rt, model, &calib_ds, 64, 512)?.scales();

    let server = Arc::new(InferenceServer::start(
        rt,
        model,
        graph.input_hwc,
        graph.num_classes,
        scales,
        SparqConfig::named("5opt_r").unwrap(),
        BatchPolicy {
            max_batch: graph.eval_batch,
            max_wait: Duration::from_millis(4),
        },
    )?);

    println!(
        "serving resnet10 (SPARQ 5opt+R) to {clients} clients x {per_client} requests, \
         batch up to {} ...",
        graph.eval_batch
    );
    // warmup: first request triggers nothing extra (exe precompiled), but
    // prime the pipeline anyway
    let _ = server.infer(eval.image_f32(0))?;

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let s = server.clone();
            let d = eval.clone();
            std::thread::spawn(move || -> (usize, usize) {
                let mut correct = 0;
                for r in 0..per_client {
                    let idx = (c * per_client + r) % d.n;
                    let reply = s.infer(d.image_f32(idx)).unwrap();
                    let pred = reply
                        .logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if pred == d.label(idx) {
                        correct += 1;
                    }
                }
                (correct, per_client)
            })
        })
        .collect();
    let mut correct = 0;
    let mut total = 0;
    for h in handles {
        let (c, t) = h.join().unwrap();
        correct += c;
        total += t;
    }
    let wall = t0.elapsed().as_secs_f64();

    let metrics = server.metrics();
    let m = metrics.lock().unwrap();
    println!("\nresults:");
    println!("  requests        {total}  ({correct} correct = {:.2}%)", 100.0 * correct as f64 / total as f64);
    println!("  wall time       {wall:.2}s");
    println!("  throughput      {:.1} req/s", total as f64 / wall);
    println!("  latency mean    {:.1} ms", m.e2e.mean_us() / 1000.0);
    println!("  latency p50     {:.1} ms", m.e2e.quantile_us(0.50) as f64 / 1000.0);
    println!("  latency p99     {:.1} ms", m.e2e.quantile_us(0.99) as f64 / 1000.0);
    println!("  latency max     {:.1} ms", m.e2e.max_us() as f64 / 1000.0);
    println!("  queue mean      {:.1} ms", m.queue.mean_us() / 1000.0);
    Ok(())
}
