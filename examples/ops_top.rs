//! `ops_top`: a top-style live terminal view over `GET /v1/metrics`.
//!
//! ```bash
//! cargo run --release --example ops_top                            # self-hosted demo
//! cargo run --release --example ops_top -- --attach 127.0.0.1:8080 # watch a real server
//! cargo run --release --example ops_top -- --frames 2 --interval-ms 100 --plain  # CI smoke
//! ```
//!
//! Default mode boots the three-variant demo router (`5opt_r` default,
//! `a8w8`, `first8`, one shared weights allocation) behind the HTTP
//! front door on an ephemeral loopback port, drives it with a weighted
//! synthetic load (~70/20/10 across the variants), and then polls its
//! own `/v1/metrics` endpoint **over a real socket** — exactly the path
//! an external collector takes, so the dashboard exercises the wire
//! format, not an in-process shortcut.
//!
//! Each frame shows aggregate request rate (delta between polls),
//! per-variant request shares (with the sliding-window `recent p99`
//! that drives the SLO ladder), per-shard p50/p99 with a sparkline of
//! the bucketed latency histogram, and the queue-health counters
//! (depth/peak/shed/expired/rejected) that make overload visible.
//! When a model has an SLO degradation ladder installed, its active
//! rung, time-in-degraded-mode, and transition counters get their own
//! line, and the rung currently serving is marked `nominal` or
//! `degraded` in the variant table.
//!
//! `--frames N` stops after N frames (default 5), `--once` is
//! `--frames 1`, `--interval-ms M` sets the poll period, and `--plain`
//! suppresses ANSI screen clearing (also auto-suppressed when stdout is
//! not a terminal).

use std::io::IsTerminal;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};
use sparq::coordinator::{BatchPolicy, HttpConfig, HttpServer, InferenceRouter};
use sparq::json::JsonValue;
use sparq::model::demo::synth_model;
use sparq::model::{EngineMode, ModelParams};
use sparq::observability::http_get_json;
use sparq::quant::{QuantPolicy, SparqConfig};

fn main() -> Result<()> {
    let mut attach: Option<String> = None;
    let mut frames = 5usize;
    let mut interval = Duration::from_millis(500);
    let mut plain = false;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--attach" => {
                i += 1;
                attach = Some(args.get(i).context("`--attach` needs host:port")?.clone());
            }
            "--frames" => {
                i += 1;
                frames = args.get(i).context("`--frames` needs a count")?.parse()?;
            }
            "--interval-ms" => {
                i += 1;
                let ms: u64 = args.get(i).context("`--interval-ms` needs a number")?.parse()?;
                interval = Duration::from_millis(ms);
            }
            "--once" => frames = 1,
            "--plain" => plain = true,
            other => anyhow::bail!("unknown argument `{other}`"),
        }
        i += 1;
    }
    anyhow::ensure!(frames >= 1, "--frames must be at least 1");
    let clear = !plain && std::io::stdout().is_terminal();

    // Demo stack (kept alive for the whole run) unless attaching.
    let demo = if attach.is_none() {
        Some(demo_stack()?)
    } else {
        None
    };
    let addr = match &attach {
        Some(a) => a.clone(),
        None => demo.as_ref().unwrap().0.addr().to_string(),
    };
    println!("polling http://{addr}/v1/metrics ({frames} frame(s), every {interval:?})");

    let mut prev: Option<(Instant, f64)> = None;
    for frame in 0..frames {
        if frame > 0 {
            std::thread::sleep(interval);
        }
        let metrics = http_get_json(&addr, "/v1/metrics", Duration::from_secs(5))?;
        render(&metrics, &addr, frame, &mut prev, clear);
    }
    Ok(())
}

/// Load-generator threads attached to the demo router; stopped and
/// joined on drop so the example always exits cleanly.
struct DemoLoad {
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Drop for DemoLoad {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// The three-variant demo router behind the front door on an ephemeral
/// loopback port, plus a weighted synthetic load: per 10 requests,
/// 7 hit the default `5opt_r` variant, 2 `a8w8`, 1 `first8`.
fn demo_stack() -> Result<(HttpServer, DemoLoad)> {
    let (graph, weights, scales) = synth_model();
    let (graph, weights) = (Arc::new(graph), Arc::new(weights));
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        ..BatchPolicy::default()
    };
    let mk = |p: QuantPolicy| -> Result<Arc<ModelParams>> {
        Ok(Arc::new(ModelParams::with_policy(
            graph.clone(),
            weights.clone(),
            p,
            &scales,
            EngineMode::Dense,
        )?))
    };
    let sparq = mk(QuantPolicy::uniform(SparqConfig::named("5opt_r").unwrap()))?;
    let a8w8 = mk(QuantPolicy::named("a8w8").expect("registry preset"))?;
    let first8 = mk(QuantPolicy::named("first8").expect("registry preset"))?;
    let [h, w, c] = graph.input_hwc;
    let router = Arc::new(
        InferenceRouter::builder()
            .model_variant_with_threads("synth", "5opt_r", sparq, 2, policy, 1)
            .model_variant_with_threads("synth", "a8w8", a8w8, 1, policy, 1)
            .model_variant_with_threads("synth", "first8", first8, 1, policy, 1)
            .build()?,
    );
    let server = HttpServer::bind("127.0.0.1:0", router.clone(), HttpConfig::default())?;
    let image: Vec<f32> = (0..h * w * c)
        .map(|j| {
            let hash = (j as u64).wrapping_mul(0x9e3779b97f4a7c15);
            (hash >> 40) as f32 / 16_777_216.0
        })
        .collect();
    let stop = Arc::new(AtomicBool::new(false));
    let threads = (0..3)
        .map(|t| {
            let r = router.clone();
            let im = image.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = t * 3; // offset so the threads interleave variants
                while !stop.load(Ordering::Relaxed) {
                    let res = match i % 10 {
                        0..=6 => r.infer("synth", im.clone()),
                        7 | 8 => r.infer_variant("synth", "a8w8", im.clone()),
                        _ => r.infer_variant("synth", "first8", im.clone()),
                    };
                    if res.is_err() {
                        break; // router shut down — stop generating
                    }
                    i += 1;
                    std::thread::sleep(Duration::from_millis(2));
                }
            })
        })
        .collect();
    Ok((server, DemoLoad { stop, threads }))
}

fn num(v: Option<&JsonValue>) -> f64 {
    v.and_then(JsonValue::as_f64).unwrap_or(0.0)
}

/// ASCII sparkline over the histogram's (elided) bucket counts — shape
/// of the latency distribution at a glance.
fn sparkline(hist: Option<&JsonValue>) -> String {
    let Some(buckets) = hist.and_then(|hh| hh.get("buckets")).and_then(JsonValue::as_array)
    else {
        return String::new();
    };
    let counts: Vec<f64> = buckets.iter().map(|b| num(b.get("count"))).collect();
    let max = counts.iter().cloned().fold(0.0f64, f64::max);
    if max <= 0.0 {
        return String::new();
    }
    const GLYPHS: [char; 8] = ['.', ':', '-', '=', '+', 'x', '*', '#'];
    counts
        .iter()
        .map(|&cnt| GLYPHS[((cnt / max) * 7.0).round() as usize])
        .collect()
}

/// The variant's version column: serving generation + lifecycle state,
/// and — while a rollout is live — the canary's incoming generation,
/// its share of traffic (1-in-N batches) and agreement progress, so a
/// rollout is visible as it happens.
fn version_label(v: &JsonValue) -> String {
    let generation = num(v.get("generation"));
    if generation <= 0.0 {
        // executor-backed variants carry no version metadata
        return String::new();
    }
    let state = v.get("state").and_then(JsonValue::as_str).unwrap_or("serving");
    let mut label = format!("gen {generation:.0} {state}");
    if let Some(c) = v.get("rollout").and_then(|r| r.get("canary")) {
        if !matches!(c, JsonValue::Null) {
            let share = num(c.get("share")).max(1.0);
            label.push_str(&format!(
                " ← gen {:.0} {:.1}% traffic ({:.0}/{:.0} agree)",
                num(c.get("generation")),
                100.0 / share,
                num(c.get("agree")),
                num(c.get("total")),
            ));
        }
    }
    label
}

/// One line of ladder state for a model with an SLO policy installed:
/// the active rung, which variant it serves, accumulated
/// time-in-degraded-mode, and the down/up transition counters. `None`
/// when no policy is installed (`"slo": null` in the metrics JSON).
fn slo_label(m: &JsonValue) -> Option<String> {
    let slo = m.get("slo")?;
    if matches!(slo, JsonValue::Null) {
        return None;
    }
    let rungs = slo.get("ladder").and_then(JsonValue::as_array).map_or(0, <[JsonValue]>::len);
    let serving = slo.get("serving").and_then(JsonValue::as_str).unwrap_or("?");
    let degraded = slo.get("degraded").and_then(JsonValue::as_bool) == Some(true);
    Some(format!(
        "  slo: rung {:.0}/{rungs} serving `{serving}` ({})  degraded {:.1} ms total  \
         {:.0} down / {:.0} up",
        num(slo.get("rung")) + 1.0,
        if degraded { "degraded" } else { "nominal" },
        num(slo.get("time_degraded_us")) / 1000.0,
        num(slo.get("transitions_down")),
        num(slo.get("transitions_up")),
    ))
}

/// Per-variant auto-search marker: `⌕ ` on variants whose serving
/// version came from policy auto-search (provenance origin `search`),
/// so searched operating points stand out from hand-written ones.
fn search_marker(v: &JsonValue) -> &'static str {
    let origin = v.get("provenance").and_then(|p| p.get("origin")).and_then(JsonValue::as_str);
    if origin == Some("search") {
        "⌕ "
    } else {
        ""
    }
}

/// One line of auto-search state for a model that has launched one:
/// the current phase and eval progress, plus the chosen policy once
/// the run is terminal. `None` until the first autosearch POST.
fn autosearch_label(m: &JsonValue) -> Option<String> {
    let a = m.get("autosearch")?;
    if matches!(a, JsonValue::Null) {
        return None;
    }
    let phase = a.get("phase").and_then(JsonValue::as_str).unwrap_or("?");
    let mut label = format!(
        "  autosearch: {phase} ({:.0}/{:.0} evals)",
        num(a.get("evals_done")),
        num(a.get("evals_planned")),
    );
    if let Some(out) = a.get("outcome") {
        if let Some(display) = out.get("display").and_then(JsonValue::as_str) {
            label.push_str(&format!(
                " → {display} {:.2} bits/act, agreement {:.4}",
                num(out.get("footprint_bits")),
                num(out.get("agreement")),
            ));
        }
        if let Some(err) = out.get("error").and_then(JsonValue::as_str) {
            label.push_str(&format!(" — {err}"));
        }
    }
    Some(label)
}

/// Per-variant ladder marker: the rung currently serving is tagged
/// `nominal` (the default rung) or `degraded` (any cheaper rung);
/// everything else — other rungs, models without a policy — is blank.
fn slo_marker(slo: Option<&JsonValue>, vname: &str) -> &'static str {
    let Some(slo) = slo else { return "" };
    if slo.get("serving").and_then(JsonValue::as_str) != Some(vname) {
        return "";
    }
    if slo.get("degraded").and_then(JsonValue::as_bool) == Some(true) {
        " ← degraded"
    } else {
        " ← nominal"
    }
}

fn share_bar(frac: f64, width: usize) -> String {
    let filled = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    format!("{}{}", "#".repeat(filled), ".".repeat(width - filled))
}

fn render(
    metrics: &JsonValue,
    addr: &str,
    frame: usize,
    prev: &mut Option<(Instant, f64)>,
    clear: bool,
) {
    if clear {
        print!("\x1b[2J\x1b[H");
    }
    let now = Instant::now();
    let agg = metrics.get("aggregate");
    let requests = num(agg.and_then(|a| a.get("requests")));
    let rate = match *prev {
        Some((t0, r0)) => {
            let dt = now.duration_since(t0).as_secs_f64();
            if dt > 0.0 {
                (requests - r0).max(0.0) / dt
            } else {
                0.0
            }
        }
        None => 0.0,
    };
    *prev = Some((now, requests));
    println!("ops_top — http://{addr}/v1/metrics — frame {frame}");
    println!(
        "aggregate: {requests:.0} reqs  {rate:>8.1} req/s   batches {:.0}  shed {:.0}  \
         rejected {:.0}  expired {:.0}",
        num(agg.and_then(|a| a.get("batches"))),
        num(agg.and_then(|a| a.get("shed"))),
        num(agg.and_then(|a| a.get("rejected"))),
        num(agg.and_then(|a| a.get("expired"))),
    );
    let Some(models) = metrics.get("models").and_then(JsonValue::as_object) else {
        println!("(no models reported)");
        return;
    };
    for (name, m) in models {
        let total = m.get("total");
        let model_reqs = num(total.and_then(|t| t.get("requests"))).max(1.0);
        println!(
            "\nmodel {name}: {} replica(s), {} param bytes, queue depth {:.0} (peak {:.0})",
            num(m.get("replicas")),
            num(m.get("param_bytes")),
            num(total.and_then(|t| t.get("queue_depth"))),
            num(total.and_then(|t| t.get("peak_queue_depth"))),
        );
        if let Some(label) = slo_label(m) {
            println!("{label}");
        }
        if let Some(label) = autosearch_label(m) {
            println!("{label}");
        }
        if let Some(variants) = m.get("variants").and_then(JsonValue::as_array) {
            for v in variants {
                let vname = v.get("variant").and_then(JsonValue::as_str).unwrap_or("?");
                let vreqs = num(v.get("total").and_then(|t| t.get("requests")));
                println!(
                    "  {}{vname:<10} [{}] {vreqs:>8.0} reqs  {:.0} replica(s)  \
                     {:.2} bits/act  recent p99 {:>6.0} us  {}{}",
                    search_marker(v),
                    share_bar(vreqs / model_reqs, 20),
                    num(v.get("replicas")),
                    num(v.get("footprint_bits_per_act")),
                    num(v.get("recent_p99_us")),
                    version_label(v),
                    slo_marker(m.get("slo"), vname),
                );
            }
        }
        if let Some(shards) = m.get("shards").and_then(JsonValue::as_array) {
            for s in shards {
                let b = s.get("batcher");
                println!(
                    "    shard {:>2}  p50 {:>7.0} us  p99 {:>7.0} us  {:>8.0} reqs  \
                     peak {:>3.0}  shed {:.0}  rej {:.0}  exp {:.0}  {}",
                    num(s.get("shard")),
                    num(s.get("p50_latency_us")),
                    num(s.get("p99_latency_us")),
                    num(b.and_then(|x| x.get("requests"))),
                    num(b.and_then(|x| x.get("peak_queue_depth"))),
                    num(b.and_then(|x| x.get("shed"))),
                    num(b.and_then(|x| x.get("rejected"))),
                    num(b.and_then(|x| x.get("expired"))),
                    sparkline(s.get("hist")),
                );
            }
        }
    }
}
