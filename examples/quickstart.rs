//! Quickstart: the minimal SPARQ workflow on one model.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the resnet10 artifacts, calibrates activation scales on the
//! training split (paper §5: min-max over calibration images),
//! evaluates FP32 / A8W8 / SPARQ-5opt+R top-1 through the PJRT request
//! path, and walks through the Figure-1 bit-trim example.

use std::path::PathBuf;

use anyhow::Result;
use sparq::coordinator::{calibrate, evaluate_pjrt};
use sparq::data::Dataset;
use sparq::quant::bsparq::trim_window;
use sparq::quant::{Mode, SparqConfig};
use sparq::runtime::{Manifest, PjrtRuntime};

fn main() -> Result<()> {
    let dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string()),
    );
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {} ({} device)", rt.platform(), rt.device_count());

    let manifest = Manifest::load(&dir)?;
    let model = manifest.get("resnet10")?;
    let eval = Dataset::load(&dir.join("test.bin"))?;
    let calib_ds = Dataset::load(&dir.join("train.bin"))?;

    // 1. calibrate (min-max over calibration images)
    let stats = calibrate(&rt, model, &calib_ds, 64, 512)?;
    let scales = stats.scales();
    println!("calibrated {} activation scales", scales.len());

    // 2. evaluate: FP32, A8W8, SPARQ 4-bit (5opt + rounding + vSPARQ)
    let limit = 512;
    let fp32 = evaluate_pjrt(&rt, model, &eval, 64, &[], None, limit)?;
    println!("FP32      top-1 = {:.2}%", 100.0 * fp32.accuracy());
    for name in ["a8w8", "5opt_r", "2opt"] {
        let cfg = SparqConfig::named(name).unwrap();
        let rep = evaluate_pjrt(&rt, model, &eval, 64, &scales, Some(cfg), limit)?;
        println!(
            "{:<9} top-1 = {:.2}%  (delta {:+.2}%)",
            cfg.to_string(),
            100.0 * rep.accuracy(),
            100.0 * (rep.accuracy() - fp32.accuracy())
        );
    }

    // 3. the Figure-1 example: how bSPARQ trims 27 = 00011011b
    println!("\nFigure 1 walkthrough for 27 (00011011b):");
    for (label, mode) in [("5opt", Mode::Full), ("3opt", Mode::Opt3), ("2opt", Mode::Opt2)] {
        println!(
            "  {label}: trim -> {:2}, +R -> {:2}",
            trim_window(27, 4, mode, false),
            trim_window(27, 4, mode, true)
        );
    }
    Ok(())
}
