//! Hardware case study (paper §4): runs a real conv-layer GEMM from the
//! exported zoo through the systolic-array and tensor-core simulators,
//! and on the 2:4 models through the STC datapath, reporting cycles,
//! utilization, eq.-2 case mix, and the area model's Table-5 view.
//!
//! ```bash
//! cargo run --release --example hw_sim [artifacts-dir]
//! ```

use std::path::PathBuf;

use anyhow::Result;
use sparq::coordinator::calibrate;
use sparq::data::Dataset;
use sparq::hw::stc::{dense_tc_cycles, stc_gemm, CompressedWeights};
use sparq::hw::systolic::SystolicArray;
use sparq::hw::tensor_core::SparqDpUnit;
use sparq::hw::{area, TrimUnit};
use sparq::model::{Graph, Weights};
use sparq::quant::minmax::ActScale;
use sparq::quant::SparqConfig;
use sparq::runtime::{Manifest, PjrtRuntime};
use sparq::tensor::im2col_u8;

fn main() -> Result<()> {
    let dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string()),
    );
    let manifest = Manifest::load(&dir)?;
    let eval = Dataset::load(&dir.join("test.bin"))?;
    let calib_ds = Dataset::load(&dir.join("train.bin"))?;
    let rt = PjrtRuntime::cpu()?;

    // Real operands: quantized activations of resnet10's first quantized
    // conv on real eval images (so the sparsity mix is genuine).
    let model = manifest.get("resnet10")?;
    let graph = Graph::load(&model.meta_path())?;
    let weights = Weights::load(&model.weights_path())?;
    let scales = calibrate(&rt, model, &calib_ds, 64, 256)?.scales();

    // run the float stem in the native engine up to the first quantized
    // conv by tracing — simpler: quantize the *input images* of conv2 via
    // a one-batch traced forward
    struct Grab {
        layer: String,
        acts: Option<Vec<u8>>,
        k: usize,
    }
    impl sparq::model::TraceSink for Grab {
        fn record(&mut self, layer: &str, acts_q: &[u8]) {
            if layer == self.layer && self.acts.is_none() {
                self.acts = Some(acts_q.to_vec());
            }
        }
    }
    let engine = sparq::model::Engine::new(
        &graph,
        &weights,
        SparqConfig::A8W8,
        &scales,
        sparq::model::EngineMode::Dense,
    )?;
    let first_q = graph.quant_convs[0].clone();
    let qc = weights.quant_conv(&first_q)?;
    let mut grab = Grab { layer: first_q.clone(), acts: None, k: qc.k };
    let mut buf = Vec::new();
    eval.batch_f32_into(0, 16, &mut buf);
    engine.forward_traced(&buf, 16, &mut grab)?;
    let patches = grab.acts.expect("trace captured");
    let m = patches.len() / grab.k;
    let zero_frac =
        patches.iter().filter(|&&x| x == 0).count() as f64 / patches.len() as f64;
    println!(
        "workload: {first_q} of resnet10 — GEMM {m}x{}x{} from real images, {:.1}% zero acts\n",
        qc.k,
        qc.o,
        100.0 * zero_frac
    );

    println!("== systolic array 16x16 (paper Fig. 3) ==");
    for name in ["5opt_r", "3opt_r", "2opt_r", "6opt_r", "7opt_r"] {
        let cfg = SparqConfig::named(name).unwrap();
        let sa = SystolicArray::new(16, 16, cfg);
        let run = sa.gemm(&patches, &qc.wq, m, qc.k, qc.o);
        let pairs = (run.both_zero + run.zero_skip + run.dual_trim).max(1);
        println!(
            "  {:<8} cycles {:>8}  speedup {:.2}x  zero-skip {:>5.1}%  dual-trim {:>5.1}%",
            cfg.to_string(),
            run.cycles,
            sa.baseline_cycles(m, qc.k, qc.o) as f64 / run.cycles as f64,
            100.0 * run.zero_skip as f64 / pairs as f64,
            100.0 * run.dual_trim as f64 / pairs as f64,
        );
    }

    println!("\n== tensor core DP unit (paper Fig. 4) ==");
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let mut dp = SparqDpUnit::new(cfg);
    let row = &patches[..qc.k];
    let col: Vec<i8> = (0..qc.k).map(|r| qc.wq[r * qc.o]).collect();
    let (y, stats) = dp.dot(row, &col);
    println!(
        "  one DP (K={}): result {}, {} cycles (dense TC: {}), zero-skip rate {:.2}",
        qc.k,
        y,
        stats.cycles,
        SparqDpUnit::baseline_cycles(qc.k),
        SparqDpUnit::zero_skip_rate(&stats)
    );

    println!("\n== sparse tensor core (paper Fig. 5, 2:4 model) ==");
    let pmodel = manifest.get("resnet10_p24")?;
    let pweights = Weights::load(&pmodel.weights_path())?;
    let pgraph = Graph::load(&pmodel.meta_path())?;
    let pqc = pweights.quant_conv(&pgraph.quant_convs[0])?;
    let k4 = pqc.k.div_ceil(4) * 4;
    let mut wq = vec![0i8; k4 * pqc.o];
    for r in 0..pqc.k {
        wq[r * pqc.o..(r + 1) * pqc.o]
            .copy_from_slice(&pqc.wq[r * pqc.o..(r + 1) * pqc.o]);
    }
    let cw = CompressedWeights::compress(&wq, k4, pqc.o)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let (cbits, dbits) = cw.storage_bits();
    // synthetic activations at the real sparsity level
    let am = 256;
    let acts: Vec<u8> = (0..am * k4)
        .map(|i| {
            let h = (i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33;
            if (h % 100) as f64 / 100.0 < zero_frac {
                0
            } else {
                (h % 256) as u8
            }
        })
        .collect();
    let (_, sstats) = stc_gemm(&acts, &cw, am, cfg);
    println!(
        "  weights {}x{}: storage {:.2}x smaller; {} cycles vs dense TC {} ({:.2}x)",
        k4,
        pqc.o,
        dbits as f64 / cbits as f64,
        sstats.cycles,
        dense_tc_cycles(am, k4, pqc.o),
        dense_tc_cycles(am, k4, pqc.o) as f64 / sstats.cycles as f64
    );
    println!(
        "  post-selection pair-zero rate: {:.1}% (the §5.3 opportunity)",
        100.0 * sstats.pair_zero as f64 / sstats.pairs as f64
    );

    println!("\n== area model (paper Table 5) ==");
    for (label, sa, tc) in area::table5_rows() {
        println!("  {label:<9} SA {sa:.2}   TC {tc:.2}");
    }
    println!("\n== trim-unit area relative to TC (paper §5.3: 17/12/9%) ==");
    for name in ["5opt_r", "3opt_r", "2opt_r"] {
        let cfg = SparqConfig::named(name).unwrap();
        let _ = TrimUnit::new(cfg); // constructible for every SPARQ mode
        println!(
            "  {:<8} {:.1}%",
            cfg.to_string(),
            100.0 * area::trim_unit_relative_to_tc(cfg)
        );
    }
    Ok(())
}
