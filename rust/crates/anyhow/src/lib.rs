//! Offline drop-in subset of the `anyhow` crate.
//!
//! The container image carries no crates.io registry, so the workspace
//! vendors the slice of `anyhow`'s API this repo actually uses:
//!
//! * [`Error`] / [`Result`] with `?`-conversion from any
//!   `std::error::Error + Send + Sync + 'static`,
//! * the [`Context`] trait (`.context(..)` / `.with_context(..)`) on
//!   both `Result` and `Option`,
//! * the `anyhow!`, `bail!` and `ensure!` macros.
//!
//! Errors are flattened to a single message string with `:`-joined
//! context, matching how this repo formats and asserts on errors. The
//! crate can be replaced by the real `anyhow` without source changes.

use std::fmt::{self, Debug, Display};

/// A flattened error: message plus any context prepended by
/// [`Context::context`] / [`Context::with_context`].
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message (`anyhow::Error::msg`).
    pub fn msg<M: Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }

    fn wrap<C: Display>(mut self, context: C) -> Self {
        self.msg = format!("{context}: {}", self.msg);
        self
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow: `Error` itself does not implement `std::error::Error`,
// which is what keeps this blanket impl coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Self { msg }
    }
}

/// `anyhow::Result`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    pub trait Sealed {}
    impl<T, E> Sealed for std::result::Result<T, E> {}
    impl<T> Sealed for Option<T> {}

    /// Conversion into [`crate::Error`] for the `Context` impls. Two
    /// coherent impls: every std error, and `Error` itself (which does
    /// not implement `std::error::Error`, so they never overlap).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Attach context to errors (and to `None`), as in `anyhow`.
pub trait Context<T, E>: private::Sealed {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: private::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| private::IntoError::into_error(e).wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| private::IntoError::into_error(e).wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/anyhow-shim-test")
            .context("reading test file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_and_context_chain() {
        let err = io_fail().unwrap_err();
        let msg = err.to_string();
        assert!(msg.starts_with("reading test file: "), "{msg}");
    }

    #[test]
    fn option_context_and_with_context() {
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let none: Option<u8> = None;
        let err = none.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(err.to_string(), "missing 7");
        assert_eq!(Some(3u8).context("unused").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 42));
        let err = r.context("outer").unwrap_err();
        assert_eq!(err.to_string(), "outer: inner 42");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too large: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("negative input -1"));
        assert!(f(101).unwrap_err().to_string().contains("too large: 101"));
    }
}
