//! Compile-time stub of the `xla` (xla_extension 0.5.1) wrapper crate.
//!
//! The container image does not ship the xla_extension shared library or
//! its Rust bindings, so this in-repo crate keeps the PJRT runtime layer
//! *compiling* while making its unavailability explicit at runtime:
//!
//! * [`Literal`] is a real host-side implementation (shape + bytes), so
//!   literal construction/readback round-trips work exactly as with the
//!   native crate;
//! * [`PjRtClient::compile`] and everything downstream of it return a
//!   descriptive [`Error`] — callers (the `sparq` coordinator and its
//!   artifact-gated tests) treat that as "PJRT backend unavailable".
//!
//! Swap the `xla` path dependency in `rust/Cargo.toml` for the real
//! bindings to enable the PJRT execution path; no source changes needed.

use std::fmt::{self, Display};

/// Stub error; implements `std::error::Error` so `anyhow` context works.
#[derive(Debug)]
pub struct Error(pub String);

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Marker carried by every stub-unavailability error. Artifact-gated
/// tests match on it via `sparq::runtime::PJRT_STUB_MARKER` (they
/// cannot reference this const — the real xla crate lacks it, and the
/// swap must stay manifest-only); keep the two strings identical.
pub const STUB_UNAVAILABLE: &str = "xla_extension is not available in this offline build";

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: {STUB_UNAVAILABLE}; the PJRT path is disabled \
         (the native engine in sparq::model is fully functional)"
    ))
}

/// Element types used by this repo's artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F32,
    F64,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            Self::Pred | Self::S8 | Self::U8 => 1,
            Self::S32 | Self::U32 | Self::F32 => 4,
            Self::S64 | Self::F64 => 8,
        }
    }
}

/// Host types readable out of a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().unwrap())
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes(bytes.try_into().unwrap())
    }
}

impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
    fn from_le(bytes: &[u8]) -> Self {
        f64::from_le_bytes(bytes.try_into().unwrap())
    }
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn from_le(bytes: &[u8]) -> Self {
        bytes[0]
    }
}

/// Array shape: dimensions + element type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side literal: a shaped, typed byte buffer (fully functional).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Self> {
        let elems: usize = dims.iter().product();
        if elems * ty.byte_size() != untyped_data.len() {
            return Err(Error(format!(
                "literal data length {} does not match shape {dims:?} of {ty:?}",
                untyped_data.len()
            )));
        }
        Ok(Self {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: untyped_data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!("literal is {:?}, requested {:?}", self.ty, T::TY)));
        }
        Ok(self.bytes.chunks_exact(self.ty.byte_size()).map(T::from_le).collect())
    }

    /// Stub literals are never tuples (tuples only come back from PJRT
    /// execution, which the stub cannot perform).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("decomposing tuple literal"))
    }
}

/// Parsed HLO module handle (stub: verifies the file is readable).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(Self(()))
    }
}

/// Computation handle (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

/// PJRT client (stub: construction succeeds, compilation errors).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self(()))
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (xla_extension unavailable)".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling HLO"))
    }
}

/// Compiled executable (stub: unreachable in practice, since `compile`
/// always errors; `execute` errors defensively anyway).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetching result buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<f32> = (0..6).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes)
                .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
    }

    #[test]
    fn literal_rejects_bad_length_and_type() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 7])
                .is_err()
        );
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[1],
            &42i32.to_le_bytes(),
        )
        .unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![42]);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn pjrt_stub_fails_loudly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let comp = XlaComputation::from_proto(&HloModuleProto(()));
        let err = client.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("xla_extension is not available"), "{err}");
    }
}
