//! Zero-dependency readiness loop for event-driven servers.
//!
//! The container image carries no crates.io registry, so the HTTP front
//! door cannot pull `mio` or `tokio`. This crate vendors the thin slice
//! of readiness polling the serving stack actually needs, in the same
//! offline-shim spirit as `crates/anyhow`:
//!
//! * [`Poller`] — register file descriptors with a `u64` token and an
//!   [`Interest`] (readable / writable), then [`Poller::wait`] for
//!   [`Event`]s, level-triggered.
//! * On Linux the backend is **epoll** (`epoll_create1` /`epoll_ctl` /
//!   `epoll_wait` via the libc symbols std already links). Everywhere
//!   else — and as a runtime fallback if `epoll_create1` fails — it is
//!   portable **`poll(2)`**, which rebuilds its descriptor array per
//!   wait; fine at front-door connection counts.
//!
//! Semantics are deliberately level-triggered on both backends so the
//! caller may ignore an event and see it again on the next wait.
//! `EPOLLERR`/`EPOLLHUP` (and `POLLERR`/`POLLHUP`/`POLLNVAL`) are
//! reported as both readable and writable, so whichever direction the
//! caller services next observes the failure from the socket itself.
//!
//! The poller never owns the descriptors: callers register borrowed raw
//! fds and must [`Poller::deregister`] before closing them (the poll
//! backend has no kernel-side cleanup on close).

#![cfg(unix)]

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// Which readiness directions a registration asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READABLE: Self = Self { readable: true, writable: false };
    pub const WRITABLE: Self = Self { readable: false, writable: true };
    pub const BOTH: Self = Self { readable: true, writable: true };
}

/// One readiness notification from [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token supplied at registration.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Convert a wait timeout to the millisecond argument `epoll_wait` and
/// `poll` share: `None` blocks indefinitely; sub-millisecond non-zero
/// durations round **up** to 1ms so a short timeout never busy-spins.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis();
            if ms == 0 && d.as_nanos() > 0 {
                1
            } else {
                ms.min(i32::MAX as u128) as i32
            }
        }
    }
}

#[cfg(target_os = "linux")]
mod epoll {
    use super::{Event, Interest};
    use std::io;
    use std::os::unix::io::RawFd;

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;

    /// Mirror of the kernel's `struct epoll_event`; packed on x86_64
    /// only, exactly as the kernel (and libc) declare it.
    #[derive(Clone, Copy)]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = 0;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    pub(crate) struct Epoll {
        epfd: RawFd,
        buf: Vec<EpollEvent>,
    }

    impl Epoll {
        pub(crate) fn new() -> io::Result<Self> {
            // SAFETY: epoll_create1 takes no pointers; the flag is a
            // valid kernel constant and the return value is checked.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Self { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 256] })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            // EPOLL_CTL_DEL ignores the event argument on modern
            // kernels but pre-2.6.9 ones reject a null pointer, so a
            // real struct is always passed.
            let mut ev = EpollEvent { events: mask(interest), data: token };
            // SAFETY: `ev` is a live, properly-initialized #[repr(C,
            // packed)] EpollEvent for the duration of the call; the
            // kernel only reads it. epfd/fd validity is the kernel's to
            // check (bad fds surface as EBADF, handled below).
            if unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub(crate) fn register(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub(crate) fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub(crate) fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, Interest { readable: false, writable: false })
        }

        pub(crate) fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            loop {
                // SAFETY: `buf` is an initialized Vec whose length is
                // passed as maxevents, so the kernel writes at most
                // `buf.len()` EpollEvent structs into owned memory; the
                // borrow of `self.buf` outlives the call.
                let n = unsafe {
                    epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, timeout_ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e);
                }
                for &ev in self.buf.iter().take(n as usize) {
                    let bits = ev.events;
                    let broken = bits & (EPOLLERR | EPOLLHUP) != 0;
                    out.push(Event {
                        token: ev.data,
                        readable: bits & EPOLLIN != 0 || broken,
                        writable: bits & EPOLLOUT != 0 || broken,
                    });
                }
                return Ok(());
            }
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            // SAFETY: epfd was returned by epoll_create1 and is owned
            // exclusively by this struct — nothing else closes it, so
            // this cannot double-close or free another thread's fd.
            unsafe {
                close(self.epfd);
            }
        }
    }
}

mod poll_backend {
    use super::{Event, Interest};
    use std::io;
    use std::os::unix::io::RawFd;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const POLLNVAL: i16 = 0x020;

    /// `nfds_t` is `c_ulong` on Linux and `u32` on the BSD-derived
    /// platforms this fallback otherwise targets.
    #[cfg(target_os = "linux")]
    type Nfds = core::ffi::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type Nfds = u32;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: Nfds, timeout: i32) -> i32;
    }

    struct Registration {
        fd: RawFd,
        token: u64,
        interest: Interest,
    }

    fn mask(interest: Interest) -> i16 {
        let mut m = 0;
        if interest.readable {
            m |= POLLIN;
        }
        if interest.writable {
            m |= POLLOUT;
        }
        m
    }

    #[derive(Default)]
    pub(crate) struct PollSet {
        entries: Vec<Registration>,
        /// Scratch `pollfd` array rebuilt per wait (lives here so the
        /// steady state allocates nothing).
        fds: Vec<PollFd>,
    }

    impl PollSet {
        pub(crate) fn new() -> Self {
            Self::default()
        }

        fn position(&self, fd: RawFd) -> Option<usize> {
            self.entries.iter().position(|e| e.fd == fd)
        }

        pub(crate) fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            if self.position(fd).is_some() {
                return Err(io::Error::new(
                    io::ErrorKind::AlreadyExists,
                    format!("fd {fd} is already registered"),
                ));
            }
            self.entries.push(Registration { fd, token, interest });
            Ok(())
        }

        pub(crate) fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            match self.position(fd) {
                Some(i) => {
                    self.entries[i].token = token;
                    self.entries[i].interest = interest;
                    Ok(())
                }
                None => Err(io::Error::new(
                    io::ErrorKind::NotFound,
                    format!("fd {fd} is not registered"),
                )),
            }
        }

        pub(crate) fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            match self.position(fd) {
                Some(i) => {
                    self.entries.swap_remove(i);
                    Ok(())
                }
                None => Err(io::Error::new(
                    io::ErrorKind::NotFound,
                    format!("fd {fd} is not registered"),
                )),
            }
        }

        pub(crate) fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            self.fds.clear();
            for e in &self.entries {
                self.fds.push(PollFd { fd: e.fd, events: mask(e.interest), revents: 0 });
            }
            loop {
                // SAFETY: `fds` was rebuilt above as a Vec of
                // #[repr(C)] PollFd, so the pointer/length pair passed
                // to poll(2) describes exactly the owned, initialized
                // array the kernel reads and writes revents into.
                let n = unsafe {
                    poll(self.fds.as_mut_ptr(), self.fds.len() as Nfds, timeout_ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e);
                }
                break;
            }
            for (e, p) in self.entries.iter().zip(&self.fds) {
                let r = p.revents;
                if r == 0 {
                    continue;
                }
                let broken = r & (POLLERR | POLLHUP | POLLNVAL) != 0;
                out.push(Event {
                    token: e.token,
                    readable: r & POLLIN != 0 || broken,
                    writable: r & POLLOUT != 0 || broken,
                });
            }
            Ok(())
        }
    }
}

enum Backend {
    #[cfg(target_os = "linux")]
    Epoll(epoll::Epoll),
    Poll(poll_backend::PollSet),
}

/// Level-triggered readiness poller over borrowed raw fds.
pub struct Poller {
    backend: Backend,
}

impl Poller {
    /// Best backend for the platform: epoll on Linux (falling back to
    /// `poll(2)` if `epoll_create1` itself fails), `poll(2)` elsewhere.
    pub fn new() -> io::Result<Self> {
        #[cfg(target_os = "linux")]
        if let Ok(ep) = epoll::Epoll::new() {
            return Ok(Self { backend: Backend::Epoll(ep) });
        }
        Ok(Self::with_poll_backend())
    }

    /// Force the portable `poll(2)` backend (exercised by tests and the
    /// `HttpConfig::use_poll_fallback` escape hatch).
    pub fn with_poll_backend() -> Self {
        Self { backend: Backend::Poll(poll_backend::PollSet::new()) }
    }

    /// Which backend this poller runs on: `"epoll"` or `"poll"`.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(_) => "epoll",
            Backend::Poll(_) => "poll",
        }
    }

    /// Start watching `fd` with the given token and interest. The fd
    /// must stay open until [`Poller::deregister`].
    pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(ep) => ep.register(fd, token, interest),
            Backend::Poll(ps) => ps.register(fd, token, interest),
        }
    }

    /// Replace the token/interest of an already registered fd.
    pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(ep) => ep.modify(fd, token, interest),
            Backend::Poll(ps) => ps.modify(fd, token, interest),
        }
    }

    /// Stop watching `fd`. Call before closing the descriptor.
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(ep) => ep.deregister(fd),
            Backend::Poll(ps) => ps.deregister(fd),
        }
    }

    /// Clear `events` and fill it with whatever is ready, blocking up
    /// to `timeout` (`None` = indefinitely). Returns with an empty vec
    /// on timeout. `EINTR` retries internally.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        let ms = timeout_ms(timeout);
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(ep) => ep.wait(events, ms),
            Backend::Poll(ps) => ps.wait(events, ms),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Instant;

    /// Wait (with a deadline) until an event for `token` shows up;
    /// panics on timeout so a broken backend fails loudly.
    fn wait_for(p: &mut Poller, token: u64, want_read: bool, want_write: bool) -> Event {
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut events = Vec::new();
        while Instant::now() < deadline {
            p.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
            for ev in &events {
                if ev.token == token
                    && (!want_read || ev.readable)
                    && (!want_write || ev.writable)
                {
                    return *ev;
                }
            }
        }
        panic!("no event for token {token} within deadline ({})", p.backend_name());
    }

    fn backends() -> Vec<Poller> {
        let mut v = vec![Poller::with_poll_backend()];
        #[cfg(target_os = "linux")]
        v.push(Poller::new().unwrap());
        v
    }

    #[test]
    fn listener_becomes_readable_on_pending_connection() {
        for mut p in backends() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            listener.set_nonblocking(true).unwrap();
            p.register(listener.as_raw_fd(), 7, Interest::READABLE).unwrap();

            // nothing pending: a short wait times out empty
            let mut events = Vec::new();
            p.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert!(events.is_empty(), "spurious event on idle listener");

            let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let ev = wait_for(&mut p, 7, true, false);
            assert!(ev.readable);
            p.deregister(listener.as_raw_fd()).unwrap();
        }
    }

    #[test]
    fn stream_readable_and_writable_transitions() {
        for mut p in backends() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (mut served, _) = listener.accept().unwrap();
            served.set_nonblocking(true).unwrap();

            // A fresh connected socket has send-buffer space: writable.
            p.register(served.as_raw_fd(), 1, Interest::BOTH).unwrap();
            let ev = wait_for(&mut p, 1, false, true);
            assert!(ev.writable, "connected socket should be writable ({})", p.backend_name());

            // Not readable until the peer sends something.
            p.modify(served.as_raw_fd(), 1, Interest::READABLE).unwrap();
            let mut events = Vec::new();
            p.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert!(events.is_empty(), "readable before any data ({})", p.backend_name());

            client.write_all(b"ping").unwrap();
            let ev = wait_for(&mut p, 1, true, false);
            assert!(ev.readable);
            let mut buf = [0u8; 8];
            assert_eq!(served.read(&mut buf).unwrap(), 4);

            // Level-triggered + drained: quiet again until the peer
            // closes, which must surface as readable (EOF).
            p.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
            assert!(events.is_empty(), "event after drain ({})", p.backend_name());
            drop(client);
            let ev = wait_for(&mut p, 1, true, false);
            assert!(ev.readable, "peer close must read as EOF readiness");
            p.deregister(served.as_raw_fd()).unwrap();
        }
    }

    #[test]
    fn deregistered_fd_stays_silent() {
        for mut p in backends() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            listener.set_nonblocking(true).unwrap();
            p.register(listener.as_raw_fd(), 3, Interest::READABLE).unwrap();
            p.deregister(listener.as_raw_fd()).unwrap();
            let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let mut events = Vec::new();
            p.wait(&mut events, Some(Duration::from_millis(30))).unwrap();
            assert!(events.is_empty(), "deregistered fd produced events");
            // double deregister is a clean error, not UB or a panic
            assert!(p.deregister(listener.as_raw_fd()).is_err());
        }
    }

    #[test]
    fn timeout_returns_promptly_when_idle() {
        for mut p in backends() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            p.register(listener.as_raw_fd(), 1, Interest::READABLE).unwrap();
            let t0 = Instant::now();
            let mut events = Vec::new();
            p.wait(&mut events, Some(Duration::from_millis(25))).unwrap();
            let waited = t0.elapsed();
            assert!(events.is_empty());
            assert!(waited >= Duration::from_millis(15), "returned too early: {waited:?}");
            assert!(waited < Duration::from_secs(5), "timeout overshot wildly: {waited:?}");
        }
    }
}
