//! Bench: the §5.1 / F2 bit-toggle statistics over real traced
//! activations (paper: bits 7..4 toggle 0.5/9.2/33.8/44.8%; >= 1 MSB
//! toggled 67%; top-2 quiet 90%), plus trace throughput.

include!("harness.rs");

use std::path::PathBuf;

use sparq::coordinator::calibrate;
use sparq::data::Dataset;
use sparq::experiments::toggle_stats;
use sparq::model::{Graph, Weights};
use sparq::runtime::{Manifest, PjrtRuntime};

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (manifest, eval, calib_ds) = match (
        Manifest::load(&dir),
        Dataset::load(&dir.join("test.bin")),
        Dataset::load(&dir.join("train.bin")),
    ) {
        (Ok(m), Ok(e), Ok(c)) => (m, e, c),
        _ => {
            eprintln!("skipping (run `make artifacts`)");
            return;
        }
    };
    let rt = PjrtRuntime::cpu().expect("pjrt");
    println!("model        zero-frac  b7     b6     b5     b4     any-MSB  top2-quiet  pair-zero");
    for tag in manifest.dense_tags().iter().map(|s| s.to_string()) {
        let model = manifest.get(&tag).unwrap();
        let graph = Graph::load(&model.meta_path()).unwrap();
        let weights = Weights::load(&model.weights_path()).unwrap();
        let scales = calibrate(&rt, model, &calib_ds, 64, 256).unwrap().scales();
        let t0 = std::time::Instant::now();
        let ts = toggle_stats(&graph, &weights, &eval, &scales, 128, 32).unwrap();
        println!(
            "{:<12} {:>8.3}  {:.3}  {:.3}  {:.3}  {:.3}  {:>7.3}  {:>10.3}  {:>9.3}   ({:.1}s)",
            tag,
            ts.zero_fraction(),
            ts.bit_prob(7),
            ts.bit_prob(6),
            ts.bit_prob(5),
            ts.bit_prob(4),
            ts.any_msb_prob(),
            ts.top2_quiet_prob(),
            ts.pair_zero_prob(),
            t0.elapsed().as_secs_f64(),
        );
    }
    println!("paper:ResNet-18     -  0.005  0.092  0.338  0.448    0.670       0.900          -");
}
