//! Bench: regenerates paper Table 1 (FP32 / A8W8 / A4W8 / A8W4 top-1)
//! end-to-end through the PJRT path, and times the per-config eval.
//!
//! Run: `cargo bench --bench table1_quant_grid [-- eval-limit]`

include!("harness.rs");

use std::path::PathBuf;

use sparq::experiments::{table1, ExperimentCtx};

fn main() {
    let limit: usize = std::env::args()
        .skip_while(|a| a != "--")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut ctx = match ExperimentCtx::new(&dir, limit, 1024) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e}");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    let table = table1(&mut ctx).expect("table1");
    println!("{}", table.render());
    println!(
        "table1: {} models x 4 precisions over {limit} images in {:.1}s",
        table.rows.len(),
        t0.elapsed().as_secs_f64()
    );
}
