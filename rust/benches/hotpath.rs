//! Bench: the L3 hot paths in isolation — the inputs to the §Perf
//! optimization loop in EXPERIMENTS.md.
//!
//! Sections (none need artifacts except the final PJRT one):
//!
//! 1. trim+dot microbench — scalar reference vs the 256-entry LUT;
//! 2. quantized GEMM before/after — the seed's naive single-threaded
//!    kernel vs the cache-blocked kernel, serial and row-parallel;
//! 3. end-to-end native forward on a synthetic 4-conv model — engine at
//!    1 thread vs all cores, with reused scratch (the serving shape);
//! 4. PJRT end-to-end batch latency (skipped when artifacts/xla absent).
//!
//! Run with `cargo bench --bench hotpath`; set `SPARQ_THREADS` to pin
//! the parallel sections.

include!("harness.rs");

use std::collections::HashMap;
use std::path::PathBuf;

use sparq::model::{Engine, EngineMode, Graph, Node, Op, QuantGemm, Scratch, Weights};
use sparq::model::threadpool;
use sparq::model::weights::{FloatConv, QuantConv};
use sparq::quant::vsparq::sparq_dot;
use sparq::quant::{SparqConfig, TrimLut};
use sparq::runtime::{ArtifactKind, Manifest, PjrtRuntime, TensorArg};

fn main() {
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let k = 1152usize; // largest zoo reduction (64ch * 3x3 * 2)
    let acts = synth_acts(k, 40);
    let weights = synth_weights(k);

    // 1. trim+dot microbench: scalar reference vs LUT
    let lut = TrimLut::new(cfg);
    bench("sparq_dot scalar (K=1152)", 2000, || {
        std::hint::black_box(sparq_dot(&acts, &weights, cfg));
    });
    bench("sparq_dot LUT    (K=1152)", 2000, || {
        std::hint::black_box(lut.dot(&acts, &weights));
    });

    // 2. GEMM before/after: naive (the seed path) vs blocked serial vs
    // blocked parallel — all bit-identical, only speed differs.
    let (m, n) = (400, 64);
    let a = synth_acts(m * k, 40);
    let w = synth_weights(k * n);
    let gemm = QuantGemm::new(cfg);
    let wt = gemm.prepare_weights(&w, k, n);
    let mut scratch_rows = a.clone();
    let mut out = vec![0i32; m * n];
    let mut pack = Vec::new();
    let macs = (m * k * n) as f64;
    let gmacs = |r: &BenchResult| macs / (r.median_us * 1e-6) / 1e9;

    let r_naive = bench("GEMM 400x1152x64 naive (seed)", 20, || {
        scratch_rows.copy_from_slice(&a);
        gemm.gemm_naive(&mut scratch_rows, m, k, &wt, n, &mut out);
        std::hint::black_box(&out);
    });
    println!("    -> {:.2} GMAC/s", gmacs(&r_naive));
    let reference = out.clone();

    let r_serial = bench("GEMM 400x1152x64 blocked 1 thread", 20, || {
        scratch_rows.copy_from_slice(&a);
        gemm.gemm_with(&mut scratch_rows, m, k, &wt, n, &mut out, &mut pack, 1);
        std::hint::black_box(&out);
    });
    println!("    -> {:.2} GMAC/s", gmacs(&r_serial));
    assert_eq!(out, reference, "blocked serial GEMM diverged from naive");

    let nt = threadpool::max_threads();
    let r_par = bench("GEMM 400x1152x64 blocked parallel", 20, || {
        scratch_rows.copy_from_slice(&a);
        gemm.gemm_with(&mut scratch_rows, m, k, &wt, n, &mut out, &mut pack, nt);
        std::hint::black_box(&out);
    });
    println!("    -> {:.2} GMAC/s ({nt} threads)", gmacs(&r_par));
    assert_eq!(out, reference, "blocked parallel GEMM diverged from naive");
    println!(
        "    => GEMM speedup vs seed: {:.2}x serial, {:.2}x parallel",
        r_naive.median_us / r_serial.median_us,
        r_naive.median_us / r_par.median_us
    );

    // 3. end-to-end native forward on a synthetic model (no artifacts)
    let (graph, wts, scales) = synth_model();
    let batch = 32;
    let img: Vec<f32> = (0..batch * 20 * 20 * 3)
        .map(|i| ((i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33) as f32 % 251.0 / 251.0)
        .collect();
    let mut engine = Engine::new(&graph, &wts, cfg, &scales, EngineMode::Dense).unwrap();
    let mut scratch = Scratch::default();

    engine.set_threads(1);
    let r_e2e_1 = bench("native fwd batch-32 1 thread", 15, || {
        std::hint::black_box(engine.forward_scratch(&img, batch, &mut scratch).unwrap());
    });
    println!("    -> {:.1} img/s", batch as f64 / (r_e2e_1.median_us * 1e-6));

    engine.set_threads(nt);
    let r_e2e_n = bench("native fwd batch-32 parallel", 15, || {
        std::hint::black_box(engine.forward_scratch(&img, batch, &mut scratch).unwrap());
    });
    println!("    -> {:.1} img/s ({nt} threads)", batch as f64 / (r_e2e_n.median_us * 1e-6));
    println!(
        "    => end-to-end forward speedup 1 -> {nt} threads: {:.2}x",
        r_e2e_1.median_us / r_e2e_n.median_us
    );

    // 4. PJRT end-to-end batch (compile once, then per-batch latency)
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(manifest) => pjrt_section(&manifest, cfg),
        Err(_) => eprintln!("artifacts missing; PJRT section skipped"),
    }
}

/// Synthetic 4-layer model shaped like the zoo's resnet10 stem: float
/// stem conv + two quantized convs + gap + fc. Weights are the shared
/// deterministic generators, so runs are comparable across builds.
fn synth_model() -> (Graph, Weights, Vec<f32>) {
    let graph = Graph {
        arch: "bench".into(),
        variant: "synthetic".into(),
        num_classes: 10,
        input_hwc: [20, 20, 3],
        eval_batch: 32,
        quant_convs: vec!["q1".into(), "q2".into()],
        nodes: vec![
            Node { name: "img".into(), op: Op::Input, inputs: vec![] },
            Node {
                name: "c1".into(),
                op: Op::Conv { k: 3, stride: 1, out_ch: 16, relu: true, quant: false },
                inputs: vec!["img".into()],
            },
            Node {
                name: "q1".into(),
                op: Op::Conv { k: 3, stride: 2, out_ch: 32, relu: true, quant: true },
                inputs: vec!["c1".into()],
            },
            Node {
                name: "q2".into(),
                op: Op::Conv { k: 3, stride: 1, out_ch: 64, relu: true, quant: true },
                inputs: vec!["q1".into()],
            },
            Node { name: "g".into(), op: Op::Gap, inputs: vec!["q2".into()] },
            Node { name: "fc".into(), op: Op::Fc { out: 10 }, inputs: vec!["g".into()] },
        ],
    };
    let mut float = HashMap::new();
    let c1_len = 3 * 3 * 3 * 16;
    float.insert(
        "c1".to_string(),
        FloatConv {
            w: synth_weights(c1_len).iter().map(|&v| f32::from(v) / 400.0).collect(),
            kh: 3,
            kw: 3,
            c_in: 3,
            c_out: 16,
            bias: vec![0.01; 16],
        },
    );
    let mut quant = HashMap::new();
    quant.insert(
        "q1".to_string(),
        QuantConv {
            wq: synth_weights(16 * 9 * 32),
            k: 16 * 9,
            o: 32,
            scale: vec![0.002; 32],
            bias: vec![0.0; 32],
        },
    );
    quant.insert(
        "q2".to_string(),
        QuantConv {
            wq: synth_weights(32 * 9 * 64),
            k: 32 * 9,
            o: 64,
            scale: vec![0.002; 64],
            bias: vec![0.0; 64],
        },
    );
    let fc_len = 64 * 10;
    let weights = Weights {
        quant,
        float,
        fc_w: synth_weights(fc_len).iter().map(|&v| f32::from(v) / 127.0).collect(),
        fc_in: 64,
        fc_out: 10,
        fc_b: vec![0.0; 10],
    };
    (graph, weights, vec![0.02, 0.02])
}

fn pjrt_section(manifest: &Manifest, cfg: SparqConfig) {
    let rt = match PjrtRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT unavailable ({e}); section skipped");
            return;
        }
    };
    let model = match manifest.get("resnet10") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("resnet10 not in manifest ({e}); section skipped");
            return;
        }
    };
    let exe = match rt.load(&model.hlo_path(ArtifactKind::Sparq)) {
        Ok(exe) => exe,
        Err(e) => {
            eprintln!("PJRT compile unavailable ({e}); section skipped");
            return;
        }
    };
    let nq = model.quant_convs;
    let img: Vec<f32> = (0..64 * 20 * 20 * 3).map(|i| (i % 251) as f32 / 251.0).collect();
    let scales = vec![0.03f32; nq];
    let cfg_vec = cfg.to_vec().to_vec();
    let r = bench("PJRT sparq batch-64 fwd (resnet10)", 20, || {
        let out = exe
            .run(&[
                TensorArg::f32(&[64, 20, 20, 3], img.clone()),
                TensorArg::f32(&[nq], scales.clone()),
                TensorArg::i32(&[5], cfg_vec.clone()),
            ])
            .unwrap();
        std::hint::black_box(out);
    });
    println!("    -> {:.1} img/s", 64.0 / (r.median_us * 1e-6));
    match rt.load(&model.hlo_path(ArtifactKind::Float)) {
        Ok(fexe) => {
            let r = bench("PJRT float batch-64 fwd (resnet10)", 20, || {
                let out =
                    fexe.run(&[TensorArg::f32(&[64, 20, 20, 3], img.clone())]).unwrap();
                std::hint::black_box(out);
            });
            println!("    -> {:.1} img/s", 64.0 / (r.median_us * 1e-6));
        }
        Err(e) => eprintln!("float artifact unavailable ({e}); float row skipped"),
    }
}
