//! Bench: the L3 hot paths in isolation — the inputs to the §Perf
//! optimization loop in EXPERIMENTS.md.
//!
//! Sections (none need artifacts except the final PJRT one):
//!
//! 1. trim+dot microbench — scalar reference vs the 256-entry LUT;
//! 2. quantized GEMM before/after — the seed's naive single-threaded
//!    kernel vs the cache-blocked kernel, serial and row-parallel;
//! 3. end-to-end native forward on a synthetic 4-conv model — engine at
//!    1 thread vs all cores, with reused scratch (the serving shape);
//! 4. per-layer quantization policies end-to-end — uniform A8W8 vs
//!    uniform 4-bit vs first/last-at-8-bit, img/s + footprint
//!    bits/activation (the cost of per-layer LUT selection in the hot
//!    loop);
//! 5. sharded serving router over the same model: 1 vs N single-thread
//!    replica shards sharing one Arc'd parameter copy, under concurrent
//!    client load (img/s);
//! 6. the HTTP front door over that router: keep-alive TcpStream
//!    clients through the single event-loop thread vs the in-process
//!    router path (req/s — the network edge's overhead);
//! 7. PJRT end-to-end batch latency (skipped when artifacts/xla absent).
//!
//! Run with `cargo bench --bench hotpath`; set `SPARQ_THREADS` to pin
//! the parallel sections. Set `SPARQ_BENCH_JSON=<path>` to also write
//! the measured sections as a `sparq-bench/1` report
//! (`sparq::observability`) — the same schema `serve_bench
//! --bench-json` emits and `--check-budgets` gates CI on.

include!("harness.rs");

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use sparq::coordinator::{BatchPolicy, HttpConfig, HttpServer, InferenceRouter, LatencyHist};
use sparq::json_obj;
use sparq::model::demo::synth_model;
use sparq::model::threadpool;
use sparq::model::{Engine, EngineMode, ModelParams, QuantGemm, Scratch};
use sparq::observability::{BenchReport, BenchSection, QueueStats};
use sparq::quant::footprint::report_bits;
use sparq::quant::vsparq::sparq_dot;
use sparq::quant::{SparqConfig, TrimLut};
use sparq::runtime::{ArtifactKind, Manifest, PjrtRuntime, TensorArg};

/// Append a section when `SPARQ_BENCH_JSON` asked for a report.
fn emit(report: &mut Option<(PathBuf, BenchReport)>, sec: BenchSection) {
    if let Some((_, r)) = report.as_mut() {
        r.push(sec);
    }
}

fn main() {
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let mut report: Option<(PathBuf, BenchReport)> =
        std::env::var("SPARQ_BENCH_JSON").ok().map(|p| (PathBuf::from(p), BenchReport::new()));
    let bits = report_bits(cfg);
    let k = 1152usize; // largest zoo reduction (64ch * 3x3 * 2)
    let acts = synth_acts(k, 40);
    let weights = synth_weights(k);

    // 1. trim+dot microbench: scalar reference vs LUT
    let lut = TrimLut::new(cfg);
    bench("sparq_dot scalar (K=1152)", 2000, || {
        std::hint::black_box(sparq_dot(&acts, &weights, cfg));
    });
    bench("sparq_dot LUT    (K=1152)", 2000, || {
        std::hint::black_box(lut.dot(&acts, &weights));
    });

    // 2. GEMM before/after: naive (the seed path) vs blocked serial vs
    // blocked parallel — all bit-identical, only speed differs.
    let (m, n) = (400, 64);
    let a = synth_acts(m * k, 40);
    let w = synth_weights(k * n);
    let gemm = QuantGemm::new(cfg);
    let wt = gemm.prepare_weights(&w, k, n);
    let mut scratch_rows = a.clone();
    let mut out = vec![0i32; m * n];
    let mut pack = Vec::new();
    let macs = (m * k * n) as f64;
    let gmacs = |r: &BenchResult| macs / (r.median_us * 1e-6) / 1e9;

    let r_naive = bench("GEMM 400x1152x64 naive (seed)", 20, || {
        scratch_rows.copy_from_slice(&a);
        gemm.gemm_naive(&mut scratch_rows, m, k, &wt, n, &mut out);
        std::hint::black_box(&out);
    });
    println!("    -> {:.2} GMAC/s", gmacs(&r_naive));
    let reference = out.clone();
    emit(
        &mut report,
        BenchSection {
            gmac_per_s: gmacs(&r_naive),
            p50_us: r_naive.median_us,
            p99_us: r_naive.p99_us,
            bits_per_act: bits,
            ..BenchSection::new("kernel_naive")
        },
    );

    let r_serial = bench("GEMM 400x1152x64 blocked 1 thread", 20, || {
        scratch_rows.copy_from_slice(&a);
        gemm.gemm_with(&mut scratch_rows, m, k, &wt, n, &mut out, &mut pack, 1);
        std::hint::black_box(&out);
    });
    println!("    -> {:.2} GMAC/s", gmacs(&r_serial));
    assert_eq!(out, reference, "blocked serial GEMM diverged from naive");
    emit(
        &mut report,
        BenchSection {
            gmac_per_s: gmacs(&r_serial),
            p50_us: r_serial.median_us,
            p99_us: r_serial.p99_us,
            bits_per_act: bits,
            ..BenchSection::new("kernel_blocked_1t")
        },
    );

    let nt = threadpool::max_threads();
    let r_par = bench("GEMM 400x1152x64 blocked parallel", 20, || {
        scratch_rows.copy_from_slice(&a);
        gemm.gemm_with(&mut scratch_rows, m, k, &wt, n, &mut out, &mut pack, nt);
        std::hint::black_box(&out);
    });
    println!("    -> {:.2} GMAC/s ({nt} threads)", gmacs(&r_par));
    assert_eq!(out, reference, "blocked parallel GEMM diverged from naive");
    emit(
        &mut report,
        BenchSection {
            gmac_per_s: gmacs(&r_par),
            p50_us: r_par.median_us,
            p99_us: r_par.p99_us,
            bits_per_act: bits,
            ..BenchSection::new("kernel_blocked_mt")
        },
    );
    println!(
        "    => GEMM speedup vs seed: {:.2}x serial, {:.2}x parallel",
        r_naive.median_us / r_serial.median_us,
        r_naive.median_us / r_par.median_us
    );

    // 3. end-to-end native forward on a synthetic model (no artifacts)
    let (graph, wts, scales) = synth_model();
    let batch = 32;
    let img: Vec<f32> = (0..batch * 20 * 20 * 3)
        .map(|i| ((i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33) as f32 % 251.0 / 251.0)
        .collect();
    let mut engine = Engine::new(&graph, &wts, cfg, &scales, EngineMode::Dense).unwrap();
    let mut scratch = Scratch::default();

    engine.set_threads(1);
    let r_e2e_1 = bench("native fwd batch-32 1 thread", 15, || {
        std::hint::black_box(engine.forward_scratch(&img, batch, &mut scratch).unwrap());
    });
    println!("    -> {:.1} img/s", batch as f64 / (r_e2e_1.median_us * 1e-6));
    emit(
        &mut report,
        BenchSection {
            img_per_s: batch as f64 / (r_e2e_1.median_us * 1e-6),
            p50_us: r_e2e_1.median_us,
            p99_us: r_e2e_1.p99_us,
            bits_per_act: bits,
            ..BenchSection::new("engine_fwd_1t")
        },
    );

    engine.set_threads(nt);
    let r_e2e_n = bench("native fwd batch-32 parallel", 15, || {
        std::hint::black_box(engine.forward_scratch(&img, batch, &mut scratch).unwrap());
    });
    println!("    -> {:.1} img/s ({nt} threads)", batch as f64 / (r_e2e_n.median_us * 1e-6));
    println!(
        "    => end-to-end forward speedup 1 -> {nt} threads: {:.2}x",
        r_e2e_1.median_us / r_e2e_n.median_us
    );
    emit(
        &mut report,
        BenchSection {
            img_per_s: batch as f64 / (r_e2e_n.median_us * 1e-6),
            p50_us: r_e2e_n.median_us,
            p99_us: r_e2e_n.p99_us,
            bits_per_act: bits,
            ..BenchSection::new("engine_fwd_mt")
        },
    );

    // 4. per-layer policies end-to-end: same engine/scratch shape as
    // section 3, but the policy decides each layer's LUT/weight table.
    // Shows the throughput cost of per-layer LUT selection (it should
    // be ~zero — selection is one hash lookup per conv, not per MAC)
    // next to the footprint each policy pays per activation.
    {
        use sparq::quant::QuantPolicy;
        let policies = [
            ("policy_a8w8", "uniform a8w8", QuantPolicy::named("a8w8").unwrap()),
            ("policy_a4w8", "uniform a4w8", QuantPolicy::named("a4w8").unwrap()),
            ("policy_edge8", "edge8 first/last@8", QuantPolicy::named("edge8").unwrap()),
        ];
        for (section, label, policy) in policies {
            let mut e =
                Engine::with_policy(&graph, &wts, policy, &scales, EngineMode::Dense).unwrap();
            e.set_threads(nt);
            let pbits = e.params().footprint_bits(1);
            let luts = e.params().distinct_configs();
            let mut sc = Scratch::default();
            let r = bench(&format!("policy fwd batch-32 {label}"), 15, || {
                std::hint::black_box(e.forward_scratch(&img, batch, &mut sc).unwrap());
            });
            println!(
                "    -> {:.1} img/s, {pbits:.2} bits/act, {luts} LUT(s)",
                batch as f64 / (r.median_us * 1e-6)
            );
            emit(
                &mut report,
                BenchSection {
                    img_per_s: batch as f64 / (r.median_us * 1e-6),
                    p50_us: r.median_us,
                    p99_us: r.p99_us,
                    bits_per_act: pbits,
                    ..BenchSection::new(section)
                },
            );
        }
    }

    // 5. sharded serving router: the same model behind 1 vs N replica
    // shards. Every shard is a single-threaded engine over one shared
    // Arc<ModelParams> (replicas ARE the parallelism), so the scaling
    // here is the router's, not the GEMM's.
    let params = Arc::new(
        ModelParams::new(
            Arc::new(graph.clone()),
            Arc::new(wts.clone()),
            cfg,
            &scales,
            EngineMode::Dense,
        )
        .unwrap(),
    );
    let single = img[..20 * 20 * 3].to_vec();
    let mut baseline_us = 0.0;
    let mut router_n_us = 0.0;
    let max_replicas = nt.max(2);
    for replicas in [1usize, max_replicas] {
        let router = Arc::new(
            InferenceRouter::builder()
                .model_with_threads(
                    "bench",
                    params.clone(),
                    replicas,
                    BatchPolicy {
                        max_batch: 8,
                        max_wait: Duration::from_micros(500),
                        ..BatchPolicy::default()
                    },
                    1,
                )
                .build()
                .unwrap(),
        );
        let clients = max_replicas * 2;
        let per = 48usize;
        let _ = router.infer("bench", single.clone()).unwrap(); // warmup
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..clients {
                let r = router.clone();
                let im = single.clone();
                s.spawn(move || {
                    for _ in 0..per {
                        r.infer("bench", im.clone()).unwrap();
                    }
                });
            }
        });
        let us = t0.elapsed().as_secs_f64() * 1e6;
        let total = (clients * per) as f64;
        println!(
            "router {replicas} replica(s) x 1-thread shards        {:>10.1} img/s \
             ({clients} clients x {per} reqs)",
            total / (us * 1e-6)
        );
        if replicas == 1 {
            baseline_us = us;
        } else {
            router_n_us = us;
            println!(
                "    => router throughput 1 -> {replicas} replicas: {:.2}x",
                baseline_us / us
            );
        }
        if report.is_some() {
            let section = if replicas == 1 {
                "router_1shard"
            } else {
                "router_mshard"
            };
            let m = router.metrics("bench").unwrap();
            let mut hist = LatencyHist::default();
            for sh in &m.shards {
                hist.merge(&sh.hist);
            }
            emit(
                &mut report,
                BenchSection {
                    img_per_s: total / (us * 1e-6),
                    p50_us: hist.quantile_us(0.50) as f64,
                    p99_us: hist.quantile_us(0.99) as f64,
                    queue: QueueStats::from_snapshot(&m.total),
                    bits_per_act: bits,
                    ..BenchSection::new(section)
                },
            );
        }
    }

    // 6. HTTP front door: the same sharded router behind the single
    // event-loop thread, driven by keep-alive TcpStream clients —
    // quantifies what the network edge costs over in-process dispatch.
    {
        let router = Arc::new(
            InferenceRouter::builder()
                .model_with_threads(
                    "bench",
                    params.clone(),
                    max_replicas,
                    BatchPolicy {
                        max_batch: 8,
                        max_wait: Duration::from_micros(500),
                        ..BatchPolicy::default()
                    },
                    1,
                )
                .build()
                .unwrap(),
        );
        let server =
            HttpServer::bind("127.0.0.1:0", router.clone(), HttpConfig::default()).unwrap();
        let addr = server.addr();
        let body = json_obj! {
            "image" => single.iter().map(|&v| f64::from(v)).collect::<Vec<f64>>()
        }
        .to_string();
        let raw: Arc<Vec<u8>> = Arc::new(
            format!(
                "POST /v1/infer/bench HTTP/1.1\r\nHost: b\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .into_bytes(),
        );
        // One response per request; responses are Content-Length framed.
        fn one_request(stream: &mut TcpStream, raw: &[u8], buf: &mut Vec<u8>) {
            stream.write_all(raw).unwrap();
            let head_end = loop {
                if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                    break i;
                }
                let mut chunk = [0u8; 4096];
                let n = stream.read(&mut chunk).unwrap();
                assert!(n > 0, "server closed mid-response");
                buf.extend_from_slice(&chunk[..n]);
            };
            let head = std::str::from_utf8(&buf[..head_end]).unwrap();
            assert!(head.starts_with("HTTP/1.1 200"), "bench request failed: {head}");
            let clen: usize = head
                .split("\r\n")
                .find_map(|l| l.strip_prefix("Content-Length: "))
                .unwrap()
                .parse()
                .unwrap();
            let total = head_end + 4 + clen;
            while buf.len() < total {
                let mut chunk = [0u8; 4096];
                let n = stream.read(&mut chunk).unwrap();
                assert!(n > 0, "server closed mid-body");
                buf.extend_from_slice(&chunk[..n]);
            }
            buf.drain(..total);
        }
        let clients = max_replicas * 2;
        let per = 48usize;
        // warmup
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_nodelay(true).unwrap();
            one_request(&mut s, &raw, &mut Vec::new());
        }
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..clients {
                let raw = raw.clone();
                scope.spawn(move || {
                    let mut s = TcpStream::connect(addr).unwrap();
                    s.set_nodelay(true).unwrap();
                    let mut buf = Vec::new();
                    for _ in 0..per {
                        one_request(&mut s, &raw, &mut buf);
                    }
                });
            }
        });
        let us = t0.elapsed().as_secs_f64() * 1e6;
        let total = (clients * per) as f64;
        println!(
            "http front door {max_replicas} shard(s), 1 event loop    {:>10.1} req/s \
             ({clients} keep-alive clients x {per} reqs)",
            total / (us * 1e-6)
        );
        println!(
            "    => network-edge overhead vs in-process {max_replicas}-replica router: \
             {:.2}x wall time",
            us / router_n_us.max(1.0)
        );
        if report.is_some() {
            let m = router.metrics("bench").unwrap();
            emit(
                &mut report,
                BenchSection {
                    img_per_s: total / (us * 1e-6),
                    queue: QueueStats::from_snapshot(&m.total),
                    bits_per_act: bits,
                    ..BenchSection::new("http_edge")
                },
            );
        }
    }

    // 7. PJRT end-to-end batch (compile once, then per-batch latency)
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(manifest) => pjrt_section(&manifest, cfg),
        Err(_) => eprintln!("artifacts missing; PJRT section skipped"),
    }

    if let Some((path, rep)) = report {
        rep.save(&path).expect("writing bench report");
        println!("bench report: wrote {} section(s) to {}", rep.sections.len(), path.display());
    }
}

fn pjrt_section(manifest: &Manifest, cfg: SparqConfig) {
    let rt = match PjrtRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT unavailable ({e}); section skipped");
            return;
        }
    };
    let model = match manifest.get("resnet10") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("resnet10 not in manifest ({e}); section skipped");
            return;
        }
    };
    let exe = match rt.load(&model.hlo_path(ArtifactKind::Sparq)) {
        Ok(exe) => exe,
        Err(e) => {
            eprintln!("PJRT compile unavailable ({e}); section skipped");
            return;
        }
    };
    let nq = model.quant_convs;
    let img: Vec<f32> = (0..64 * 20 * 20 * 3).map(|i| (i % 251) as f32 / 251.0).collect();
    let scales = vec![0.03f32; nq];
    let cfg_vec = cfg.to_vec().to_vec();
    let r = bench("PJRT sparq batch-64 fwd (resnet10)", 20, || {
        let out = exe
            .run(&[
                TensorArg::f32(&[64, 20, 20, 3], img.clone()),
                TensorArg::f32(&[nq], scales.clone()),
                TensorArg::i32(&[5], cfg_vec.clone()),
            ])
            .unwrap();
        std::hint::black_box(out);
    });
    println!("    -> {:.1} img/s", 64.0 / (r.median_us * 1e-6));
    match rt.load(&model.hlo_path(ArtifactKind::Float)) {
        Ok(fexe) => {
            let r = bench("PJRT float batch-64 fwd (resnet10)", 20, || {
                let out =
                    fexe.run(&[TensorArg::f32(&[64, 20, 20, 3], img.clone())]).unwrap();
                std::hint::black_box(out);
            });
            println!("    -> {:.1} img/s", 64.0 / (r.median_us * 1e-6));
        }
        Err(e) => eprintln!("float artifact unavailable ({e}); float row skipped"),
    }
}
