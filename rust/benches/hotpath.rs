//! Bench: the L3 hot paths in isolation — the inputs to the §Perf
//! optimization loop in EXPERIMENTS.md. Compares the scalar reference
//! against the LUT-optimized implementations and measures the native
//! GEMM engine and PJRT end-to-end batch latency.

include!("harness.rs");

use std::path::PathBuf;

use sparq::model::QuantGemm;
use sparq::quant::vsparq::sparq_dot;
use sparq::quant::{SparqConfig, TrimLut};
use sparq::runtime::{ArtifactKind, Manifest, PjrtRuntime, TensorArg};

fn main() {
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let k = 1152usize; // largest zoo reduction (64ch * 3x3 * 2)
    let acts = synth_acts(k, 40);
    let weights = synth_weights(k);

    // 1. trim+dot microbench: scalar reference vs LUT
    let lut = TrimLut::new(cfg);
    bench("sparq_dot scalar (K=1152)", 2000, || {
        std::hint::black_box(sparq_dot(&acts, &weights, cfg));
    });
    bench("sparq_dot LUT    (K=1152)", 2000, || {
        std::hint::black_box(lut.dot(&acts, &weights));
    });

    // 2. trim of a full im2col tile
    let mut tile = synth_acts(256 * k, 40);
    bench("trim_slice 256xK tile", 200, || {
        tile.copy_from_slice(&synth_acts(256 * k, 40));
        for row in tile.chunks_exact_mut(k) {
            lut.trim_slice(row);
        }
        std::hint::black_box(&tile);
    });

    // 3. full native GEMM (the native engine's conv core)
    let (m, n) = (400, 64);
    let a = synth_acts(m * k, 40);
    let w = synth_weights(k * n);
    let gemm = QuantGemm::new(cfg);
    let wt = gemm.prepare_weights(&w, k, n);
    let mut scratch = a.clone();
    let mut out = vec![0i32; m * n];
    let r = bench("native GEMM 400x1152x64", 20, || {
        scratch.copy_from_slice(&a);
        gemm.gemm(&mut scratch, m, k, &wt, n, &mut out);
        std::hint::black_box(&out);
    });
    let macs = (m * k * n) as f64;
    println!(
        "    -> {:.2} GMAC/s",
        macs / (r.median_us * 1e-6) / 1e9
    );

    // "further attempt" for the §Perf stopping criterion: manual 4-way
    // accumulator splitting of the inner dot. Kept out of the production
    // path unless it clears the 5% bar (record below).
    let a16: Vec<i16> = synth_acts(k, 40).iter().map(|&x| i16::from(x)).collect();
    let w16: Vec<i16> = synth_weights(k).iter().map(|&w| i16::from(w)).collect();
    let r_plain = bench("inner dot i16 plain (K=1152)", 5000, || {
        let mut acc = 0i32;
        for (&x, &w) in a16.iter().zip(&w16) {
            acc += i32::from(x) * i32::from(w);
        }
        std::hint::black_box(acc);
    });
    let r_split = bench("inner dot i16 4-acc split (K=1152)", 5000, || {
        let mut acc = [0i32; 4];
        let chunks_a = a16.chunks_exact(4);
        let chunks_w = w16.chunks_exact(4);
        for (ca, cw) in chunks_a.zip(chunks_w) {
            for l in 0..4 {
                acc[l] += i32::from(ca[l]) * i32::from(cw[l]);
            }
        }
        std::hint::black_box(acc[0] + acc[1] + acc[2] + acc[3]);
    });
    println!(
        "    -> split vs plain: {:+.1}% (kept only if < -5%)",
        100.0 * (r_split.min_us - r_plain.min_us) / r_plain.min_us
    );

    // 4. PJRT end-to-end batch (compile once, then per-batch latency)
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if let Ok(manifest) = Manifest::load(&dir) {
        let rt = PjrtRuntime::cpu().expect("pjrt");
        let model = manifest.get("resnet10").unwrap();
        let exe = rt.load(&model.hlo_path(ArtifactKind::Sparq)).unwrap();
        let nq = model.quant_convs;
        let img: Vec<f32> = (0..64 * 20 * 20 * 3).map(|i| (i % 251) as f32 / 251.0).collect();
        let scales = vec![0.03f32; nq];
        let cfg_vec = cfg.to_vec().to_vec();
        let r = bench("PJRT sparq batch-64 fwd (resnet10)", 20, || {
            let out = exe
                .run(&[
                    TensorArg::f32(&[64, 20, 20, 3], img.clone()),
                    TensorArg::f32(&[nq], scales.clone()),
                    TensorArg::i32(&[5], cfg_vec.clone()),
                ])
                .unwrap();
            std::hint::black_box(out);
        });
        println!("    -> {:.1} img/s", 64.0 / (r.median_us * 1e-6));
        let fexe = rt.load(&model.hlo_path(ArtifactKind::Float)).unwrap();
        let r = bench("PJRT float batch-64 fwd (resnet10)", 20, || {
            let out = fexe.run(&[TensorArg::f32(&[64, 20, 20, 3], img.clone())]).unwrap();
            std::hint::black_box(out);
        });
        println!("    -> {:.1} img/s", 64.0 / (r.median_us * 1e-6));
    } else {
        eprintln!("artifacts missing; PJRT section skipped");
    }
}
