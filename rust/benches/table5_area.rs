//! Bench: paper Table 5 (relative PE area) + the §5.2 ablation the
//! DESIGN.md calls out — how the shifter option count drives area — and
//! cycle-model scaling of the SA across GEMM shapes.

include!("harness.rs");

use sparq::experiments::table5;
use sparq::hw::area;
use sparq::hw::systolic::SystolicArray;
use sparq::quant::{Mode, SparqConfig};

fn main() {
    println!("{}", table5().render());

    // ablation: area vs placement-option count at fixed n=4 (the §5.2
    // "shift-left logic is the main contributor" claim)
    println!("## Ablation — shifter options vs area (SA, n=4)\n");
    for (name, cfg) in [
        ("2opt", SparqConfig::new(4, Mode::Opt2, true, true)),
        ("3opt", SparqConfig::new(4, Mode::Opt3, true, true)),
        ("5opt", SparqConfig::new(4, Mode::Full, true, true)),
    ] {
        let pe = area::sa_sparq(cfg);
        println!(
            "  {name}: total {:.0} gates (mult {:.0} / shift {:.0} / add {:.0} / mux {:.0} / reg {:.0})",
            pe.total(),
            pe.multipliers,
            pe.shifters,
            pe.adders,
            pe.muxes,
            pe.registers
        );
    }

    // §5.3 trim-unit area (paper: 17% / 12% / 9% of a TC)
    println!("\n## Trim-and-round unit relative to TC\n");
    for name in ["5opt_r", "3opt_r", "2opt_r"] {
        let cfg = SparqConfig::named(name).unwrap();
        println!("  {:<8} {:.1}%", cfg.to_string(), 100.0 * area::trim_unit_relative_to_tc(cfg));
    }

    // §5.1 footprint model + §6 shared-ShiftCtrl trade (future work the
    // paper names; implemented in quant::{footprint, shared_shift})
    println!("\n## Memory footprint (bits/activation; shared ShiftCtrl groups)\n");
    println!("  config     g=1    g=4    g=16   (int8 = 8.0)");
    for (name, b1, b4, b16) in sparq::quant::footprint::footprint_rows() {
        println!("  {name:<9} {b1:<6.2} {b4:<6.2} {b16:<6.2}");
    }
    println!("\n## Shared-shift accuracy trade (trim MSE on synthetic acts, 5opt+R)\n");
    let cfg_ns = SparqConfig::named("5opt_r_novs").unwrap();
    let orig = synth_acts(65536, 40);
    for g in [1usize, 2, 4, 8, 16, 64] {
        let mut t = orig.clone();
        sparq::quant::shared_shift::trim_slice_grouped(&mut t, cfg_ns, g);
        println!(
            "  group {g:>3}: MSE {:>8.3}  bits/act {:.2}",
            sparq::quant::shared_shift::trim_mse(&orig, &t),
            sparq::quant::footprint::bits_per_activation(
                SparqConfig { vsparq: false, ..cfg_ns },
                g as u32
            )
        );
    }

    // cycle-model timing: SA gemm simulation cost (the simulator itself)
    println!("\n## Simulator throughput\n");
    let cfg = SparqConfig::named("5opt_r").unwrap();
    let (m, k, n) = (64, 576, 64);
    let a = synth_acts(m * k, 40);
    let w = synth_weights(k * n);
    let sa = SystolicArray::new(16, 16, cfg);
    bench("systolic 16x16 gemm 64x576x64 (cycle sim)", 10, || {
        std::hint::black_box(sa.gemm(&a, &w, m, k, n));
    });
}
