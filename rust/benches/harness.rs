// Tiny shared bench harness (criterion is not in the offline crate
// set). Each bench target `include!`s this file. Methodology: warmup
// runs, then timed iterations; reports min/median/mean wall time.

use std::time::Instant;

#[allow(dead_code)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_us: f64,
    pub median_us: f64,
    pub mean_us: f64,
    /// Nearest-rank 99th percentile (== max for small iteration counts).
    pub p99_us: f64,
}

#[allow(dead_code)]
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    // warmup (also primes caches / JITted XLA executables)
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99_idx = ((samples.len() as f64 * 0.99).ceil() as usize).clamp(1, samples.len()) - 1;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        min_us: samples[0],
        median_us: samples[samples.len() / 2],
        mean_us: samples.iter().sum::<f64>() / samples.len() as f64,
        p99_us: samples[p99_idx],
    };
    println!(
        "{:<44} {:>5} iters   min {:>10.1} us   median {:>10.1} us   mean {:>10.1} us   \
         p99 {:>10.1} us",
        r.name, r.iters, r.min_us, r.median_us, r.mean_us, r.p99_us
    );
    r
}

/// Deterministic operand generator shared by the benches.
#[allow(dead_code)]
pub fn synth_acts(n: usize, sparsity_pct: u64) -> Vec<u8> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33;
            if h % 100 < sparsity_pct {
                0
            } else {
                (h % 256) as u8
            }
        })
        .collect()
}

/// Single source of truth lives in the library so the benches and the
/// demo model can't drift apart.
#[allow(dead_code)]
pub fn synth_weights(n: usize) -> Vec<i8> {
    sparq::model::demo::synth_weights(n)
}
