//! Bench: regenerates paper Tables 2, 3, 4 and 6 — the full SPARQ
//! accuracy grid — and reports wall time per table.
//!
//! Run: `cargo bench --bench table2_sparq_configs [-- eval-limit]`

include!("harness.rs");

use std::path::PathBuf;

use sparq::experiments::{table2, table3, table4, table6, ExperimentCtx};

fn main() {
    let limit: usize = std::env::args()
        .skip_while(|a| a != "--")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut ctx = match ExperimentCtx::new(&dir, limit, 1024) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e}");
            return;
        }
    };
    for (name, f) in [
        ("table2", table2 as fn(&mut ExperimentCtx) -> anyhow::Result<_>),
        ("table3", table3),
        ("table4", table4),
        ("table6", table6),
    ] {
        let t0 = std::time::Instant::now();
        match f(&mut ctx) {
            Ok(t) => {
                println!("{}", t.render());
                println!("{name}: {:.1}s\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => eprintln!("{name} failed: {e}"),
        }
    }
}
