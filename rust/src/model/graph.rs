//! Graph IR loader — parses `<tag>_meta.json` (the contract documented
//! in `python/compile/layers.py`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::JsonValue;

/// Node operation, mirroring the python builder's op set.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Input,
    Conv { k: usize, stride: usize, out_ch: usize, relu: bool, quant: bool },
    Pool { avg: bool },
    Gap,
    Add,
    Relu,
    Concat,
    Fc { out: usize },
}

#[derive(Clone, Debug)]
pub struct Node {
    pub name: String,
    pub op: Op,
    pub inputs: Vec<String>,
}

/// A loaded model graph.
#[derive(Clone, Debug)]
pub struct Graph {
    pub arch: String,
    pub variant: String,
    pub num_classes: usize,
    pub input_hwc: [usize; 3],
    pub eval_batch: usize,
    /// Quantized conv names in activation-scale-vector order.
    pub quant_convs: Vec<String>,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading meta {}", path.display()))?;
        Self::from_json(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = JsonValue::parse(text)?;
        let req_str = |val: &JsonValue, key: &str| -> Result<String> {
            Ok(val
                .get(key)
                .and_then(JsonValue::as_str)
                .with_context(|| format!("meta missing `{key}`"))?
                .to_string())
        };
        let hwc = v
            .get("input_hwc")
            .and_then(JsonValue::as_array)
            .context("meta missing input_hwc")?;
        if hwc.len() != 3 {
            bail!("input_hwc must have 3 entries");
        }
        let mut nodes = Vec::new();
        for n in v.get("nodes").and_then(JsonValue::as_array).context("missing nodes")? {
            let name = req_str(n, "name")?;
            let op_name = req_str(n, "op")?;
            let inputs: Vec<String> = n
                .get("inputs")
                .and_then(JsonValue::as_array)
                .unwrap_or(&[])
                .iter()
                .filter_map(JsonValue::as_str)
                .map(str::to_string)
                .collect();
            let usize_attr = |key: &str| -> Result<usize> {
                n.get(key)
                    .and_then(JsonValue::as_usize)
                    .with_context(|| format!("node {name}: missing `{key}`"))
            };
            let bool_attr = |key: &str| -> Result<bool> {
                n.get(key)
                    .and_then(JsonValue::as_bool)
                    .with_context(|| format!("node {name}: missing `{key}`"))
            };
            let op = match op_name.as_str() {
                "input" => Op::Input,
                "conv" => Op::Conv {
                    k: usize_attr("k")?,
                    stride: usize_attr("stride")?,
                    out_ch: usize_attr("out_ch")?,
                    relu: bool_attr("relu")?,
                    quant: bool_attr("quant")?,
                },
                "pool" => Op::Pool { avg: req_str(n, "kind")? == "avg" },
                "gap" => Op::Gap,
                "add" => Op::Add,
                "relu" => Op::Relu,
                "concat" => Op::Concat,
                "fc" => Op::Fc { out: usize_attr("out")? },
                other => bail!("unknown op `{other}` in node {name}"),
            };
            nodes.push(Node { name, op, inputs });
        }
        let graph = Self {
            arch: req_str(&v, "arch")?,
            variant: req_str(&v, "variant")?,
            num_classes: v.get("num_classes").and_then(JsonValue::as_usize).context("num_classes")?,
            input_hwc: [
                hwc[0].as_usize().context("hwc")?,
                hwc[1].as_usize().context("hwc")?,
                hwc[2].as_usize().context("hwc")?,
            ],
            eval_batch: v.get("eval_batch").and_then(JsonValue::as_usize).context("eval_batch")?,
            quant_convs: v
                .get("quant_convs")
                .and_then(JsonValue::as_array)
                .context("quant_convs")?
                .iter()
                .filter_map(JsonValue::as_str)
                .map(str::to_string)
                .collect(),
            nodes,
        };
        graph.validate()?;
        Ok(graph)
    }

    /// Structural checks: topo order, known inputs, single fc sink.
    pub fn validate(&self) -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for node in &self.nodes {
            for i in &node.inputs {
                if !seen.contains(i.as_str()) {
                    bail!("node {} consumes `{i}` before it is produced", node.name);
                }
            }
            if !seen.insert(node.name.as_str()) {
                bail!("duplicate node name {}", node.name);
            }
        }
        let quant_names: Vec<&str> = self
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Conv { quant: true, .. }))
            .map(|n| n.name.as_str())
            .collect();
        if quant_names != self.quant_convs.iter().map(String::as_str).collect::<Vec<_>>() {
            bail!("quant_convs order mismatch: {quant_names:?} vs {:?}", self.quant_convs);
        }
        match self.nodes.last().map(|n| &n.op) {
            Some(Op::Fc { out }) if *out == self.num_classes => Ok(()),
            other => bail!("graph must end in fc(num_classes), got {other:?}"),
        }
    }

    pub fn node(&self, name: &str) -> Result<&Node> {
        self.nodes
            .iter()
            .find(|n| n.name == name)
            .with_context(|| format!("node `{name}` not in graph"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const TINY_META: &str = r#"{
      "arch": "tiny", "variant": "dense", "num_classes": 3,
      "input_hwc": [4, 4, 2], "eval_batch": 2,
      "quant_convs": ["c2"],
      "nodes": [
        {"name": "img", "op": "input", "inputs": []},
        {"name": "c1", "op": "conv", "inputs": ["img"],
         "k": 3, "stride": 1, "out_ch": 4, "relu": true, "quant": false},
        {"name": "c2", "op": "conv", "inputs": ["c1"],
         "k": 3, "stride": 2, "out_ch": 6, "relu": true, "quant": true},
        {"name": "g", "op": "gap", "inputs": ["c2"]},
        {"name": "fc", "op": "fc", "inputs": ["g"], "out": 3}
      ]
    }"#;

    #[test]
    fn parse_tiny() {
        let g = Graph::from_json(TINY_META).unwrap();
        assert_eq!(g.arch, "tiny");
        assert_eq!(g.nodes.len(), 5);
        assert_eq!(g.quant_convs, vec!["c2"]);
        assert!(matches!(
            g.node("c2").unwrap().op,
            Op::Conv { k: 3, stride: 2, out_ch: 6, relu: true, quant: true }
        ));
    }

    #[test]
    fn rejects_bad_topo() {
        let bad = TINY_META.replace(r#""inputs": ["c1"]"#, r#""inputs": ["nope"]"#);
        assert!(Graph::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_quant_conv_mismatch() {
        let bad = TINY_META.replace(r#""quant_convs": ["c2"]"#, r#""quant_convs": ["c1"]"#);
        assert!(Graph::from_json(&bad).is_err());
    }
}
