//! Graph IR loader — parses `<tag>_meta.json` (the contract documented
//! in `python/compile/layers.py`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::JsonValue;

/// Node operation, mirroring the python builder's op set.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Input,
    Conv { k: usize, stride: usize, out_ch: usize, relu: bool, quant: bool },
    Pool { avg: bool },
    Gap,
    Add,
    Relu,
    Concat,
    Fc { out: usize },
}

#[derive(Clone, Debug)]
pub struct Node {
    pub name: String,
    pub op: Op,
    pub inputs: Vec<String>,
}

/// A loaded model graph.
#[derive(Clone, Debug)]
pub struct Graph {
    pub arch: String,
    pub variant: String,
    pub num_classes: usize,
    pub input_hwc: [usize; 3],
    pub eval_batch: usize,
    /// Quantized conv names in activation-scale-vector order.
    pub quant_convs: Vec<String>,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading meta {}", path.display()))?;
        Self::from_json(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = JsonValue::parse(text)?;
        let req_str = |val: &JsonValue, key: &str| -> Result<String> {
            Ok(val
                .get(key)
                .and_then(JsonValue::as_str)
                .with_context(|| format!("meta missing `{key}`"))?
                .to_string())
        };
        let hwc = v
            .get("input_hwc")
            .and_then(JsonValue::as_array)
            .context("meta missing input_hwc")?;
        if hwc.len() != 3 {
            bail!("input_hwc must have 3 entries");
        }
        let mut nodes = Vec::new();
        for n in v.get("nodes").and_then(JsonValue::as_array).context("missing nodes")? {
            let name = req_str(n, "name")?;
            let op_name = req_str(n, "op")?;
            let inputs: Vec<String> = n
                .get("inputs")
                .and_then(JsonValue::as_array)
                .unwrap_or(&[])
                .iter()
                .filter_map(JsonValue::as_str)
                .map(str::to_string)
                .collect();
            let usize_attr = |key: &str| -> Result<usize> {
                n.get(key)
                    .and_then(JsonValue::as_usize)
                    .with_context(|| format!("node {name}: missing `{key}`"))
            };
            let bool_attr = |key: &str| -> Result<bool> {
                n.get(key)
                    .and_then(JsonValue::as_bool)
                    .with_context(|| format!("node {name}: missing `{key}`"))
            };
            let op = match op_name.as_str() {
                "input" => Op::Input,
                "conv" => Op::Conv {
                    k: usize_attr("k")?,
                    stride: usize_attr("stride")?,
                    out_ch: usize_attr("out_ch")?,
                    relu: bool_attr("relu")?,
                    quant: bool_attr("quant")?,
                },
                "pool" => Op::Pool { avg: req_str(n, "kind")? == "avg" },
                "gap" => Op::Gap,
                "add" => Op::Add,
                "relu" => Op::Relu,
                "concat" => Op::Concat,
                "fc" => Op::Fc { out: usize_attr("out")? },
                other => bail!("unknown op `{other}` in node {name}"),
            };
            nodes.push(Node { name, op, inputs });
        }
        let graph = Self {
            arch: req_str(&v, "arch")?,
            variant: req_str(&v, "variant")?,
            num_classes: v.get("num_classes").and_then(JsonValue::as_usize).context("num_classes")?,
            input_hwc: [
                hwc[0].as_usize().context("hwc")?,
                hwc[1].as_usize().context("hwc")?,
                hwc[2].as_usize().context("hwc")?,
            ],
            eval_batch: v.get("eval_batch").and_then(JsonValue::as_usize).context("eval_batch")?,
            quant_convs: v
                .get("quant_convs")
                .and_then(JsonValue::as_array)
                .context("quant_convs")?
                .iter()
                .filter_map(JsonValue::as_str)
                .map(str::to_string)
                .collect(),
            nodes,
        };
        graph.validate()?;
        Ok(graph)
    }

    /// Structural checks: topo order, known inputs, single fc sink.
    pub fn validate(&self) -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for node in &self.nodes {
            for i in &node.inputs {
                if !seen.contains(i.as_str()) {
                    bail!("node {} consumes `{i}` before it is produced", node.name);
                }
            }
            if !seen.insert(node.name.as_str()) {
                bail!("duplicate node name {}", node.name);
            }
        }
        let quant_names: Vec<&str> = self
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Conv { quant: true, .. }))
            .map(|n| n.name.as_str())
            .collect();
        if quant_names != self.quant_convs.iter().map(String::as_str).collect::<Vec<_>>() {
            bail!("quant_convs order mismatch: {quant_names:?} vs {:?}", self.quant_convs);
        }
        match self.nodes.last().map(|n| &n.op) {
            Some(Op::Fc { out }) if *out == self.num_classes => Ok(()),
            other => bail!("graph must end in fc(num_classes), got {other:?}"),
        }
    }

    pub fn node(&self, name: &str) -> Result<&Node> {
        self.nodes
            .iter()
            .find(|n| n.name == name)
            .with_context(|| format!("node `{name}` not in graph"))
    }

    /// Per-image im2col activation volume — `oh*ow * c_in*k*k` — for
    /// each quantized conv, in `quant_convs` order: the number of
    /// quantized activation values that layer's GEMM consumes per
    /// image. This is the natural weight for policy-level footprint
    /// accounting ([`crate::quant::footprint::policy_bits_per_activation`]),
    /// derived by a static shape walk over the graph ops (the same
    /// shape rules the engine applies at execute time).
    ///
    /// The walk is lenient about nodes whose input shape is unknown
    /// (e.g. structurally invalid corners like a post-fc consumer,
    /// which the engine rejects with a better error at forward time) —
    /// it only fails if a *quantized conv's* input shape cannot be
    /// derived.
    pub fn quant_act_volumes(&self) -> Result<Vec<usize>> {
        use crate::tensor::out_dim;
        let mut shapes: std::collections::HashMap<&str, [usize; 3]> =
            std::collections::HashMap::new();
        let mut vols = Vec::new();
        for node in &self.nodes {
            let input = |i: usize| -> Option<[usize; 3]> {
                shapes.get(node.inputs.get(i)?.as_str()).copied()
            };
            let out: Option<[usize; 3]> = match &node.op {
                Op::Input => Some(self.input_hwc),
                Op::Conv { k, stride, out_ch, quant, .. } => {
                    let shape = input(0);
                    if *quant {
                        let [h, w, c] = shape.with_context(|| {
                            format!(
                                "cannot derive the input shape of quantized conv `{}`",
                                node.name
                            )
                        })?;
                        vols.push(out_dim(h, *stride) * out_dim(w, *stride) * c * k * k);
                    }
                    shape.map(|[h, w, _]| [out_dim(h, *stride), out_dim(w, *stride), *out_ch])
                }
                Op::Pool { .. } => input(0).map(|[h, w, c]| [h / 2, w / 2, c]),
                Op::Gap => input(0).map(|[_, _, c]| [1, 1, c]),
                Op::Add | Op::Relu => input(0),
                Op::Concat => {
                    let mut acc = input(0);
                    if let Some([h, w, _]) = acc {
                        let mut c_sum = 0usize;
                        for i in 0..node.inputs.len() {
                            match input(i) {
                                Some(s) => c_sum += s[2],
                                None => {
                                    acc = None;
                                    break;
                                }
                            }
                        }
                        if acc.is_some() {
                            acc = Some([h, w, c_sum]);
                        }
                    }
                    acc
                }
                Op::Fc { .. } => None,
            };
            if let Some(s) = out {
                shapes.insert(node.name.as_str(), s);
            }
        }
        if vols.len() != self.quant_convs.len() {
            bail!(
                "shape walk saw {} quantized convs, graph lists {}",
                vols.len(),
                self.quant_convs.len()
            );
        }
        Ok(vols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const TINY_META: &str = r#"{
      "arch": "tiny", "variant": "dense", "num_classes": 3,
      "input_hwc": [4, 4, 2], "eval_batch": 2,
      "quant_convs": ["c2"],
      "nodes": [
        {"name": "img", "op": "input", "inputs": []},
        {"name": "c1", "op": "conv", "inputs": ["img"],
         "k": 3, "stride": 1, "out_ch": 4, "relu": true, "quant": false},
        {"name": "c2", "op": "conv", "inputs": ["c1"],
         "k": 3, "stride": 2, "out_ch": 6, "relu": true, "quant": true},
        {"name": "g", "op": "gap", "inputs": ["c2"]},
        {"name": "fc", "op": "fc", "inputs": ["g"], "out": 3}
      ]
    }"#;

    #[test]
    fn parse_tiny() {
        let g = Graph::from_json(TINY_META).unwrap();
        assert_eq!(g.arch, "tiny");
        assert_eq!(g.nodes.len(), 5);
        assert_eq!(g.quant_convs, vec!["c2"]);
        assert!(matches!(
            g.node("c2").unwrap().op,
            Op::Conv { k: 3, stride: 2, out_ch: 6, relu: true, quant: true }
        ));
    }

    #[test]
    fn rejects_bad_topo() {
        let bad = TINY_META.replace(r#""inputs": ["c1"]"#, r#""inputs": ["nope"]"#);
        assert!(Graph::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_quant_conv_mismatch() {
        let bad = TINY_META.replace(r#""quant_convs": ["c2"]"#, r#""quant_convs": ["c1"]"#);
        assert!(Graph::from_json(&bad).is_err());
    }

    #[test]
    fn quant_act_volumes_match_the_engine_shape_rules() {
        // tiny meta: img 4x4x2 -> c1 (float, 3x3 s1, 4ch) -> c2 (quant,
        // 3x3 s2, 6ch) -> gap -> fc. c2's im2col per image:
        // oh*ow = ceil(4/2)^2 = 4, K = c_in*k*k = 4*9 = 36.
        let g = Graph::from_json(TINY_META).unwrap();
        assert_eq!(g.quant_act_volumes().unwrap(), vec![2 * 2 * 4 * 3 * 3]);
    }
}
