//! Deterministic synthetic demo model shared by artifact-free drivers
//! (`benches/hotpath.rs`, `examples/serve_bench.rs`): a float stem conv
//! + two quantized convs + gap + fc over 20x20x3 inputs, shaped like
//! the zoo's resnet10 stem. Hidden from the documented API — it exists
//! so the bench and the example can't drift apart.

use std::collections::HashMap;

use super::graph::{Graph, Node, Op};
use super::weights::{FloatConv, QuantConv, Weights};

/// splitmix-style deterministic i8 weights (same constants as the
/// bench harness's generator, so results are comparable across
/// targets and builds).
pub fn synth_weights(n: usize) -> Vec<i8> {
    (0..n)
        .map(|i| ((((i as u64).wrapping_mul(0xbf58476d1ce4e5b9) >> 33) % 255) as i32 - 127) as i8)
        .collect()
}

/// Synthetic 4-layer model + its activation scales.
pub fn synth_model() -> (Graph, Weights, Vec<f32>) {
    let graph = Graph {
        arch: "bench".into(),
        variant: "synthetic".into(),
        num_classes: 10,
        input_hwc: [20, 20, 3],
        eval_batch: 32,
        quant_convs: vec!["q1".into(), "q2".into()],
        nodes: vec![
            Node { name: "img".into(), op: Op::Input, inputs: vec![] },
            Node {
                name: "c1".into(),
                op: Op::Conv { k: 3, stride: 1, out_ch: 16, relu: true, quant: false },
                inputs: vec!["img".into()],
            },
            Node {
                name: "q1".into(),
                op: Op::Conv { k: 3, stride: 2, out_ch: 32, relu: true, quant: true },
                inputs: vec!["c1".into()],
            },
            Node {
                name: "q2".into(),
                op: Op::Conv { k: 3, stride: 1, out_ch: 64, relu: true, quant: true },
                inputs: vec!["q1".into()],
            },
            Node { name: "g".into(), op: Op::Gap, inputs: vec!["q2".into()] },
            Node { name: "fc".into(), op: Op::Fc { out: 10 }, inputs: vec!["g".into()] },
        ],
    };
    let mut float = HashMap::new();
    let c1_len = 3 * 3 * 3 * 16;
    float.insert(
        "c1".to_string(),
        FloatConv {
            w: synth_weights(c1_len).iter().map(|&v| f32::from(v) / 400.0).collect(),
            kh: 3,
            kw: 3,
            c_in: 3,
            c_out: 16,
            bias: vec![0.01; 16],
        },
    );
    let mut quant = HashMap::new();
    quant.insert(
        "q1".to_string(),
        QuantConv {
            wq: synth_weights(16 * 9 * 32),
            k: 16 * 9,
            o: 32,
            scale: vec![0.002; 32],
            bias: vec![0.0; 32],
        },
    );
    quant.insert(
        "q2".to_string(),
        QuantConv {
            wq: synth_weights(32 * 9 * 64),
            k: 32 * 9,
            o: 64,
            scale: vec![0.002; 64],
            bias: vec![0.0; 64],
        },
    );
    let fc_len = 64 * 10;
    let weights = Weights {
        quant,
        float,
        fc_w: synth_weights(fc_len).iter().map(|&v| f32::from(v) / 127.0).collect(),
        fc_in: 64,
        fc_out: 10,
        fc_b: vec![0.0; 10],
    };
    (graph, weights, vec![0.02, 0.02])
}
