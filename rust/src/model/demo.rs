//! Deterministic synthetic demo model shared by artifact-free drivers
//! (`benches/hotpath.rs`, `examples/serve_bench.rs`, the policy eval
//! tests): a float stem conv + three quantized convs + gap + fc over
//! 20x20x3 inputs, shaped like the zoo's resnet10 stem. Hidden from the
//! documented API — it exists so the bench, the example and the tests
//! can't drift apart. Three quantized convs (not two) so first/last
//! per-layer policies leave a genuinely distinct middle layer.

use std::collections::HashMap;

use crate::data::Dataset;
use crate::quant::SparqConfig;

use super::engine::{Engine, EngineMode, Scratch};
use super::graph::{Graph, Node, Op};
use super::weights::{FloatConv, QuantConv, Weights};

/// splitmix-style deterministic i8 weights (same constants as the
/// bench harness's generator, so results are comparable across
/// targets and builds).
pub fn synth_weights(n: usize) -> Vec<i8> {
    (0..n)
        .map(|i| ((((i as u64).wrapping_mul(0xbf58476d1ce4e5b9) >> 33) % 255) as i32 - 127) as i8)
        .collect()
}

/// Synthetic 5-layer model (1 float + 3 quantized convs) + its
/// activation scales.
pub fn synth_model() -> (Graph, Weights, Vec<f32>) {
    let graph = Graph {
        arch: "bench".into(),
        variant: "synthetic".into(),
        num_classes: 10,
        input_hwc: [20, 20, 3],
        eval_batch: 32,
        quant_convs: vec!["q1".into(), "q2".into(), "q3".into()],
        nodes: vec![
            Node { name: "img".into(), op: Op::Input, inputs: vec![] },
            Node {
                name: "c1".into(),
                op: Op::Conv { k: 3, stride: 1, out_ch: 16, relu: true, quant: false },
                inputs: vec!["img".into()],
            },
            Node {
                name: "q1".into(),
                op: Op::Conv { k: 3, stride: 2, out_ch: 32, relu: true, quant: true },
                inputs: vec!["c1".into()],
            },
            Node {
                name: "q2".into(),
                op: Op::Conv { k: 3, stride: 1, out_ch: 64, relu: true, quant: true },
                inputs: vec!["q1".into()],
            },
            Node {
                name: "q3".into(),
                op: Op::Conv { k: 1, stride: 1, out_ch: 64, relu: true, quant: true },
                inputs: vec!["q2".into()],
            },
            Node { name: "g".into(), op: Op::Gap, inputs: vec!["q3".into()] },
            Node { name: "fc".into(), op: Op::Fc { out: 10 }, inputs: vec!["g".into()] },
        ],
    };
    let mut float = HashMap::new();
    let c1_len = 3 * 3 * 3 * 16;
    float.insert(
        "c1".to_string(),
        FloatConv {
            w: synth_weights(c1_len).iter().map(|&v| f32::from(v) / 400.0).collect(),
            kh: 3,
            kw: 3,
            c_in: 3,
            c_out: 16,
            bias: vec![0.01; 16],
        },
    );
    let mut quant = HashMap::new();
    quant.insert(
        "q1".to_string(),
        QuantConv {
            wq: synth_weights(16 * 9 * 32),
            k: 16 * 9,
            o: 32,
            scale: vec![0.002; 32],
            bias: vec![0.0; 32],
        },
    );
    quant.insert(
        "q2".to_string(),
        QuantConv {
            wq: synth_weights(32 * 9 * 64),
            k: 32 * 9,
            o: 64,
            scale: vec![0.002; 64],
            bias: vec![0.0; 64],
        },
    );
    quant.insert(
        "q3".to_string(),
        QuantConv {
            wq: synth_weights(64 * 64),
            k: 64,
            o: 64,
            scale: vec![0.002; 64],
            bias: vec![0.0; 64],
        },
    );
    let fc_len = 64 * 10;
    let weights = Weights {
        quant,
        float,
        fc_w: synth_weights(fc_len).iter().map(|&v| f32::from(v) / 127.0).collect(),
        fc_in: 64,
        fc_out: 10,
        fc_b: vec![0.0; 10],
    };
    (graph, weights, vec![0.02, 0.02, 0.02])
}

/// Linear test graph with `n` quantized 1x1 convs named `l0..l{n-1}`
/// (img -> l0 -> … -> gap -> fc): the minimal shape for per-layer
/// policy tests. Shared by the policy unit tests and the `layer_plan`
/// property tests so the two cannot drift apart. Carries no weights —
/// it exists for plan/selector resolution, not execution.
pub fn chain_graph(n: usize) -> Graph {
    let mut nodes = vec![Node { name: "img".into(), op: Op::Input, inputs: vec![] }];
    let mut prev = "img".to_string();
    let mut quant_convs = Vec::new();
    for i in 0..n {
        let name = format!("l{i}");
        nodes.push(Node {
            name: name.clone(),
            op: Op::Conv { k: 1, stride: 1, out_ch: 2, relu: true, quant: true },
            inputs: vec![prev.clone()],
        });
        quant_convs.push(name.clone());
        prev = name;
    }
    nodes.push(Node { name: "g".into(), op: Op::Gap, inputs: vec![prev] });
    nodes.push(Node { name: "fc".into(), op: Op::Fc { out: 2 }, inputs: vec!["g".into()] });
    Graph {
        arch: "chain".into(),
        variant: "policy-test".into(),
        num_classes: 2,
        input_hwc: [2, 2, 2],
        eval_batch: 1,
        quant_convs,
        nodes,
    }
}

/// Deterministic synthetic dataset for the demo model, **labelled by
/// the uniform-A8W8 engine's own top-1 predictions**: the 8-bit
/// reference scores 100% by construction, so "accuracy" measures
/// agreement with the reference and more aggressive per-layer policies
/// can be ordered meaningfully without real data (the policy eval
/// tests and the CI smoke lean on this).
pub fn synth_dataset(graph: &Graph, weights: &Weights, scales: &[f32], n: usize) -> Dataset {
    let [h, w, c] = graph.input_hwc;
    let stride = h * w * c;
    let images: Vec<u8> = (0..n * stride)
        .map(|i| (((i as u64).wrapping_mul(0x9e3779b97f4a7c15) >> 33) % 256) as u8)
        .collect();
    let engine = Engine::new(graph, weights, SparqConfig::A8W8, scales, EngineMode::Dense)
        .expect("demo A8W8 engine");
    let mut scratch = Scratch::default();
    let mut labels = Vec::with_capacity(n);
    let mut img = Vec::with_capacity(stride);
    for i in 0..n {
        img.clear();
        img.extend(images[i * stride..(i + 1) * stride].iter().map(|&p| f32::from(p) / 255.0));
        let logits = engine.forward_scratch(&img, 1, &mut scratch).expect("demo forward");
        labels.push(Engine::argmax(&logits, graph.num_classes)[0] as u8);
    }
    Dataset { n, h, w, c, num_classes: graph.num_classes, images, labels }
}
