//! Quantized GEMM — the native engine's hot path.
//!
//! Semantics are exactly `quant::vsparq::sparq_dot` applied per output
//! element; the implementation factors the work for speed:
//!
//! 1. the SPARQ trim touches each activation once per *row* (not once
//!    per output column) through the 256-entry [`TrimLut`],
//! 2. weights are requantized once and transposed to (O, K) so the
//!    inner dot product walks two contiguous slices,
//! 3. the inner loop accumulates i32 over u8 x i8 products, which LLVM
//!    auto-vectorizes well (verified in the §Perf pass).

use crate::quant::{SparqConfig, TrimLut};

/// A reusable GEMM context for one configuration.
pub struct QuantGemm {
    pub lut: TrimLut,
}

impl QuantGemm {
    pub fn new(cfg: SparqConfig) -> Self {
        Self { lut: TrimLut::new(cfg) }
    }

    pub fn cfg(&self) -> SparqConfig {
        self.lut.cfg
    }

    /// Requantize + transpose weights (K, O) -> (O, K) once per layer.
    ///
    /// Weights are widened to i16 at preparation time (a one-off, cached
    /// per layer): the inner dot then runs i16 x i16 -> i32, which LLVM
    /// vectorizes to multiply-add-pairs on AVX2/AVX-512 — measured ~30%
    /// faster than the u8 x i8 widening loop (EXPERIMENTS.md §Perf L3).
    pub fn prepare_weights(&self, w: &[i8], k: usize, o: usize) -> Vec<i16> {
        assert_eq!(w.len(), k * o);
        let mut wt = vec![0i16; k * o];
        for r in 0..k {
            for c in 0..o {
                wt[c * k + r] = i16::from(self.lut.weight(w[r * o + c]));
            }
        }
        wt
    }

    /// `a (M x K, u8, already uniform-quantized)` x `wt (O x K, prepared)`
    /// -> `out (M x O, i32)`. `a` is trimmed in place (it is scratch).
    pub fn gemm(&self, a: &mut [u8], m: usize, k: usize, wt: &[i16], o: usize, out: &mut [i32]) {
        assert_eq!(a.len(), m * k);
        assert_eq!(wt.len(), o * k);
        assert_eq!(out.len(), m * o);
        let mut row16 = vec![0i16; k];
        for mi in 0..m {
            let row = &mut a[mi * k..(mi + 1) * k];
            self.lut.trim_slice(row);
            for (d, &s) in row16.iter_mut().zip(row.iter()) {
                *d = i16::from(s);
            }
            let out_row = &mut out[mi * o..(mi + 1) * o];
            for (oi, ov) in out_row.iter_mut().enumerate() {
                *ov = dot_i16(&row16, &wt[oi * k..(oi + 1) * k]);
            }
        }
    }
}

/// Contiguous i16 x i16 dot with i32 accumulation (vectorizes to
/// multiply-add-pairs; values are < 2^15 so products never overflow).
#[inline]
fn dot_i16(a: &[i16], b: &[i16]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    for (&x, &w) in a.iter().zip(b) {
        acc += i32::from(x) * i32::from(w);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vsparq::sparq_dot;

    #[test]
    fn gemm_matches_scalar_reference() {
        let (m, k, o) = (7, 34, 5);
        let a0: Vec<u8> = (0..m * k)
            .map(|i| if i % 4 == 0 { 0 } else { ((i * 67) % 256) as u8 })
            .collect();
        let w: Vec<i8> = (0..k * o).map(|i| (((i * 19) % 255) as i32 - 127) as i8).collect();
        for name in ["a8w8", "a8w4", "a4w8", "5opt_r", "3opt", "2opt_r", "6opt_r", "7opt_r_novs"] {
            let cfg = SparqConfig::named(name).unwrap();
            let g = QuantGemm::new(cfg);
            let wt = g.prepare_weights(&w, k, o);
            let mut a = a0.clone();
            let mut out = vec![0i32; m * o];
            g.gemm(&mut a, m, k, &wt, o, &mut out);
            for mi in 0..m {
                for oi in 0..o {
                    let col: Vec<i8> = (0..k).map(|r| w[r * o + oi]).collect();
                    assert_eq!(
                        out[mi * o + oi],
                        sparq_dot(&a0[mi * k..(mi + 1) * k], &col, cfg),
                        "{name} ({mi},{oi})"
                    );
                }
            }
        }
    }

    #[test]
    fn odd_k_pads_like_hardware() {
        let (m, k, o) = (2, 9, 3);
        let a0: Vec<u8> = (0..m * k).map(|i| ((i * 53 + 1) % 256) as u8).collect();
        let w = vec![1i8; k * o];
        let cfg = SparqConfig::named("5opt_r").unwrap();
        let g = QuantGemm::new(cfg);
        let wt = g.prepare_weights(&w, k, o);
        let mut a = a0.clone();
        let mut out = vec![0i32; m * o];
        g.gemm(&mut a, m, k, &wt, o, &mut out);
        let col = vec![1i8; k];
        for mi in 0..m {
            assert_eq!(out[mi * o], sparq_dot(&a0[mi * k..(mi + 1) * k], &col, cfg));
        }
    }
}
