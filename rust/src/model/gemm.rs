//! Quantized GEMM — the native engine's hot path.
//!
//! Semantics are exactly `quant::vsparq::sparq_dot` applied per output
//! element; the implementation factors the work for speed:
//!
//! 1. the SPARQ trim touches each activation once per *row* (not once
//!    per output column) through the 256-entry [`TrimLut`], fused into
//!    the i16 row packing,
//! 2. weights are requantized once and transposed to (O, K) so the
//!    inner dot product walks two contiguous slices,
//! 3. the kernel is cache-blocked (M x O tiles over K panels) with a
//!    4-column register accumulator, and output rows are partitioned
//!    across scoped threads ([`super::threadpool`]).
//!
//! Integer accumulation is associative, so tiling and threading cannot
//! change results: every path here is bit-identical to
//! [`QuantGemm::gemm_naive`], the retained unblocked single-threaded
//! reference (asserted by unit tests, property tests, and the
//! `benches/hotpath.rs` before/after comparison).

use crate::quant::{SparqConfig, TrimLut};

use super::threadpool;

/// Rows per register tile.
const MC: usize = 16;
/// Output columns per tile (4-way unrolled inner loop).
const NC: usize = 32;
/// Reduction panel: per tile the packed activation rows (`MC * KC` i16,
/// 24 KB) plus the weight panel (`NC * KC` i16, 48 KB) stay L2-resident.
const KC: usize = 768;
/// Target MACs per worker thread: below this a GEMM runs serial, and
/// above it the worker count grows one per multiple (capped by the
/// requested count). At the kernel's measured throughput this keeps
/// every worker busy for hundreds of microseconds, comfortably
/// amortizing scoped-thread spawn/join (~tens of microseconds).
pub const MIN_PARALLEL_MACS: usize = 512 * 1024;

/// A reusable GEMM context for one configuration.
pub struct QuantGemm {
    pub lut: TrimLut,
}

impl QuantGemm {
    pub fn new(cfg: SparqConfig) -> Self {
        Self { lut: TrimLut::new(cfg) }
    }

    pub fn cfg(&self) -> SparqConfig {
        self.lut.cfg
    }

    /// Requantize + transpose weights (K, O) -> (O, K) once per layer.
    ///
    /// Weights are widened to i16 at preparation time (a one-off, cached
    /// per layer): the inner dot then runs i16 x i16 -> i32, which LLVM
    /// vectorizes to multiply-add-pairs on AVX2/AVX-512 — measured ~30%
    /// faster than the u8 x i8 widening loop (EXPERIMENTS.md §Perf L3).
    pub fn prepare_weights(&self, w: &[i8], k: usize, o: usize) -> Vec<i16> {
        assert_eq!(w.len(), k * o);
        let mut wt = vec![0i16; k * o];
        for r in 0..k {
            for c in 0..o {
                wt[c * k + r] = i16::from(self.lut.weight(w[r * o + c]));
            }
        }
        wt
    }

    /// `a (M x K, u8, already uniform-quantized)` x `wt (O x K, prepared)`
    /// -> `out (M x O, i32)`. `a` is trimmed in place (it is scratch).
    ///
    /// Convenience wrapper that allocates its own pack buffer and uses
    /// the default thread count; steady-state callers (the engine) use
    /// [`QuantGemm::gemm_with`] with reused scratch instead.
    pub fn gemm(&self, a: &mut [u8], m: usize, k: usize, wt: &[i16], o: usize, out: &mut [i32]) {
        let mut pack = Vec::new();
        self.gemm_with(a, m, k, wt, o, out, &mut pack, threadpool::max_threads());
    }

    /// Cache-blocked, row-parallel GEMM with caller-owned scratch.
    ///
    /// `pack` is the i16 packed-row buffer (grown to `m * k` on first
    /// use, then reused allocation-free); `threads` bounds the scoped
    /// worker count (1 = fully serial). Results are bit-identical for
    /// every `threads` value.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_with(
        &self,
        a: &mut [u8],
        m: usize,
        k: usize,
        wt: &[i16],
        o: usize,
        out: &mut [i32],
        pack: &mut Vec<i16>,
        threads: usize,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(wt.len(), o * k);
        assert_eq!(out.len(), m * o);
        if m == 0 || o == 0 {
            return;
        }
        if k == 0 {
            out.fill(0);
            return;
        }
        if pack.len() < m * k {
            pack.resize(m * k, 0);
        }
        let pack = &mut pack[..m * k];
        // Scale workers to the work: one per MIN_PARALLEL_MACS of MACs,
        // capped by the requested count and the row count. Small GEMMs
        // run serial; sizes just above the cutoff get few threads, so
        // spawn/join never dominates. Results are identical either way
        // (integer accumulation is associative).
        let nt = threads.min((m * k * o / MIN_PARALLEL_MACS).max(1)).clamp(1, m);
        if nt == 1 {
            self.gemm_block(a, m, k, wt, o, out, pack);
            return;
        }
        // Partition output rows into contiguous per-thread blocks; each
        // worker owns disjoint row ranges of `a`, `pack` and `out`.
        let rows_per = m.div_ceil(nt);
        std::thread::scope(|s| {
            let mut a_rest = a;
            let mut p_rest = pack;
            let mut o_rest = out;
            loop {
                let rows = rows_per.min(a_rest.len() / k);
                if rows == 0 {
                    break;
                }
                let (a_blk, a_tail) = std::mem::take(&mut a_rest).split_at_mut(rows * k);
                let (p_blk, p_tail) = std::mem::take(&mut p_rest).split_at_mut(rows * k);
                let (o_blk, o_tail) = std::mem::take(&mut o_rest).split_at_mut(rows * o);
                a_rest = a_tail;
                p_rest = p_tail;
                o_rest = o_tail;
                s.spawn(move || self.gemm_block(a_blk, rows, k, wt, o, o_blk, p_blk));
            }
        });
    }

    /// One thread's share: trim + pack its rows, then the blocked kernel.
    #[allow(clippy::too_many_arguments)]
    fn gemm_block(
        &self,
        a: &mut [u8],
        m: usize,
        k: usize,
        wt: &[i16],
        o: usize,
        out: &mut [i32],
        pack: &mut [i16],
    ) {
        // SPARQ trim fused into row packing: each activation is touched
        // once, written back (so callers observe the trimmed row, as the
        // naive path did) and widened into the i16 panel.
        for (row, prow) in a.chunks_exact_mut(k).zip(pack.chunks_exact_mut(k)) {
            self.lut.trim_slice(row);
            for (d, &s) in prow.iter_mut().zip(row.iter()) {
                *d = i16::from(s);
            }
        }
        for m0 in (0..m).step_by(MC) {
            let mh = MC.min(m - m0);
            for o0 in (0..o).step_by(NC) {
                let oh = NC.min(o - o0);
                for mi in 0..mh {
                    let base = (m0 + mi) * o + o0;
                    out[base..base + oh].fill(0);
                }
                for k0 in (0..k).step_by(KC) {
                    let kh = KC.min(k - k0);
                    for mi in 0..mh {
                        let arow = &pack[(m0 + mi) * k + k0..(m0 + mi) * k + k0 + kh];
                        let obase = (m0 + mi) * o + o0;
                        let mut oi = 0;
                        // 4-column unroll: the packed row is reused from
                        // registers/L1 across four weight streams.
                        while oi + 4 <= oh {
                            let w0 = &wt[(o0 + oi) * k + k0..][..kh];
                            let w1 = &wt[(o0 + oi + 1) * k + k0..][..kh];
                            let w2 = &wt[(o0 + oi + 2) * k + k0..][..kh];
                            let w3 = &wt[(o0 + oi + 3) * k + k0..][..kh];
                            let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
                            for (j, &x) in arow.iter().enumerate() {
                                let xv = i32::from(x);
                                s0 += xv * i32::from(w0[j]);
                                s1 += xv * i32::from(w1[j]);
                                s2 += xv * i32::from(w2[j]);
                                s3 += xv * i32::from(w3[j]);
                            }
                            out[obase + oi] += s0;
                            out[obase + oi + 1] += s1;
                            out[obase + oi + 2] += s2;
                            out[obase + oi + 3] += s3;
                            oi += 4;
                        }
                        while oi < oh {
                            out[obase + oi] += dot_i16(arow, &wt[(o0 + oi) * k + k0..][..kh]);
                            oi += 1;
                        }
                    }
                }
            }
        }
    }

    /// The pre-blocking implementation: unblocked, single-threaded,
    /// fresh row buffer per call. Retained as the bit-exactness baseline
    /// for tests and the before/after measurement in `benches/hotpath.rs`.
    pub fn gemm_naive(
        &self,
        a: &mut [u8],
        m: usize,
        k: usize,
        wt: &[i16],
        o: usize,
        out: &mut [i32],
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(wt.len(), o * k);
        assert_eq!(out.len(), m * o);
        let mut row16 = vec![0i16; k];
        for mi in 0..m {
            let row = &mut a[mi * k..(mi + 1) * k];
            self.lut.trim_slice(row);
            for (d, &s) in row16.iter_mut().zip(row.iter()) {
                *d = i16::from(s);
            }
            let out_row = &mut out[mi * o..(mi + 1) * o];
            for (oi, ov) in out_row.iter_mut().enumerate() {
                *ov = dot_i16(&row16, &wt[oi * k..(oi + 1) * k]);
            }
        }
    }
}

/// Contiguous i16 x i16 dot with i32 accumulation (vectorizes to
/// multiply-add-pairs; values are < 2^15 so products never overflow).
#[inline]
fn dot_i16(a: &[i16], b: &[i16]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    for (&x, &w) in a.iter().zip(b) {
        acc += i32::from(x) * i32::from(w);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vsparq::sparq_dot;

    fn synth(m: usize, k: usize, o: usize) -> (Vec<u8>, Vec<i8>) {
        let a: Vec<u8> = (0..m * k)
            .map(|i| if i % 4 == 0 { 0 } else { ((i * 67) % 256) as u8 })
            .collect();
        let w: Vec<i8> = (0..k * o).map(|i| (((i * 19) % 255) as i32 - 127) as i8).collect();
        (a, w)
    }

    #[test]
    fn gemm_matches_scalar_reference() {
        let (m, k, o) = (7, 34, 5);
        let (a0, w) = synth(m, k, o);
        for name in ["a8w8", "a8w4", "a4w8", "5opt_r", "3opt", "2opt_r", "6opt_r", "7opt_r_novs"] {
            let cfg = SparqConfig::named(name).unwrap();
            let g = QuantGemm::new(cfg);
            let wt = g.prepare_weights(&w, k, o);
            let mut a = a0.clone();
            let mut out = vec![0i32; m * o];
            g.gemm(&mut a, m, k, &wt, o, &mut out);
            for mi in 0..m {
                for oi in 0..o {
                    let col: Vec<i8> = (0..k).map(|r| w[r * o + oi]).collect();
                    assert_eq!(
                        out[mi * o + oi],
                        sparq_dot(&a0[mi * k..(mi + 1) * k], &col, cfg),
                        "{name} ({mi},{oi})"
                    );
                }
            }
        }
    }

    #[test]
    fn odd_k_pads_like_hardware() {
        let (m, k, o) = (2, 9, 3);
        let a0: Vec<u8> = (0..m * k).map(|i| ((i * 53 + 1) % 256) as u8).collect();
        let w = vec![1i8; k * o];
        let cfg = SparqConfig::named("5opt_r").unwrap();
        let g = QuantGemm::new(cfg);
        let wt = g.prepare_weights(&w, k, o);
        let mut a = a0.clone();
        let mut out = vec![0i32; m * o];
        g.gemm(&mut a, m, k, &wt, o, &mut out);
        let col = vec![1i8; k];
        for mi in 0..m {
            assert_eq!(out[mi * o], sparq_dot(&a0[mi * k..(mi + 1) * k], &col, cfg));
        }
    }

    #[test]
    fn blocked_parallel_bit_identical_to_naive_across_tile_edges() {
        // Sizes straddling the MC/NC/KC tile boundaries and the thread
        // partition, including ragged tails.
        let cases = [(1, 1, 1), (3, 17, 4), (16, 768, 32), (17, 769, 33), (40, 1100, 70)];
        for &(m, k, o) in &cases {
            let (a0, w) = synth(m, k, o);
            for name in ["a8w8", "5opt_r", "2opt", "7opt_r"] {
                let cfg = SparqConfig::named(name).unwrap();
                let g = QuantGemm::new(cfg);
                let wt = g.prepare_weights(&w, k, o);

                let mut a_ref = a0.clone();
                let mut want = vec![0i32; m * o];
                g.gemm_naive(&mut a_ref, m, k, &wt, o, &mut want);

                for threads in [1usize, 2, 5, 16] {
                    let mut a = a0.clone();
                    let mut out = vec![-1i32; m * o];
                    let mut pack = Vec::new();
                    g.gemm_with(&mut a, m, k, &wt, o, &mut out, &mut pack, threads);
                    assert_eq!(out, want, "{name} m={m} k={k} o={o} threads={threads}");
                    // the trimmed scratch rows must also agree
                    assert_eq!(a, a_ref, "{name} trimmed rows diverge");
                }
            }
        }
    }

    #[test]
    fn scratch_pack_buffer_is_reused() {
        let (m, k, o) = (6, 50, 4);
        let (a0, w) = synth(m, k, o);
        let cfg = SparqConfig::named("5opt_r").unwrap();
        let g = QuantGemm::new(cfg);
        let wt = g.prepare_weights(&w, k, o);
        let mut pack = Vec::new();
        let mut out1 = vec![0i32; m * o];
        let mut a = a0.clone();
        g.gemm_with(&mut a, m, k, &wt, o, &mut out1, &mut pack, 2);
        let cap = pack.capacity();
        // second run with the same shapes must not reallocate
        let mut out2 = vec![0i32; m * o];
        let mut a = a0.clone();
        g.gemm_with(&mut a, m, k, &wt, o, &mut out2, &mut pack, 2);
        assert_eq!(pack.capacity(), cap);
        assert_eq!(out1, out2);
    }
}
