//! Scoped-thread row-parallel driver for the engine's hot loops.
//!
//! std threads only (rayon is not in the image's offline crate set). The
//! model is deliberately simple: a caller partitions a flat output
//! buffer into fixed-size *units* (GEMM row blocks, conv output rows),
//! and [`par_units`] fans contiguous unit ranges out across scoped
//! threads. Because every unit is a disjoint `&mut` sub-slice, there is
//! no synchronization on the data path at all — the only cost is thread
//! spawn/join, which for the engine's per-conv granularity (hundreds of
//! microseconds to milliseconds of work) is noise.
//!
//! Thread count resolution: `SPARQ_THREADS` env var if set (>= 1),
//! otherwise `std::thread::available_parallelism()`. Benchmarks pass an
//! explicit count to compare serial vs parallel on the same build.

use std::sync::OnceLock;

/// Default worker count: `SPARQ_THREADS` override or the machine's
/// available parallelism. Cached after first read.
pub fn max_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("SPARQ_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Split `data` into `data.len() / unit` contiguous units and run
/// `f(unit_index, unit_slice)` for every unit, distributing contiguous
/// unit ranges over at most `threads` scoped threads.
///
/// `data.len()` must be a multiple of `unit`. With `threads <= 1` (or a
/// single unit) everything runs on the caller's thread — the serial and
/// parallel paths execute the identical per-unit closure, so results are
/// bit-identical by construction.
pub fn par_units<T, F>(data: &mut [T], unit: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(unit > 0, "unit size must be non-zero");
    assert_eq!(data.len() % unit, 0, "data length {} not a multiple of unit {unit}", data.len());
    let n = data.len() / unit;
    if n == 0 {
        return;
    }
    let nt = threads.clamp(1, n);
    if nt == 1 {
        for (i, chunk) in data.chunks_mut(unit).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let per = n.div_ceil(nt);
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = data;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len() / unit);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * unit);
            rest = tail;
            s.spawn(move || {
                for (j, chunk) in head.chunks_mut(unit).enumerate() {
                    f(base + j, chunk);
                }
            });
            base += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn parallel_matches_serial() {
        let unit = 7;
        let n = 23; // deliberately not a multiple of any thread count
        let mut serial = vec![0i64; unit * n];
        let mut parallel = serial.clone();
        let fill = |i: usize, chunk: &mut [i64]| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (i * 1000 + j) as i64;
            }
        };
        par_units(&mut serial, unit, 1, fill);
        for threads in [2, 3, 5, 64] {
            parallel.iter_mut().for_each(|v| *v = -1);
            par_units(&mut parallel, unit, threads, fill);
            assert_eq!(parallel, serial, "threads={threads}");
        }
    }

    #[test]
    fn handles_empty_and_single_unit() {
        let mut empty: Vec<u8> = Vec::new();
        par_units(&mut empty, 4, 8, |_, _| panic!("no units to run"));
        let mut one = vec![0u8; 4];
        par_units(&mut one, 4, 8, |i, c| {
            assert_eq!(i, 0);
            c.fill(9);
        });
        assert_eq!(one, vec![9; 4]);
    }
}
