//! Native integer inference engine (DESIGN.md S15).
//!
//! Executes the exported graph IR (`<tag>_meta.json` + `<tag>_weights.npz`)
//! entirely in rust: float ops for the unquantized pieces (first conv,
//! pooling, residuals, FC head) and bit-exact SPARQ integer GEMMs for
//! every quantized conv. Three uses:
//!
//! 1. cross-validation — logits must match the PJRT/HLO path to float
//!    tolerance, and the integer GEMM outputs are *bit-exact* against
//!    the Pallas kernel semantics (rust/tests/cross_validation.rs);
//! 2. the STC / Table 6 path — 2:4 compressed execution that the HLO
//!    graphs do not model;
//! 3. activation tracing for the toggle/sparsity statistics (exp. F2).
//!
//! # Hot-path architecture
//!
//! A quantized conv travels through four stages, each designed so the
//! steady state is allocation-free and embarrassingly row-parallel:
//!
//! ```text
//!  float input ──quantize──▶ u8 ──im2col──▶ patches (M x K)
//!        │                                      │
//!        │              ┌───────────────────────┘
//!        │              ▼
//!        │   TrimLut trim fused into i16 row packing   (quant::lut)
//!        │              │
//!        │              ▼
//!        │   cache-blocked GEMM: M x O tiles over K panels,
//!        │   4-column register accumulator                (model::gemm)
//!        │              │    rows partitioned over scoped threads
//!        │              ▼                                 (model::threadpool)
//!        └──dequant + bias ◀── i32 accumulator
//! ```
//!
//! * **LUT trim** — the SPARQ eq.-2 case analysis collapses to two
//!   256-entry tables; each activation is touched once per row, not
//!   once per output column.
//! * **Blocked GEMM** — [`gemm::QuantGemm::gemm_with`] tiles M x O with
//!   K panels so the packed rows and the active weight panel stay
//!   cache-resident; integer accumulation is associative, so tiling
//!   and threading are bit-exact vs the retained naive baseline
//!   ([`gemm::QuantGemm::gemm_naive`]).
//! * **Threading** — [`threadpool::par_units`] fans disjoint `&mut`
//!   row ranges over `std::thread::scope` workers (no deps, no locks on
//!   the data path). `SPARQ_THREADS` overrides the worker count.
//! * **Scratch reuse** — [`engine::Scratch`] carries the quantized
//!   input, im2col patches, packed rows and i32 accumulator across
//!   layers and across requests: steady-state serving performs zero
//!   per-request heap allocation on the integer path, and the engine
//!   drops dead intermediate tensors as soon as their last consumer has
//!   run.
//! * **Shared parameters** — [`engine::ModelParams`] holds the graph,
//!   weights and one-off prepared weight tables behind an `Arc`;
//!   [`engine::Engine`] is a cheap per-replica handle, so N serving
//!   replicas (see `coordinator::router`) share a single parameter
//!   copy instead of N deep clones.
//! * **Per-layer policies** — parameters are prepared under a
//!   [`crate::quant::QuantPolicy`]
//!   ([`engine::ModelParams::with_policy`]): one TrimLut per distinct
//!   layer config, per-layer requantized weight tables, and the
//!   forward pass selects each quantized conv's context by name.
//!   Policy *variants* of one model each carry their own `ModelParams`
//!   over the same `Arc<Graph>`/`Arc<Weights>`.
//!
//! Measure it with `cargo bench --bench hotpath` (no artifacts needed):
//! the bench compares the naive single-threaded seed GEMM against the
//! blocked serial and blocked parallel kernels, and runs an end-to-end
//! synthetic-model forward at 1 vs N threads.

#[doc(hidden)]
pub mod demo;
pub mod engine;
pub mod gemm;
pub mod graph;
pub mod threadpool;
pub mod weights;

pub use engine::{Engine, EngineMode, ModelParams, Scratch, TraceSink};
pub use gemm::QuantGemm;
pub use graph::{Graph, Node, Op};
pub use weights::Weights;
