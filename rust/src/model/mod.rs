//! Native integer inference engine (DESIGN.md S15).
//!
//! Executes the exported graph IR (`<tag>_meta.json` + `<tag>_weights.npz`)
//! entirely in rust: float ops for the unquantized pieces (first conv,
//! pooling, residuals, FC head) and bit-exact SPARQ integer GEMMs for
//! every quantized conv. Three uses:
//!
//! 1. cross-validation — logits must match the PJRT/HLO path to float
//!    tolerance, and the integer GEMM outputs are *bit-exact* against
//!    the Pallas kernel semantics (rust/tests/cross_validation.rs);
//! 2. the STC / Table 6 path — 2:4 compressed execution that the HLO
//!    graphs do not model;
//! 3. activation tracing for the toggle/sparsity statistics (exp. F2).

pub mod engine;
pub mod gemm;
pub mod graph;
pub mod weights;

pub use engine::{Engine, EngineMode, TraceSink};
pub use gemm::QuantGemm;
pub use graph::{Graph, Node, Op};
pub use weights::Weights;
