//! Weight store — loads `<tag>_weights.npz` (layout documented in
//! `python/compile/aot.py::export_weights_npz`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::npz::Npz;

/// Weights of one quantized conv, flattened for the im2col GEMM.
#[derive(Clone, Debug)]
pub struct QuantConv {
    /// (K, O) row-major, K ordered (C, kh, kw) — see tensor::im2col.
    pub wq: Vec<i8>,
    pub k: usize,
    pub o: usize,
    /// Per-output-channel dequant scales.
    pub scale: Vec<f32>,
    /// Float bias added after dequantization.
    pub bias: Vec<f32>,
}

/// Weights of a float conv (the unquantized first layer): HWIO.
#[derive(Clone, Debug)]
pub struct FloatConv {
    pub w: Vec<f32>,
    pub kh: usize,
    pub kw: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub bias: Vec<f32>,
}

/// All parameters of one exported model variant.
#[derive(Clone, Debug)]
pub struct Weights {
    pub quant: HashMap<String, QuantConv>,
    pub float: HashMap<String, FloatConv>,
    pub fc_w: Vec<f32>,
    pub fc_in: usize,
    pub fc_out: usize,
    pub fc_b: Vec<f32>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Self> {
        let npz = Npz::read(path)?;
        Self::from_npz(&npz).with_context(|| format!("loading weights {}", path.display()))
    }

    pub fn from_npz(npz: &Npz) -> Result<Self> {
        let mut quant = HashMap::new();
        let mut float = HashMap::new();
        for name in npz.names() {
            if let Some(conv) = name.strip_suffix(".wq") {
                let (shape, wq) = npz.i8(name)?;
                if shape.len() != 2 {
                    bail!("{name}: expected 2-D flattened weights");
                }
                let (_, scale) = npz.f32(&format!("{conv}.scale"))?;
                let (_, bias) = npz.f32(&format!("{conv}.bias"))?;
                if scale.len() != shape[1] || bias.len() != shape[1] {
                    bail!("{conv}: scale/bias length mismatch");
                }
                quant.insert(
                    conv.to_string(),
                    QuantConv {
                        wq: wq.to_vec(),
                        k: shape[0],
                        o: shape[1],
                        scale: scale.to_vec(),
                        bias: bias.to_vec(),
                    },
                );
            } else if let Some(conv) = name.strip_suffix(".w") {
                if conv == "fc" {
                    continue;
                }
                let (shape, w) = npz.f32(name)?;
                if shape.len() != 4 {
                    bail!("{name}: expected HWIO conv weights");
                }
                let (_, bias) = npz.f32(&format!("{conv}.bias"))?;
                float.insert(
                    conv.to_string(),
                    FloatConv {
                        w: w.to_vec(),
                        kh: shape[0],
                        kw: shape[1],
                        c_in: shape[2],
                        c_out: shape[3],
                        bias: bias.to_vec(),
                    },
                );
            }
        }
        let (fc_shape, fc_w) = npz.f32("fc.w")?;
        let (_, fc_b) = npz.f32("fc.b")?;
        if fc_shape.len() != 2 {
            bail!("fc.w must be 2-D");
        }
        Ok(Self {
            quant,
            float,
            fc_w: fc_w.to_vec(),
            fc_in: fc_shape[0],
            fc_out: fc_shape[1],
            fc_b: fc_b.to_vec(),
        })
    }

    pub fn quant_conv(&self, name: &str) -> Result<&QuantConv> {
        self.quant.get(name).with_context(|| format!("no quantized weights for `{name}`"))
    }

    pub fn float_conv(&self, name: &str) -> Result<&FloatConv> {
        self.float.get(name).with_context(|| format!("no float weights for `{name}`"))
    }

    /// Approximate heap bytes held by the parameter store — the cost a
    /// replica engine used to pay per deep clone before Arc sharing,
    /// and what the serving router reports as the (single) shared
    /// parameter footprint.
    pub fn param_bytes(&self) -> usize {
        let f32s = self
            .float
            .values()
            .map(|f| f.w.len() + f.bias.len())
            .sum::<usize>()
            + self.quant.values().map(|q| q.scale.len() + q.bias.len()).sum::<usize>()
            + self.fc_w.len()
            + self.fc_b.len();
        let i8s = self.quant.values().map(|q| q.wq.len()).sum::<usize>();
        f32s * std::mem::size_of::<f32>() + i8s
    }

    /// Total parameter count (reporting).
    pub fn param_count(&self) -> usize {
        self.quant.values().map(|q| q.wq.len() + q.scale.len() + q.bias.len()).sum::<usize>()
            + self.float.values().map(|f| f.w.len() + f.bias.len()).sum::<usize>()
            + self.fc_w.len()
            + self.fc_b.len()
    }
}
