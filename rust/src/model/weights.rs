//! Weight store — loads `<tag>_weights.npz` (layout documented in
//! `python/compile/aot.py::export_weights_npz`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::npz::Npz;

/// Weights of one quantized conv, flattened for the im2col GEMM.
#[derive(Clone, Debug)]
pub struct QuantConv {
    /// (K, O) row-major, K ordered (C, kh, kw) — see tensor::im2col.
    pub wq: Vec<i8>,
    pub k: usize,
    pub o: usize,
    /// Per-output-channel dequant scales.
    pub scale: Vec<f32>,
    /// Float bias added after dequantization.
    pub bias: Vec<f32>,
}

/// Weights of a float conv (the unquantized first layer): HWIO.
#[derive(Clone, Debug)]
pub struct FloatConv {
    pub w: Vec<f32>,
    pub kh: usize,
    pub kw: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub bias: Vec<f32>,
}

/// All parameters of one exported model variant.
#[derive(Clone, Debug)]
pub struct Weights {
    pub quant: HashMap<String, QuantConv>,
    pub float: HashMap<String, FloatConv>,
    pub fc_w: Vec<f32>,
    pub fc_in: usize,
    pub fc_out: usize,
    pub fc_b: Vec<f32>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Self> {
        let npz = Npz::read(path)?;
        Self::from_npz(&npz).with_context(|| format!("loading weights {}", path.display()))
    }

    pub fn from_npz(npz: &Npz) -> Result<Self> {
        let mut quant = HashMap::new();
        let mut float = HashMap::new();
        for name in npz.names() {
            if let Some(conv) = name.strip_suffix(".wq") {
                let (shape, wq) = npz.i8(name)?;
                if shape.len() != 2 {
                    bail!("{name}: expected 2-D flattened weights");
                }
                let (_, scale) = npz.f32(&format!("{conv}.scale"))?;
                let (_, bias) = npz.f32(&format!("{conv}.bias"))?;
                if scale.len() != shape[1] || bias.len() != shape[1] {
                    bail!("{conv}: scale/bias length mismatch");
                }
                quant.insert(
                    conv.to_string(),
                    QuantConv {
                        wq: wq.to_vec(),
                        k: shape[0],
                        o: shape[1],
                        scale: scale.to_vec(),
                        bias: bias.to_vec(),
                    },
                );
            } else if let Some(conv) = name.strip_suffix(".w") {
                if conv == "fc" {
                    continue;
                }
                let (shape, w) = npz.f32(name)?;
                if shape.len() != 4 {
                    bail!("{name}: expected HWIO conv weights");
                }
                let (_, bias) = npz.f32(&format!("{conv}.bias"))?;
                float.insert(
                    conv.to_string(),
                    FloatConv {
                        w: w.to_vec(),
                        kh: shape[0],
                        kw: shape[1],
                        c_in: shape[2],
                        c_out: shape[3],
                        bias: bias.to_vec(),
                    },
                );
            }
        }
        let (fc_shape, fc_w) = npz.f32("fc.w")?;
        let (_, fc_b) = npz.f32("fc.b")?;
        if fc_shape.len() != 2 {
            bail!("fc.w must be 2-D");
        }
        Ok(Self {
            quant,
            float,
            fc_w: fc_w.to_vec(),
            fc_in: fc_shape[0],
            fc_out: fc_shape[1],
            fc_b: fc_b.to_vec(),
        })
    }

    pub fn quant_conv(&self, name: &str) -> Result<&QuantConv> {
        self.quant.get(name).with_context(|| format!("no quantized weights for `{name}`"))
    }

    pub fn float_conv(&self, name: &str) -> Result<&FloatConv> {
        self.float.get(name).with_context(|| format!("no float weights for `{name}`"))
    }

    /// Approximate heap bytes held by the parameter store — the cost a
    /// replica engine used to pay per deep clone before Arc sharing,
    /// and what the serving router reports as the (single) shared
    /// parameter footprint.
    pub fn param_bytes(&self) -> usize {
        let f32s = self
            .float
            .values()
            .map(|f| f.w.len() + f.bias.len())
            .sum::<usize>()
            + self.quant.values().map(|q| q.scale.len() + q.bias.len()).sum::<usize>()
            + self.fc_w.len()
            + self.fc_b.len();
        let i8s = self.quant.values().map(|q| q.wq.len()).sum::<usize>();
        f32s * std::mem::size_of::<f32>() + i8s
    }

    /// Total parameter count (reporting).
    pub fn param_count(&self) -> usize {
        self.quant.values().map(|q| q.wq.len() + q.scale.len() + q.bias.len()).sum::<usize>()
            + self.float.values().map(|f| f.w.len() + f.bias.len()).sum::<usize>()
            + self.fc_w.len()
            + self.fc_b.len()
    }

    /// Check that `incoming` is shape-compatible with this parameter
    /// store: same conv name sets and per-conv dimensions, same fc
    /// dimensions. This is the staged-reload validation — a hot-swapped
    /// weight version must drop into the live graph's prepared-table
    /// slots without re-deriving anything structural. Values are free
    /// to differ; only shapes are compared.
    pub fn same_shapes(&self, incoming: &Weights) -> Result<()> {
        let names = |m: &HashMap<String, QuantConv>| {
            let mut v: Vec<String> = m.keys().cloned().collect();
            v.sort();
            v
        };
        let fnames = |m: &HashMap<String, FloatConv>| {
            let mut v: Vec<String> = m.keys().cloned().collect();
            v.sort();
            v
        };
        if names(&self.quant) != names(&incoming.quant) {
            bail!(
                "quant conv set mismatch: live [{}] vs incoming [{}]",
                names(&self.quant).join(", "),
                names(&incoming.quant).join(", ")
            );
        }
        if fnames(&self.float) != fnames(&incoming.float) {
            bail!(
                "float conv set mismatch: live [{}] vs incoming [{}]",
                fnames(&self.float).join(", "),
                fnames(&incoming.float).join(", ")
            );
        }
        for (name, q) in &self.quant {
            let n = &incoming.quant[name];
            if (q.k, q.o) != (n.k, n.o) {
                bail!("{name}: shape (K={}, O={}) vs incoming (K={}, O={})", q.k, q.o, n.k, n.o);
            }
            if n.wq.len() != n.k * n.o || n.scale.len() != n.o || n.bias.len() != n.o {
                bail!("{name}: incoming weight/scale/bias lengths inconsistent with (K, O)");
            }
        }
        for (name, f) in &self.float {
            let n = &incoming.float[name];
            if (f.kh, f.kw, f.c_in, f.c_out) != (n.kh, n.kw, n.c_in, n.c_out) {
                bail!(
                    "{name}: shape {}x{}x{}x{} vs incoming {}x{}x{}x{}",
                    f.kh,
                    f.kw,
                    f.c_in,
                    f.c_out,
                    n.kh,
                    n.kw,
                    n.c_in,
                    n.c_out
                );
            }
        }
        if (self.fc_in, self.fc_out) != (incoming.fc_in, incoming.fc_out) {
            bail!(
                "fc: shape {}x{} vs incoming {}x{}",
                self.fc_in,
                self.fc_out,
                incoming.fc_in,
                incoming.fc_out
            );
        }
        Ok(())
    }

    /// Deterministic 64-bit content hash (FNV-1a over shapes and raw
    /// parameter bytes, conv names visited in sorted order), rendered as
    /// 16 hex chars. This is the `weights_sha` the versioned model
    /// registry surfaces in `/v1/models`: two `Weights` values hash
    /// equal iff every tensor is bit-identical, independent of
    /// `HashMap` iteration order or which allocation holds them.
    pub fn content_sha(&self) -> String {
        let mut h = Fnv1a::new();
        let mut names: Vec<&String> = self.quant.keys().collect();
        names.sort();
        for name in names {
            let q = &self.quant[name];
            h.update(name.as_bytes());
            h.update_usize(q.k);
            h.update_usize(q.o);
            h.update_i8(&q.wq);
            h.update_f32(&q.scale);
            h.update_f32(&q.bias);
        }
        let mut names: Vec<&String> = self.float.keys().collect();
        names.sort();
        for name in names {
            let f = &self.float[name];
            h.update(name.as_bytes());
            h.update_usize(f.kh);
            h.update_usize(f.kw);
            h.update_usize(f.c_in);
            h.update_usize(f.c_out);
            h.update_f32(&f.w);
            h.update_f32(&f.bias);
        }
        h.update_usize(self.fc_in);
        h.update_usize(self.fc_out);
        h.update_f32(&self.fc_w);
        h.update_f32(&self.fc_b);
        format!("{:016x}", h.finish())
    }
}

/// Minimal FNV-1a (64-bit) — dependency-free and stable across
/// platforms, which is all a change-detection fingerprint needs.
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn update_usize(&mut self, v: usize) {
        self.update(&(v as u64).to_le_bytes());
    }

    fn update_i8(&mut self, vs: &[i8]) {
        for &v in vs {
            self.update(&[v as u8]);
        }
    }

    fn update_f32(&mut self, vs: &[f32]) {
        for &v in vs {
            self.update(&v.to_bits().to_le_bytes());
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use crate::model::demo::synth_model;

    #[test]
    fn content_sha_is_deterministic_and_bit_sensitive() {
        let (_, weights, _) = synth_model();
        let (_, again, _) = synth_model();
        assert_eq!(weights.content_sha(), again.content_sha());
        assert_eq!(weights.content_sha().len(), 16);

        let mut perturbed = weights.clone();
        let q = perturbed.quant.get_mut("q2").expect("demo model has q2");
        q.wq[0] = q.wq[0].wrapping_add(1);
        assert_ne!(weights.content_sha(), perturbed.content_sha());
    }

    #[test]
    fn same_shapes_accepts_value_changes_and_rejects_shape_changes() {
        let (_, weights, _) = synth_model();
        let mut perturbed = weights.clone();
        for q in perturbed.quant.values_mut() {
            for w in &mut q.wq {
                *w = w.wrapping_add(3);
            }
        }
        weights.same_shapes(&perturbed).expect("value-only change must pass");

        let mut reshaped = weights.clone();
        {
            let q = reshaped.quant.get_mut("q2").expect("demo model has q2");
            q.o += 1;
        }
        let err = weights.same_shapes(&reshaped).unwrap_err().to_string();
        assert!(err.contains("q2"), "error names the offending conv: {err}");

        let mut missing = weights.clone();
        missing.quant.remove("q3");
        assert!(weights.same_shapes(&missing).is_err());
    }
}
