//! Graph executor — float ops + SPARQ integer convs (DESIGN.md S15).
//!
//! Serving hot path (see the module doc in [`super`]): per quantized
//! conv the input is uniform-quantized into reusable scratch, im2col'd
//! into reusable scratch, trimmed through the [`TrimLut`] fused into
//! row packing, and multiplied by the prepared (O, K) i16 weights with
//! the cache-blocked row-parallel GEMM. A [`Scratch`] carries the four
//! hot buffers (quantized input, im2col patches, packed rows, i32
//! accumulator) across layers *and* across requests, so steady-state
//! serving performs zero per-request heap allocation on those paths.
//! Intermediate tensors are dropped from the value map as soon as their
//! last consumer has run, holding peak memory to the graph's live set.
//!
//! # Shared parameters
//!
//! Everything immutable about a ready-to-run model — graph, weights,
//! prepared/compressed weight tables, activation scales, liveness map —
//! lives in one [`ModelParams`] behind an `Arc`. An [`Engine`] is a
//! cheap handle (`Arc` + a thread-count knob): N replica engines for
//! serving, per-config sweeps, or traced statistics runs all share a
//! single parameter copy instead of each paying a full deep clone of
//! graph + weights + prepared tables (the pre-Arc behaviour). Replica
//! count is therefore a runtime knob, not a memory multiplier.
//!
//! # Per-layer policies
//!
//! Parameters are prepared under a [`QuantPolicy`]
//! ([`ModelParams::with_policy`]): the policy lowers to one
//! [`SparqConfig`] per quantized conv, the params build one
//! [`QuantGemm`] (TrimLut) per *distinct* config plus a per-layer
//! requantized weight table, and the forward pass selects each layer's
//! context by name. `ModelParams::new` / [`Engine::new`] remain the
//! uniform-policy convenience. Multiple policy *variants* of one model
//! (see `coordinator::router`) each carry their own `ModelParams` while
//! sharing the same `Arc<Graph>` and `Arc<Weights>` — the weight bytes
//! exist once no matter how many operating points are served.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::hw::stc::{stc_gemm, CompressedWeights};
use crate::quant::minmax::ActScale;
use crate::quant::{QuantPolicy, SparqConfig};
use crate::tensor::{im2col_u8_into, out_dim, same_padding, TensorF32};

use super::gemm::QuantGemm;
use super::graph::{Graph, Node, Op};
use super::threadpool;
use super::weights::Weights;

/// How quantized convs execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// Dense SPARQ GEMM (the Table 1–4 path; bit-exact vs the HLO).
    Dense,
    /// 2:4 Sparse-Tensor-Core datapath (the Table 6 path). Requires the
    /// model's quantized weights to be 2:4 structured.
    Stc,
}

/// Observer for quantized activations (drives the toggle statistics).
pub trait TraceSink {
    /// Called once per quantized conv per forward with the uniform-
    /// quantized (untrimmed) im2col activations.
    fn record(&mut self, layer: &str, acts_q: &[u8]);
}

/// No-op sink.
pub struct NoTrace;

impl TraceSink for NoTrace {
    fn record(&mut self, _layer: &str, _acts_q: &[u8]) {}
}

/// Reusable per-worker forward buffers. All four grow to the largest
/// layer shape on the first forward and are then reused allocation-free;
/// one `Scratch` must not be shared across concurrent forwards (give
/// each serving worker its own).
#[derive(Default)]
pub struct Scratch {
    /// Uniform-quantized input activations (u8).
    xq: Vec<u8>,
    /// im2col patch matrix (M x K, u8).
    patches: Vec<u8>,
    /// Trimmed rows packed to i16 for the vectorized inner dot.
    pack: Vec<i16>,
    /// Integer GEMM accumulator (M x O, i32).
    acc: Vec<i32>,
    /// K-padded patch copy for the STC datapath (K % 4 != 0 only).
    stc_pad: Vec<u8>,
}

/// Grow-only view: resizes the buffer if needed, returns exactly `n`
/// elements. Capacity is retained across calls, so repeated forwards
/// with stable shapes never reallocate.
fn grown<T: Copy + Default>(buf: &mut Vec<T>, n: usize) -> &mut [T] {
    if buf.len() < n {
        buf.resize(n, T::default());
    }
    &mut buf[..n]
}

/// Per-layer execution state: which of the deduplicated GEMM contexts
/// (TrimLuts) this layer runs, plus the weights prepared under exactly
/// that layer's config.
struct LayerExec {
    /// Index into [`ModelParams`]'s `gemms` vector.
    gemm: usize,
    /// Dense-mode prepared (O, K) i16 weights (empty in STC mode).
    prepared: Vec<i16>,
    /// STC-mode 2:4 compressed weights.
    compressed: Option<CompressedWeights>,
}

/// The immutable, shareable half of a ready-to-run model: graph,
/// weights, the resolved per-layer quantization policy, activation
/// scales, and the one-off derived tables (requantized+transposed dense
/// weights or 2:4 compressed weights, plus the value-liveness map).
/// Built once, shared by every [`Engine`] replica via `Arc` — the
/// prepared tables are the expensive part of engine construction and
/// are never duplicated.
///
/// A [`QuantPolicy`] lowers to one [`SparqConfig`] per quantized conv;
/// the params prepare **one [`QuantGemm`] (TrimLut) per *distinct*
/// layer config** and point each layer at its context, so a
/// first/last-at-8-bit policy over a 50-layer model costs two LUTs, not
/// fifty — and a uniform policy costs exactly one, as before.
pub struct ModelParams {
    pub graph: Arc<Graph>,
    pub weights: Arc<Weights>,
    policy: QuantPolicy,
    /// The lowered policy: one config per quant conv, `quant_convs`
    /// order.
    plan: Vec<SparqConfig>,
    mode: EngineMode,
    scales: HashMap<String, ActScale>,
    /// Deduplicated GEMM contexts, one per distinct config in `plan`.
    gemms: Vec<QuantGemm>,
    /// Layer name -> its GEMM context + prepared weight tables.
    layers: HashMap<String, LayerExec>,
    /// Per-image im2col activation volume per quant conv (`quant_convs`
    /// order) — the weights for policy footprint accounting.
    act_volumes: Vec<usize>,
    /// Value name -> index of its last consuming node (drives eager
    /// dropping of dead intermediates during forward).
    last_use: HashMap<String, usize>,
}

impl ModelParams {
    /// Uniform-policy convenience: every quantized conv runs `cfg`.
    /// `act_scales` ordered by `graph.quant_convs` (from calibration).
    pub fn new(
        graph: Arc<Graph>,
        weights: Arc<Weights>,
        cfg: SparqConfig,
        act_scales: &[f32],
        mode: EngineMode,
    ) -> Result<Self> {
        Self::with_policy(graph, weights, QuantPolicy::uniform(cfg), act_scales, mode)
    }

    /// Build the parameter block under a per-layer [`QuantPolicy`]: the
    /// policy is lowered against the graph, one GEMM context (TrimLut)
    /// is prepared per *distinct* layer config, and every layer's
    /// weight table is requantized under that layer's own config.
    pub fn with_policy(
        graph: Arc<Graph>,
        weights: Arc<Weights>,
        policy: QuantPolicy,
        act_scales: &[f32],
        mode: EngineMode,
    ) -> Result<Self> {
        if act_scales.len() != graph.quant_convs.len() {
            bail!(
                "need {} activation scales, got {}",
                graph.quant_convs.len(),
                act_scales.len()
            );
        }
        let plan = policy.layer_plan(&graph)?;
        let act_volumes = graph.quant_act_volumes()?;
        let mut gemms: Vec<QuantGemm> = Vec::new();
        let mut scales = HashMap::new();
        let mut layers = HashMap::new();
        for ((name, &s), &cfg) in graph.quant_convs.iter().zip(act_scales).zip(&plan) {
            scales.insert(name.clone(), ActScale(s));
            let gemm_idx = match gemms.iter().position(|g| g.cfg() == cfg) {
                Some(i) => i,
                None => {
                    gemms.push(QuantGemm::new(cfg));
                    gemms.len() - 1
                }
            };
            let qc = weights.quant_conv(name)?;
            let exec = match mode {
                EngineMode::Dense => LayerExec {
                    gemm: gemm_idx,
                    prepared: gemms[gemm_idx].prepare_weights(&qc.wq, qc.k, qc.o),
                    compressed: None,
                },
                EngineMode::Stc => {
                    // Requantization of the survivors happens at execute
                    // time (stc_gemm handles w_bits).
                    let padded;
                    let (wq, k) = if qc.k % 4 == 0 {
                        (&qc.wq, qc.k)
                    } else {
                        // pad K to a multiple of 4 with zero rows (the
                        // trailing partial group the pruner left dense
                        // cannot arise for our zoo; guard anyway)
                        let k4 = qc.k.div_ceil(4) * 4;
                        let mut w = vec![0i8; k4 * qc.o];
                        w[..qc.k * qc.o].copy_from_slice(&qc.wq);
                        padded = w;
                        (&padded, k4)
                    };
                    let c = CompressedWeights::compress(wq, k, qc.o).map_err(|e| {
                        anyhow::anyhow!("{name}: weights not 2:4 structured ({e})")
                    })?;
                    LayerExec { gemm: gemm_idx, prepared: Vec::new(), compressed: Some(c) }
                }
            };
            layers.insert(name.clone(), exec);
        }
        let mut last_use = HashMap::new();
        for (i, node) in graph.nodes.iter().enumerate() {
            for input in &node.inputs {
                last_use.insert(input.clone(), i);
            }
        }
        Ok(Self {
            graph,
            weights,
            policy,
            plan,
            mode,
            scales,
            gemms,
            layers,
            act_volumes,
            last_use,
        })
    }

    pub fn mode(&self) -> EngineMode {
        self.mode
    }

    /// The per-layer policy these parameters were prepared under.
    pub fn policy(&self) -> &QuantPolicy {
        &self.policy
    }

    /// The policy's default config. For uniform-policy models (the
    /// pre-policy API and every `ModelParams::new` caller) this is
    /// *the* configuration of every layer.
    pub fn default_cfg(&self) -> SparqConfig {
        self.policy.default_cfg()
    }

    /// Resolved `(layer name, config)` pairs, `graph.quant_convs` order.
    pub fn layer_cfgs(&self) -> Vec<(String, SparqConfig)> {
        self.graph
            .quant_convs
            .iter()
            .zip(&self.plan)
            .map(|(n, &c)| (n.clone(), c))
            .collect()
    }

    /// Number of distinct GEMM contexts (TrimLuts) the policy resolved
    /// to — 1 for any uniform policy.
    pub fn distinct_configs(&self) -> usize {
        self.gemms.len()
    }

    /// Policy-weighted storage bits per quantized activation (§5.1
    /// metadata model, weighted by each layer's im2col volume).
    /// `shift_group` as in [`crate::quant::footprint::bits_per_activation`].
    pub fn footprint_bits(&self, shift_group: u32) -> f64 {
        crate::quant::footprint::policy_bits_per_activation(
            &self.plan,
            &self.act_volumes,
            shift_group,
        )
    }

    /// The calibration activation scales these params were prepared
    /// with, in `graph.quant_convs` order — what a staged reload reuses
    /// when only weights or policy change.
    pub fn act_scales(&self) -> Vec<f32> {
        self.graph
            .quant_convs
            .iter()
            .map(|n| self.scales.get(n).map_or(0.0, |s| s.0))
            .collect()
    }

    /// Stage a fresh parameter block with a **new policy** over this
    /// block's graph/weights/scales. The expensive prepared tables are
    /// rebuilt off-thread by the caller (the registry's staged-load
    /// path); the graph and weight allocations are shared untouched.
    pub fn restage_policy(&self, policy: QuantPolicy) -> Result<Self> {
        Self::with_policy(
            Arc::clone(&self.graph),
            Arc::clone(&self.weights),
            policy,
            &self.act_scales(),
            self.mode,
        )
    }

    /// Stage a fresh parameter block with **new weights** under this
    /// block's graph/policy/scales — the weight-hot-swap path. The
    /// incoming store is validated shape-for-shape against the live one
    /// ([`Weights::same_shapes`]) before any table is prepared, so a
    /// mis-shaped upload fails loudly at staging time instead of
    /// corrupting the serving path.
    pub fn restage_weights(&self, weights: Arc<Weights>) -> Result<Self> {
        self.weights
            .same_shapes(&weights)
            .context("incoming weights incompatible with live graph")?;
        Self::with_policy(
            Arc::clone(&self.graph),
            weights,
            self.policy.clone(),
            &self.act_scales(),
            self.mode,
        )
    }
}

/// A ready-to-run model handle: shared [`ModelParams`] + a per-handle
/// worker-thread knob.
///
/// Construct with [`Engine::new`] (builds its own params from borrowed
/// graph/weights — one copy, source-compatible with the pre-Arc API) or
/// [`Engine::from_params`] (shares an existing `Arc<ModelParams>` with
/// zero parameter copying — the multi-replica path).
pub struct Engine {
    params: Arc<ModelParams>,
    /// Worker threads for the GEMM / float-conv row partition.
    threads: usize,
}

impl Engine {
    /// Uniform-config engine — `act_scales` ordered by
    /// `graph.quant_convs` (from calibration).
    pub fn new(
        graph: &Graph,
        weights: &Weights,
        cfg: SparqConfig,
        act_scales: &[f32],
        mode: EngineMode,
    ) -> Result<Self> {
        Self::with_policy(graph, weights, QuantPolicy::uniform(cfg), act_scales, mode)
    }

    /// Engine under a per-layer [`QuantPolicy`] (builds its own params
    /// from borrowed graph/weights — one copy; the multi-variant
    /// serving path shares an `Arc<ModelParams>` via
    /// [`Engine::from_params`] instead).
    pub fn with_policy(
        graph: &Graph,
        weights: &Weights,
        policy: QuantPolicy,
        act_scales: &[f32],
        mode: EngineMode,
    ) -> Result<Self> {
        let params = ModelParams::with_policy(
            Arc::new(graph.clone()),
            Arc::new(weights.clone()),
            policy,
            act_scales,
            mode,
        )?;
        Ok(Self::from_params(Arc::new(params)))
    }

    /// A replica engine sharing `params` — no graph/weights/prepared-
    /// table copies. This is what the serving router spawns per shard.
    pub fn from_params(params: Arc<ModelParams>) -> Self {
        Self { params, threads: threadpool::max_threads() }
    }

    /// The shared parameter block (graph, weights, prepared tables).
    pub fn params(&self) -> &Arc<ModelParams> {
        &self.params
    }

    pub fn graph(&self) -> &Graph {
        &self.params.graph
    }

    pub fn weights(&self) -> &Weights {
        &self.params.weights
    }

    /// The policy's default config (for uniform-policy engines — every
    /// `Engine::new` caller — this is *the* config of every layer).
    pub fn cfg(&self) -> SparqConfig {
        self.params.default_cfg()
    }

    /// The per-layer quantization policy this engine runs.
    pub fn policy(&self) -> &QuantPolicy {
        self.params.policy()
    }

    pub fn mode(&self) -> EngineMode {
        self.params.mode
    }

    /// Override the worker-thread count (1 = fully serial). Defaults to
    /// [`threadpool::max_threads`]. Results are identical for any value.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Forward a normalized image batch `[batch, H, W, C]` -> logits
    /// `[batch, classes]` row-major. Allocates transient scratch; the
    /// serving path uses [`Engine::forward_scratch`] instead.
    pub fn forward(&self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        self.forward_scratch(images, batch, &mut Scratch::default())
    }

    /// Forward with caller-owned reusable [`Scratch`] — the steady-state
    /// serving entry point (zero per-request allocation on the quantized
    /// hot path once the scratch has warmed up).
    pub fn forward_scratch(
        &self,
        images: &[f32],
        batch: usize,
        scratch: &mut Scratch,
    ) -> Result<Vec<f32>> {
        self.forward_traced_scratch(images, batch, scratch, &mut NoTrace)
    }

    pub fn forward_traced(
        &self,
        images: &[f32],
        batch: usize,
        sink: &mut dyn TraceSink,
    ) -> Result<Vec<f32>> {
        self.forward_traced_scratch(images, batch, &mut Scratch::default(), sink)
    }

    pub fn forward_traced_scratch(
        &self,
        images: &[f32],
        batch: usize,
        scratch: &mut Scratch,
        sink: &mut dyn TraceSink,
    ) -> Result<Vec<f32>> {
        let p = &*self.params;
        let [h, w, c] = p.graph.input_hwc;
        if images.len() != batch * h * w * c {
            bail!("input length {} != {}", images.len(), batch * h * w * c);
        }
        let mut vals: HashMap<&str, TensorF32> = HashMap::new();
        vals.insert("img", TensorF32::from_vec(batch, h, w, c, images.to_vec()));
        let mut logits = Vec::new();
        for (idx, node) in p.graph.nodes.iter().enumerate() {
            let get = |name: &String| -> Result<&TensorF32> {
                vals.get(name.as_str()).with_context(|| format!("missing value {name}"))
            };
            // `None` means "produces no value-map entry" (a terminal fc).
            let out: Option<TensorF32> = match &node.op {
                Op::Input => continue,
                Op::Conv { quant: false, k, stride, relu, .. } => {
                    let x = get(&node.inputs[0])?;
                    let mut y = self.float_conv(node, x, *k, *stride)?;
                    if *relu {
                        y.relu_inplace();
                    }
                    Some(y)
                }
                Op::Conv { quant: true, k, stride, relu, .. } => {
                    let x = get(&node.inputs[0])?;
                    let mut y = self.quant_conv(node, x, *k, *stride, scratch, sink)?;
                    if *relu {
                        y.relu_inplace();
                    }
                    Some(y)
                }
                Op::Pool { avg } => {
                    let x = get(&node.inputs[0])?;
                    Some(if *avg { x.avgpool2() } else { x.maxpool2() })
                }
                Op::Gap => {
                    let x = get(&node.inputs[0])?;
                    let g = x.gap();
                    Some(TensorF32::from_vec(x.n, 1, 1, x.c, g))
                }
                Op::Add => Some(get(&node.inputs[0])?.add(get(&node.inputs[1])?)),
                Op::Relu => {
                    let mut y = get(&node.inputs[0])?.clone();
                    y.relu_inplace();
                    Some(y)
                }
                Op::Concat => {
                    let parts: Vec<&TensorF32> =
                        node.inputs.iter().map(|i| get(i)).collect::<Result<_>>()?;
                    Some(TensorF32::concat_channels(&parts))
                }
                Op::Fc { out } => {
                    // fc is the single, terminal logits sink. A second
                    // head would silently overwrite the first (the seed
                    // bug), and a downstream consumer's effect would be
                    // silently ignored (forward returns `logits`, not a
                    // vals entry) — refuse both loudly.
                    if !logits.is_empty() {
                        bail!(
                            "node `{}` is a second fc head; the engine supports one logits sink",
                            node.name
                        );
                    }
                    if p.last_use.contains_key(node.name.as_str()) {
                        bail!(
                            "fc node `{}` has downstream consumers; fc must be terminal",
                            node.name
                        );
                    }
                    let x = get(&node.inputs[0])?;
                    if x.c != p.weights.fc_in {
                        bail!("fc input width {} != {}", x.c, p.weights.fc_in);
                    }
                    logits = vec![0f32; x.n * out];
                    for n in 0..x.n {
                        for oi in 0..*out {
                            let mut acc = p.weights.fc_b[oi];
                            for ci in 0..x.c {
                                acc += x.data[n * x.c + ci] * p.weights.fc_w[ci * out + oi];
                            }
                            logits[n * out + oi] = acc;
                        }
                    }
                    None
                }
            };
            // Drop dead intermediates: a value whose last consumer just
            // ran can never be read again.
            for input in &node.inputs {
                if p.last_use.get(input.as_str()) == Some(&idx) {
                    vals.remove(input.as_str());
                }
            }
            if let Some(out) = out {
                vals.insert(node.name.as_str(), out);
            }
        }
        if logits.is_empty() {
            bail!("graph produced no logits");
        }
        Ok(logits)
    }

    /// Direct float convolution (unquantized first layer), SAME padding,
    /// row-parallel: each (image, output-row) pair is an independent
    /// unit, and per-element accumulation order is unchanged vs the
    /// serial loop, so results are bit-identical for any thread count.
    fn float_conv(&self, node: &Node, x: &TensorF32, k: usize, stride: usize) -> Result<TensorF32> {
        let fw = self.params.weights.float_conv(&node.name)?;
        if (fw.kh, fw.kw, fw.c_in) != (k, k, x.c) {
            bail!("conv {} shape mismatch", node.name);
        }
        let (oh, ow) = (out_dim(x.h, stride), out_dim(x.w, stride));
        let (pad_t, _) = same_padding(x.h, k, stride);
        let (pad_l, _) = same_padding(x.w, k, stride);
        let mut y = TensorF32::zeros(x.n, oh, ow, fw.c_out);
        let unit = ow * fw.c_out;
        // Same work-scaled worker count as the quantized GEMM: one per
        // MIN_PARALLEL_MACS of work, so tiny convs run serial and sizes
        // just above the cutoff don't spawn a full thread complement.
        let macs = x.n * oh * ow * fw.c_out * k * k * x.c;
        let threads = self.threads.min((macs / super::gemm::MIN_PARALLEL_MACS).max(1));
        threadpool::par_units(&mut y.data, unit, threads, |row_idx, row| {
            let (n, oy) = (row_idx / oh, row_idx % oh);
            for ox in 0..ow {
                for co in 0..fw.c_out {
                    let mut acc = fw.bias[co];
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad_t as isize;
                        if iy < 0 || iy >= x.h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad_l as isize;
                            if ix < 0 || ix >= x.w as isize {
                                continue;
                            }
                            for ci in 0..x.c {
                                acc += x.at(n, iy as usize, ix as usize, ci)
                                    * fw.w[((ky * k + kx) * fw.c_in + ci) * fw.c_out + co];
                            }
                        }
                    }
                    row[ox * fw.c_out + co] = acc;
                }
            }
        });
        Ok(y)
    }

    /// SPARQ quantized conv: quantize input, im2col, trim+GEMM, dequant —
    /// all integer stages through reusable scratch.
    fn quant_conv(
        &self,
        node: &Node,
        x: &TensorF32,
        k: usize,
        stride: usize,
        scratch: &mut Scratch,
        sink: &mut dyn TraceSink,
    ) -> Result<TensorF32> {
        let p = &*self.params;
        let qc = p.weights.quant_conv(&node.name)?;
        let scale = p.scales[&node.name];
        // quantize the (non-negative) float input to u8
        let xq = grown(&mut scratch.xq, x.data.len());
        scale.quantize_slice_into(&x.data, xq);
        // im2col in the shared (C, kh, kw) feature order
        let (oh, ow) = (out_dim(x.h, stride), out_dim(x.w, stride));
        let m = x.n * oh * ow;
        let kk = x.c * k * k;
        let patches = grown(&mut scratch.patches, m * kk);
        im2col_u8_into(xq, x.n, x.h, x.w, x.c, k, stride, patches);
        sink.record(&node.name, patches);

        // Per-layer config: the policy's plan decided which prepared
        // GEMM context (TrimLut) and weight table this layer runs.
        let le = &p.layers[&node.name];
        let gemm = &p.gemms[le.gemm];
        let lcfg = gemm.cfg();
        let wrs = lcfg.weight_rescale();
        let stc_out;
        let acc: &[i32] = match p.mode {
            EngineMode::Dense => {
                let acc = grown(&mut scratch.acc, m * qc.o);
                gemm.gemm_with(
                    patches,
                    m,
                    kk,
                    &le.prepared,
                    qc.o,
                    acc,
                    &mut scratch.pack,
                    self.threads,
                );
                acc
            }
            EngineMode::Stc => {
                let cw = le.compressed.as_ref().expect("STC layer has compressed weights");
                // pad patches K to the compressed K if needed
                let src: &[u8] = if cw.k != kk {
                    let padded = grown(&mut scratch.stc_pad, m * cw.k);
                    padded.fill(0);
                    for mi in 0..m {
                        padded[mi * cw.k..mi * cw.k + kk]
                            .copy_from_slice(&patches[mi * kk..(mi + 1) * kk]);
                    }
                    padded
                } else {
                    patches
                };
                // stc_gemm owns its output; read it in place (the STC
                // datapath is the Table-6 simulation, not the serving
                // hot path, so its internal allocation is acceptable).
                let (out, _) = stc_gemm(src, cw, m, lcfg);
                stc_out = out;
                &stc_out
            }
        };
        // dequantize + bias
        let mut y = TensorF32::zeros(x.n, oh, ow, qc.o);
        for mi in 0..m {
            for oi in 0..qc.o {
                y.data[mi * qc.o + oi] = acc[mi * qc.o + oi] as f32
                    * (scale.0 * wrs * qc.scale[oi])
                    + qc.bias[oi];
            }
        }
        Ok(y)
    }

    /// Top-1 predictions for a logits buffer.
    pub fn argmax(logits: &[f32], classes: usize) -> Vec<usize> {
        logits
            .chunks_exact(classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::{Graph, Node, Op};
    use crate::model::weights::{FloatConv, QuantConv, Weights};

    /// Tiny hand-built model: img(1x1x2) -> float 1x1 conv (identity)
    /// -> add(c1, c1) -> gap -> fc(identity) => logits = 2 * img.
    fn tiny_float_model(extra_fc_head: bool) -> (Graph, Weights) {
        let mut nodes = vec![
            Node { name: "img".into(), op: Op::Input, inputs: vec![] },
            Node {
                name: "c1".into(),
                op: Op::Conv { k: 1, stride: 1, out_ch: 2, relu: false, quant: false },
                inputs: vec!["img".into()],
            },
            Node { name: "a".into(), op: Op::Add, inputs: vec!["c1".into(), "c1".into()] },
            Node { name: "g".into(), op: Op::Gap, inputs: vec!["a".into()] },
            Node { name: "fc".into(), op: Op::Fc { out: 2 }, inputs: vec!["g".into()] },
        ];
        if extra_fc_head {
            nodes.push(Node {
                name: "fc2".into(),
                op: Op::Fc { out: 2 },
                inputs: vec!["g".into()],
            });
        }
        let graph = Graph {
            arch: "tiny".into(),
            variant: "test".into(),
            num_classes: 2,
            input_hwc: [1, 1, 2],
            eval_batch: 2,
            quant_convs: vec![],
            nodes,
        };
        let mut float = HashMap::new();
        float.insert(
            "c1".to_string(),
            FloatConv {
                // HWIO 1x1x2x2 identity
                w: vec![1.0, 0.0, 0.0, 1.0],
                kh: 1,
                kw: 1,
                c_in: 2,
                c_out: 2,
                bias: vec![0.0, 0.0],
            },
        );
        let weights = Weights {
            quant: HashMap::new(),
            float,
            fc_w: vec![1.0, 0.0, 0.0, 1.0],
            fc_in: 2,
            fc_out: 2,
            fc_b: vec![0.0, 0.0],
        };
        (graph, weights)
    }

    /// Tiny model with one quantized conv, exercising every scratch
    /// buffer and the prepared-weight table.
    fn tiny_quant_model() -> (Graph, Weights) {
        let graph = Graph {
            arch: "tinyq".into(),
            variant: "test".into(),
            num_classes: 2,
            input_hwc: [4, 4, 1],
            eval_batch: 1,
            quant_convs: vec!["q1".into()],
            nodes: vec![
                Node { name: "img".into(), op: Op::Input, inputs: vec![] },
                Node {
                    name: "q1".into(),
                    op: Op::Conv { k: 3, stride: 1, out_ch: 2, relu: true, quant: true },
                    inputs: vec!["img".into()],
                },
                Node { name: "g".into(), op: Op::Gap, inputs: vec!["q1".into()] },
                Node { name: "fc".into(), op: Op::Fc { out: 2 }, inputs: vec!["g".into()] },
            ],
        };
        let mut quant = HashMap::new();
        quant.insert(
            "q1".to_string(),
            QuantConv {
                wq: (0..9 * 2).map(|i| ((i * 29) % 255) as i32 as i8).collect(),
                k: 9,
                o: 2,
                scale: vec![0.01, 0.02],
                bias: vec![0.1, -0.1],
            },
        );
        let weights = Weights {
            quant,
            float: HashMap::new(),
            fc_w: vec![1.0, 0.0, 0.0, 1.0],
            fc_in: 2,
            fc_out: 2,
            fc_b: vec![0.0, 0.0],
        };
        (graph, weights)
    }

    #[test]
    fn forward_through_shared_inputs_and_dead_value_dropping() {
        let (graph, weights) = tiny_float_model(false);
        let engine = Engine::new(&graph, &weights, SparqConfig::A8W8, &[], EngineMode::Dense)
            .unwrap();
        let logits = engine.forward(&[1.5, -2.0, 0.25, 3.0], 2).unwrap();
        // add(c1, c1) doubles; gap of 1x1 is identity; fc identity
        assert_eq!(logits, vec![3.0, -4.0, 0.5, 6.0]);
    }

    #[test]
    fn second_fc_head_is_rejected_not_silently_overwritten() {
        let (graph, weights) = tiny_float_model(true);
        let engine = Engine::new(&graph, &weights, SparqConfig::A8W8, &[], EngineMode::Dense)
            .unwrap();
        let err = engine.forward(&[1.0, 1.0], 1).unwrap_err().to_string();
        assert!(err.contains("second fc head"), "{err}");
    }

    #[test]
    fn post_fc_consumer_is_rejected_not_silently_ignored() {
        let (mut graph, weights) = tiny_float_model(false);
        // fc -> relu: the relu's effect could never reach the returned
        // logits, so the engine must refuse rather than drop it.
        graph.nodes.push(Node {
            name: "r".into(),
            op: Op::Relu,
            inputs: vec!["fc".into()],
        });
        let engine = Engine::new(&graph, &weights, SparqConfig::A8W8, &[], EngineMode::Dense)
            .unwrap();
        let err = engine.forward(&[1.0, 1.0], 1).unwrap_err().to_string();
        assert!(err.contains("must be terminal"), "{err}");
    }

    #[test]
    fn engines_share_one_parameter_copy_and_match_bitwise() {
        // Two replicas from one ModelParams: pointer-equal parameter
        // storage (no deep clone per engine — the pre-Arc bug) and
        // bit-identical logits, also across different thread counts.
        let (graph, weights) = tiny_quant_model();
        let cfg = SparqConfig::named("5opt_r").unwrap();
        let params = Arc::new(
            ModelParams::new(
                Arc::new(graph),
                Arc::new(weights),
                cfg,
                &[0.02],
                EngineMode::Dense,
            )
            .unwrap(),
        );
        let e1 = Engine::from_params(params.clone());
        let mut e2 = Engine::from_params(params.clone());
        // shared storage: both engines point at the *same* allocations
        assert!(Arc::ptr_eq(e1.params(), e2.params()), "engines built distinct param blocks");
        assert!(Arc::ptr_eq(&e1.params().graph, &e2.params().graph));
        assert!(Arc::ptr_eq(&e1.params().weights, &e2.params().weights));
        assert!(std::ptr::eq(e1.graph(), e2.graph()), "graph refs resolve to different copies");
        assert_eq!(Arc::strong_count(&params), 3, "params + 2 replicas");
        // replicas stay numerically identical to each other and to a
        // from-scratch engine, independent of the per-replica knob
        e2.set_threads(1);
        let img: Vec<f32> = (0..16).map(|i| (i as f32) / 8.0).collect();
        let l1 = e1.forward(&img, 1).unwrap();
        let l2 = e2.forward(&img, 1).unwrap();
        assert_eq!(l1, l2, "shared-params replicas diverged");
        let (graph2, weights2) = tiny_quant_model();
        let fresh = Engine::new(&graph2, &weights2, cfg, &[0.02], EngineMode::Dense).unwrap();
        assert_eq!(l1, fresh.forward(&img, 1).unwrap());
        // dropping a replica releases its handle, not the parameters
        drop(e1);
        assert_eq!(Arc::strong_count(&params), 2);
    }

    /// Tiny model with TWO quantized convs for per-layer policy tests.
    fn tiny_two_quant_model() -> (Graph, Weights) {
        let graph = Graph {
            arch: "tinyq2".into(),
            variant: "test".into(),
            num_classes: 2,
            input_hwc: [4, 4, 1],
            eval_batch: 1,
            quant_convs: vec!["q1".into(), "q2".into()],
            nodes: vec![
                Node { name: "img".into(), op: Op::Input, inputs: vec![] },
                Node {
                    name: "q1".into(),
                    op: Op::Conv { k: 3, stride: 1, out_ch: 2, relu: true, quant: true },
                    inputs: vec!["img".into()],
                },
                Node {
                    name: "q2".into(),
                    op: Op::Conv { k: 3, stride: 1, out_ch: 2, relu: true, quant: true },
                    inputs: vec!["q1".into()],
                },
                Node { name: "g".into(), op: Op::Gap, inputs: vec!["q2".into()] },
                Node { name: "fc".into(), op: Op::Fc { out: 2 }, inputs: vec!["g".into()] },
            ],
        };
        let mut quant = HashMap::new();
        quant.insert(
            "q1".to_string(),
            QuantConv {
                wq: (0..9 * 2).map(|i| (((i * 29) % 255) as i32 - 127) as i8).collect(),
                k: 9,
                o: 2,
                scale: vec![0.01, 0.02],
                bias: vec![0.1, -0.1],
            },
        );
        quant.insert(
            "q2".to_string(),
            QuantConv {
                wq: (0..2 * 9 * 2).map(|i| (((i * 53) % 255) as i32 - 127) as i8).collect(),
                k: 2 * 9,
                o: 2,
                scale: vec![0.015, 0.025],
                bias: vec![0.05, -0.05],
            },
        );
        let weights = Weights {
            quant,
            float: HashMap::new(),
            fc_w: vec![1.0, 0.0, 0.0, 1.0],
            fc_in: 2,
            fc_out: 2,
            fc_b: vec![0.0, 0.0],
        };
        (graph, weights)
    }

    #[test]
    fn uniform_policy_is_bit_identical_to_uniform_config() {
        use crate::quant::{LayerSelector, QuantPolicy};
        let (graph, weights) = tiny_two_quant_model();
        let scales = [0.02f32, 0.03];
        let img: Vec<f32> = (0..16).map(|i| (i as f32) / 8.0).collect();
        for name in ["a8w8", "a4w8", "5opt_r", "2opt", "a8w4"] {
            let cfg = SparqConfig::named(name).unwrap();
            let want = Engine::new(&graph, &weights, cfg, &scales, EngineMode::Dense)
                .unwrap()
                .forward(&img, 1)
                .unwrap();
            // uniform(cfg) and an all-layers-explicit policy with the
            // same config must both be bit-identical to the plain path.
            let uni = Engine::with_policy(
                &graph,
                &weights,
                QuantPolicy::uniform(cfg),
                &scales,
                EngineMode::Dense,
            )
            .unwrap();
            assert_eq!(uni.forward(&img, 1).unwrap(), want, "{name} uniform policy");
            assert_eq!(uni.params().distinct_configs(), 1, "{name}: uniform needs 1 LUT");
            let explicit = QuantPolicy::builder(SparqConfig::A8W8)
                .set(LayerSelector::Name("q1".into()), cfg)
                .set(LayerSelector::Name("q2".into()), cfg)
                .build()
                .unwrap();
            let exp = Engine::with_policy(&graph, &weights, explicit, &scales, EngineMode::Dense)
                .unwrap();
            assert_eq!(exp.forward(&img, 1).unwrap(), want, "{name} explicit policy");
        }
    }

    #[test]
    fn per_layer_policy_prepares_one_lut_per_distinct_config() {
        use crate::quant::QuantPolicy;
        let (graph, weights) = tiny_two_quant_model();
        let scales = [0.02f32, 0.03];
        let img: Vec<f32> = (0..16).map(|i| ((i * 7) % 23) as f32 / 10.0).collect();
        // first8: q1 at A8W8, q2 at A4W8+R -> 2 distinct contexts
        let policy = QuantPolicy::named("first8").unwrap();
        let mixed =
            Engine::with_policy(&graph, &weights, policy, &scales, EngineMode::Dense).unwrap();
        assert_eq!(mixed.params().distinct_configs(), 2);
        let plan = mixed.params().layer_cfgs();
        assert_eq!(plan[0], ("q1".to_string(), SparqConfig::A8W8));
        assert_eq!(plan[1], ("q2".to_string(), SparqConfig::named("a4w8").unwrap()));
        // the mixed engine differs from BOTH uniform endpoints…
        let a8 = Engine::new(&graph, &weights, SparqConfig::A8W8, &scales, EngineMode::Dense)
            .unwrap()
            .forward(&img, 1)
            .unwrap();
        let a4 = Engine::new(
            &graph,
            &weights,
            SparqConfig::named("a4w8").unwrap(),
            &scales,
            EngineMode::Dense,
        )
        .unwrap()
        .forward(&img, 1)
        .unwrap();
        let got = mixed.forward(&img, 1).unwrap();
        assert_ne!(got, a8, "first8 must not equal uniform A8W8");
        assert_ne!(got, a4, "first8 must not equal uniform A4W8");
        // …and the policy footprint sits strictly between the endpoints.
        let bits = mixed.params().footprint_bits(1);
        assert!(bits > 4.0 && bits < 8.0, "first8 footprint {bits}");
        // edge8 on a 2-layer model pins both layers -> uniform A8W8.
        let edge = Engine::with_policy(
            &graph,
            &weights,
            QuantPolicy::named("edge8").unwrap(),
            &scales,
            EngineMode::Dense,
        )
        .unwrap();
        assert_eq!(edge.params().distinct_configs(), 1);
        assert_eq!(edge.forward(&img, 1).unwrap(), a8);
    }

    #[test]
    fn scratch_reuse_is_deterministic_and_allocation_stable() {
        let (graph, weights) = tiny_quant_model();
        let engine =
            Engine::new(&graph, &weights, SparqConfig::named("5opt_r").unwrap(), &[0.02],
                EngineMode::Dense)
            .unwrap();
        let img: Vec<f32> = (0..16).map(|i| (i as f32) / 8.0).collect();
        let fresh = engine.forward(&img, 1).unwrap();
        let mut scratch = Scratch::default();
        let first = engine.forward_scratch(&img, 1, &mut scratch).unwrap();
        let caps = (
            scratch.xq.capacity(),
            scratch.patches.capacity(),
            scratch.pack.capacity(),
            scratch.acc.capacity(),
        );
        let second = engine.forward_scratch(&img, 1, &mut scratch).unwrap();
        assert_eq!(first, fresh, "scratch path diverges from fresh-buffer path");
        assert_eq!(second, fresh, "dirty scratch changes results");
        assert_eq!(
            caps,
            (
                scratch.xq.capacity(),
                scratch.patches.capacity(),
                scratch.pack.capacity(),
                scratch.acc.capacity(),
            ),
            "steady-state forward reallocated scratch"
        );
    }

    #[test]
    fn parallel_float_conv_matches_serial_above_cutoff() {
        // Large enough that float_conv's work-scaled worker count is
        // >= 2 (8 * 16*16 * 16 * 9 * 8 MACs is several multiples of
        // MIN_PARALLEL_MACS), so a regression in the row_idx -> (n, oy)
        // partition math shows up as a serial/parallel mismatch.
        let (n, h, w, c, co) = (8usize, 16usize, 16usize, 8usize, 16usize);
        let graph = Graph {
            arch: "par".into(),
            variant: "test".into(),
            num_classes: co,
            input_hwc: [h, w, c],
            eval_batch: n,
            quant_convs: vec![],
            nodes: vec![
                Node { name: "img".into(), op: Op::Input, inputs: vec![] },
                Node {
                    name: "c1".into(),
                    op: Op::Conv { k: 3, stride: 1, out_ch: co, relu: true, quant: false },
                    inputs: vec!["img".into()],
                },
                Node { name: "g".into(), op: Op::Gap, inputs: vec!["c1".into()] },
                Node { name: "fc".into(), op: Op::Fc { out: co }, inputs: vec!["g".into()] },
            ],
        };
        let mut float = HashMap::new();
        float.insert(
            "c1".to_string(),
            FloatConv {
                w: (0..9 * c * co).map(|i| ((i * 13) % 17) as f32 / 10.0 - 0.8).collect(),
                kh: 3,
                kw: 3,
                c_in: c,
                c_out: co,
                bias: (0..co).map(|i| i as f32 * 0.01).collect(),
            },
        );
        let mut fc_w = vec![0f32; co * co];
        for i in 0..co {
            fc_w[i * co + i] = 1.0;
        }
        let weights = Weights {
            quant: HashMap::new(),
            float,
            fc_w,
            fc_in: co,
            fc_out: co,
            fc_b: vec![0.0; co],
        };
        assert!(
            n * h * w * co * 9 * c >= 2 * crate::model::gemm::MIN_PARALLEL_MACS,
            "test model too small for >= 2 workers; grow it"
        );
        let img: Vec<f32> = (0..n * h * w * c).map(|i| ((i * 7) % 23) as f32 / 23.0).collect();
        let mut engine =
            Engine::new(&graph, &weights, SparqConfig::A8W8, &[], EngineMode::Dense).unwrap();
        engine.set_threads(1);
        let serial = engine.forward(&img, n).unwrap();
        engine.set_threads(8);
        let parallel = engine.forward(&img, n).unwrap();
        // per-element accumulation order is identical -> exact equality
        assert_eq!(serial, parallel);
        assert!(serial.iter().any(|&v| v != 0.0), "degenerate all-zero logits");
    }
}
