//! Graph executor — float ops + SPARQ integer convs (DESIGN.md S15).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::hw::stc::{stc_gemm, CompressedWeights};
use crate::quant::minmax::ActScale;
use crate::quant::SparqConfig;
use crate::tensor::{im2col_u8, out_dim, same_padding, TensorF32};

use super::gemm::QuantGemm;
use super::graph::{Graph, Node, Op};
use super::weights::Weights;

/// How quantized convs execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// Dense SPARQ GEMM (the Table 1–4 path; bit-exact vs the HLO).
    Dense,
    /// 2:4 Sparse-Tensor-Core datapath (the Table 6 path). Requires the
    /// model's quantized weights to be 2:4 structured.
    Stc,
}

/// Observer for quantized activations (drives the toggle statistics).
pub trait TraceSink {
    /// Called once per quantized conv per forward with the uniform-
    /// quantized (untrimmed) im2col activations.
    fn record(&mut self, layer: &str, acts_q: &[u8]);
}

/// No-op sink.
pub struct NoTrace;

impl TraceSink for NoTrace {
    fn record(&mut self, _layer: &str, _acts_q: &[u8]) {}
}

/// A ready-to-run model: graph + weights + config + scales.
pub struct Engine<'a> {
    pub graph: &'a Graph,
    weights: &'a Weights,
    pub cfg: SparqConfig,
    mode: EngineMode,
    scales: HashMap<String, ActScale>,
    gemm: QuantGemm,
    /// Per-layer prepared (requantized + transposed) weights.
    prepared: HashMap<String, Vec<i16>>,
    /// Per-layer 2:4 compressed weights (STC mode).
    compressed: HashMap<String, CompressedWeights>,
}

impl<'a> Engine<'a> {
    /// `act_scales` ordered by `graph.quant_convs` (from calibration).
    pub fn new(
        graph: &'a Graph,
        weights: &'a Weights,
        cfg: SparqConfig,
        act_scales: &[f32],
        mode: EngineMode,
    ) -> Result<Self> {
        if act_scales.len() != graph.quant_convs.len() {
            bail!(
                "need {} activation scales, got {}",
                graph.quant_convs.len(),
                act_scales.len()
            );
        }
        let gemm = QuantGemm::new(cfg);
        let mut scales = HashMap::new();
        let mut prepared = HashMap::new();
        let mut compressed = HashMap::new();
        for (name, &s) in graph.quant_convs.iter().zip(act_scales) {
            scales.insert(name.clone(), ActScale(s));
            let qc = weights.quant_conv(name)?;
            match mode {
                EngineMode::Dense => {
                    prepared.insert(name.clone(), gemm.prepare_weights(&qc.wq, qc.k, qc.o));
                }
                EngineMode::Stc => {
                    // STC stores pre-requantized weights? No: requantize
                    // survivors at execute time (stc_gemm handles w_bits).
                    let padded;
                    let (wq, k) = if qc.k % 4 == 0 {
                        (&qc.wq, qc.k)
                    } else {
                        // pad K to a multiple of 4 with zero rows (the
                        // trailing partial group the pruner left dense
                        // cannot arise for our zoo; guard anyway)
                        let k4 = qc.k.div_ceil(4) * 4;
                        let mut w = vec![0i8; k4 * qc.o];
                        w[..qc.k * qc.o].copy_from_slice(&qc.wq);
                        padded = w;
                        (&padded, k4)
                    };
                    let c = CompressedWeights::compress(wq, k, qc.o).map_err(|e| {
                        anyhow::anyhow!("{name}: weights not 2:4 structured ({e})")
                    })?;
                    compressed.insert(name.clone(), c);
                }
            }
        }
        Ok(Self { graph, weights, cfg, mode, scales, gemm, prepared, compressed })
    }

    /// Forward a normalized image batch `[batch, H, W, C]` -> logits
    /// `[batch, classes]` row-major.
    pub fn forward(&self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        self.forward_traced(images, batch, &mut NoTrace)
    }

    pub fn forward_traced(
        &self,
        images: &[f32],
        batch: usize,
        sink: &mut dyn TraceSink,
    ) -> Result<Vec<f32>> {
        let [h, w, c] = self.graph.input_hwc;
        if images.len() != batch * h * w * c {
            bail!("input length {} != {}", images.len(), batch * h * w * c);
        }
        let mut vals: HashMap<&str, TensorF32> = HashMap::new();
        vals.insert("img", TensorF32::from_vec(batch, h, w, c, images.to_vec()));
        let mut logits = Vec::new();
        for node in &self.graph.nodes {
            let get = |name: &String| -> Result<&TensorF32> {
                vals.get(name.as_str()).with_context(|| format!("missing value {name}"))
            };
            let out = match &node.op {
                Op::Input => continue,
                Op::Conv { quant: false, k, stride, relu, .. } => {
                    let x = get(&node.inputs[0])?;
                    let mut y = self.float_conv(node, x, *k, *stride)?;
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Conv { quant: true, k, stride, relu, .. } => {
                    let x = get(&node.inputs[0])?;
                    let mut y = self.quant_conv(node, x, *k, *stride, sink)?;
                    if *relu {
                        y.relu_inplace();
                    }
                    y
                }
                Op::Pool { avg } => {
                    let x = get(&node.inputs[0])?;
                    if *avg {
                        x.avgpool2()
                    } else {
                        x.maxpool2()
                    }
                }
                Op::Gap => {
                    let x = get(&node.inputs[0])?;
                    let g = x.gap();
                    TensorF32::from_vec(x.n, 1, 1, x.c, g)
                }
                Op::Add => get(&node.inputs[0])?.add(get(&node.inputs[1])?),
                Op::Relu => {
                    let mut y = get(&node.inputs[0])?.clone();
                    y.relu_inplace();
                    y
                }
                Op::Concat => {
                    let parts: Vec<&TensorF32> =
                        node.inputs.iter().map(|i| get(i)).collect::<Result<_>>()?;
                    TensorF32::concat_channels(&parts)
                }
                Op::Fc { out } => {
                    let x = get(&node.inputs[0])?;
                    assert_eq!(x.c, self.weights.fc_in, "fc input width");
                    logits = vec![0f32; x.n * out];
                    for n in 0..x.n {
                        for oi in 0..*out {
                            let mut acc = self.weights.fc_b[oi];
                            for ci in 0..x.c {
                                acc += x.data[n * x.c + ci] * self.weights.fc_w[ci * out + oi];
                            }
                            logits[n * out + oi] = acc;
                        }
                    }
                    continue;
                }
            };
            vals.insert(node.name.as_str(), out);
        }
        if logits.is_empty() {
            bail!("graph produced no logits");
        }
        Ok(logits)
    }

    /// Direct float convolution (unquantized first layer), SAME padding.
    fn float_conv(&self, node: &Node, x: &TensorF32, k: usize, stride: usize) -> Result<TensorF32> {
        let fw = self.weights.float_conv(&node.name)?;
        assert_eq!((fw.kh, fw.kw, fw.c_in), (k, k, x.c), "conv {} shape", node.name);
        let (oh, ow) = (out_dim(x.h, stride), out_dim(x.w, stride));
        let (pad_t, _) = same_padding(x.h, k, stride);
        let (pad_l, _) = same_padding(x.w, k, stride);
        let mut y = TensorF32::zeros(x.n, oh, ow, fw.c_out);
        for n in 0..x.n {
            for oy in 0..oh {
                for ox in 0..ow {
                    for co in 0..fw.c_out {
                        let mut acc = fw.bias[co];
                        for ky in 0..k {
                            let iy = (oy * stride + ky) as isize - pad_t as isize;
                            if iy < 0 || iy >= x.h as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = (ox * stride + kx) as isize - pad_l as isize;
                                if ix < 0 || ix >= x.w as isize {
                                    continue;
                                }
                                for ci in 0..x.c {
                                    acc += x.at(n, iy as usize, ix as usize, ci)
                                        * fw.w[((ky * k + kx) * fw.c_in + ci) * fw.c_out + co];
                                }
                            }
                        }
                        *y.at_mut(n, oy, ox, co) = acc;
                    }
                }
            }
        }
        Ok(y)
    }

    /// SPARQ quantized conv: quantize input, im2col, trim+GEMM, dequant.
    fn quant_conv(
        &self,
        node: &Node,
        x: &TensorF32,
        k: usize,
        stride: usize,
        sink: &mut dyn TraceSink,
    ) -> Result<TensorF32> {
        let qc = self.weights.quant_conv(&node.name)?;
        let scale = self.scales[&node.name];
        // quantize the (non-negative) float input to u8
        let mut xq = vec![0u8; x.data.len()];
        scale.quantize_slice_into(&x.data, &mut xq);
        // im2col in the shared (C, kh, kw) feature order
        let (mut patches, oh, ow) = im2col_u8(&xq, x.n, x.h, x.w, x.c, k, stride);
        let m = x.n * oh * ow;
        let kk = x.c * k * k;
        sink.record(&node.name, &patches);

        let wrs = self.cfg.weight_rescale();
        let mut acc = vec![0i32; m * qc.o];
        match self.mode {
            EngineMode::Dense => {
                let wt = &self.prepared[&node.name];
                self.gemm.gemm(&mut patches, m, kk, wt, qc.o, &mut acc);
            }
            EngineMode::Stc => {
                let cw = &self.compressed[&node.name];
                // pad patches K to the compressed K if needed
                if cw.k != kk {
                    let mut padded = vec![0u8; m * cw.k];
                    for mi in 0..m {
                        padded[mi * cw.k..mi * cw.k + kk]
                            .copy_from_slice(&patches[mi * kk..(mi + 1) * kk]);
                    }
                    patches = padded;
                }
                let (out, _) = stc_gemm(&patches, cw, m, self.cfg);
                acc = out;
            }
        }
        // dequantize + bias
        let mut y = TensorF32::zeros(x.n, oh, ow, qc.o);
        for mi in 0..m {
            for oi in 0..qc.o {
                y.data[mi * qc.o + oi] = acc[mi * qc.o + oi] as f32
                    * (scale.0 * wrs * qc.scale[oi])
                    + qc.bias[oi];
            }
        }
        Ok(y)
    }

    /// Top-1 predictions for a logits buffer.
    pub fn argmax(logits: &[f32], classes: usize) -> Vec<usize> {
        logits
            .chunks_exact(classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}
