//! `.npy` v1.0 parser for the dtypes our exporters emit:
//! `<f4` (f32), `|i1` (i8), `<i4` (i32), `|u1` (u8).

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
    U8,
}

impl DType {
    fn from_descr(descr: &str) -> Result<Self> {
        Ok(match descr {
            "<f4" => Self::F32,
            "|i1" | "<i1" => Self::I8,
            "<i4" => Self::I32,
            "|u1" | "<u1" => Self::U8,
            other => bail!("unsupported npy dtype {other:?}"),
        })
    }

    fn size(self) -> usize {
        match self {
            Self::F32 | Self::I32 => 4,
            Self::I8 | Self::U8 => 1,
        }
    }
}

/// A typed, C-contiguous array.
#[derive(Debug)]
pub struct NpyArray {
    pub dtype: DType,
    pub shape: Vec<usize>,
    data: Data,
}

#[derive(Debug)]
enum Data {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl NpyArray {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("array is {:?}, expected f32", self.dtype),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            Data::I8(v) => Ok(v),
            _ => bail!("array is {:?}, expected i8", self.dtype),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("array is {:?}, expected i32", self.dtype),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            Data::U8(v) => Ok(v),
            _ => bail!("array is {:?}, expected u8", self.dtype),
        }
    }
}

pub(crate) fn parse(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("missing npy magic");
    }
    let (major, _minor) = (bytes[6], bytes[7]);
    if major != 1 {
        bail!("unsupported npy version {major}");
    }
    let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
    let header = std::str::from_utf8(
        bytes
            .get(10..10 + header_len)
            .ok_or_else(|| anyhow::anyhow!("npy header truncated"))?,
    )
    .context("npy header not utf-8")?;

    let descr = dict_str(header, "descr")?;
    let dtype = DType::from_descr(&descr)?;
    let fortran = dict_raw(header, "fortran_order")?;
    if fortran.trim() != "False" {
        bail!("fortran_order arrays are not supported");
    }
    let shape = parse_shape(&dict_raw(header, "shape")?)?;

    let count: usize = shape.iter().product();
    let payload = &bytes[10 + header_len..];
    if payload.len() < count * dtype.size() {
        bail!(
            "npy payload too short: {} < {}",
            payload.len(),
            count * dtype.size()
        );
    }
    let payload = &payload[..count * dtype.size()];
    let data = match dtype {
        DType::F32 => Data::F32(
            payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
        DType::I32 => Data::I32(
            payload
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
        DType::I8 => Data::I8(payload.iter().map(|&b| b as i8).collect()),
        DType::U8 => Data::U8(payload.to_vec()),
    };
    Ok(NpyArray { dtype, shape, data })
}

/// Extract `'key': 'value'` (string values) from the header dict literal.
fn dict_str(header: &str, key: &str) -> Result<String> {
    let raw = dict_raw(header, key)?;
    let t = raw.trim();
    if (t.starts_with('\'') && t.ends_with('\'')) || (t.starts_with('"') && t.ends_with('"')) {
        Ok(t[1..t.len() - 1].to_string())
    } else {
        bail!("npy header key {key}: expected string, got {t:?}")
    }
}

/// Extract the raw value text for `key` in the header dict literal.
fn dict_raw(header: &str, key: &str) -> Result<String> {
    let pat = format!("'{key}':");
    let at = header
        .find(&pat)
        .ok_or_else(|| anyhow::anyhow!("npy header missing key {key}"))?;
    let rest = &header[at + pat.len()..];
    // value ends at the next top-level ',' or '}'
    let mut depth = 0usize;
    let mut end = rest.len();
    for (i, c) in rest.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => depth = depth.saturating_sub(1),
            ',' | '}' if depth == 0 => {
                end = i;
                break;
            }
            _ => {}
        }
    }
    Ok(rest[..end].trim().to_string())
}

fn parse_shape(raw: &str) -> Result<Vec<usize>> {
    let t = raw.trim().trim_start_matches('(').trim_end_matches(')');
    let mut shape = Vec::new();
    for part in t.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        shape.push(p.parse::<usize>().context("bad shape entry")?);
    }
    Ok(shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(descr: &str, shape: &str, data: &[u8]) -> Vec<u8> {
        let header =
            format!("{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape}, }}\n");
        let mut out = b"\x93NUMPY\x01\x00".to_vec();
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(data);
        out
    }

    #[test]
    fn parse_i32() {
        let data: Vec<u8> = [1i32, -2, 3].iter().flat_map(|v| v.to_le_bytes()).collect();
        let a = parse(&mk("<i4", "(3,)", &data)).unwrap();
        assert_eq!(a.shape, vec![3]);
        assert_eq!(a.as_i32().unwrap(), &[1, -2, 3]);
        assert!(a.as_f32().is_err());
    }

    #[test]
    fn parse_u8_2d() {
        let a = parse(&mk("|u1", "(2, 2)", &[1, 2, 3, 4])).unwrap();
        assert_eq!(a.shape, vec![2, 2]);
        assert_eq!(a.as_u8().unwrap(), &[1, 2, 3, 4]);
    }

    #[test]
    fn rejects_fortran_and_bad_dtype() {
        let hdr = "{'descr': '<f4', 'fortran_order': True, 'shape': (1,), }\n";
        let mut b = b"\x93NUMPY\x01\x00".to_vec();
        b.extend_from_slice(&(hdr.len() as u16).to_le_bytes());
        b.extend_from_slice(hdr.as_bytes());
        b.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(parse(&b).is_err());
        assert!(parse(&mk("<f8", "(1,)", &[0; 8])).is_err());
    }

    #[test]
    fn truncated_payload_fails() {
        assert!(parse(&mk("<f4", "(4,)", &[0; 8])).is_err());
    }
}
