//! STORED-only ZIP reader for `np.savez` archives.
//!
//! Walks the central directory (found via the end-of-central-directory
//! record) and returns `(name, bytes)` pairs. Any compressed entry is a
//! hard error — `np.savez` never compresses, and refusing beats silently
//! corrupting weights. CRC32 is verified for every entry.

use anyhow::{bail, Result};

pub(crate) fn read_stored_entries(bytes: &[u8]) -> Result<Vec<(String, Vec<u8>)>> {
    let eocd = find_eocd(bytes)?;
    let n_entries = u16_at(bytes, eocd + 10)? as usize;
    let cd_offset = u32_at(bytes, eocd + 16)? as usize;

    let mut out = Vec::with_capacity(n_entries);
    let mut pos = cd_offset;
    for _ in 0..n_entries {
        if u32_at(bytes, pos)? != 0x0201_4b50 {
            bail!("bad central-directory signature at {pos}");
        }
        let method = u16_at(bytes, pos + 10)?;
        let crc = u32_at(bytes, pos + 16)?;
        let comp_size = u32_at(bytes, pos + 20)? as usize;
        let uncomp_size = u32_at(bytes, pos + 24)? as usize;
        let name_len = u16_at(bytes, pos + 28)? as usize;
        let extra_len = u16_at(bytes, pos + 30)? as usize;
        let comment_len = u16_at(bytes, pos + 32)? as usize;
        let local_offset = u32_at(bytes, pos + 42)? as usize;
        let name = std::str::from_utf8(slice(bytes, pos + 46, name_len)?)?.to_string();
        if method != 0 {
            bail!("entry `{name}` uses compression method {method}; only STORED is supported (np.savez)");
        }
        if comp_size != uncomp_size {
            bail!("entry `{name}`: stored entry with mismatched sizes");
        }
        // local header: re-read lengths (may differ from central copies)
        if u32_at(bytes, local_offset)? != 0x0403_4b50 {
            bail!("bad local header for `{name}`");
        }
        let l_name = u16_at(bytes, local_offset + 26)? as usize;
        let l_extra = u16_at(bytes, local_offset + 28)? as usize;
        let data_start = local_offset + 30 + l_name + l_extra;
        let data = slice(bytes, data_start, uncomp_size)?.to_vec();
        let actual_crc = crc32(&data);
        if actual_crc != crc {
            bail!("entry `{name}`: crc mismatch ({actual_crc:#x} != {crc:#x})");
        }
        out.push((name, data));
        pos += 46 + name_len + extra_len + comment_len;
    }
    Ok(out)
}

fn find_eocd(bytes: &[u8]) -> Result<usize> {
    // EOCD is at least 22 bytes, signature 0x06054b50; search backwards
    // through the (possibly empty) trailing comment.
    if bytes.len() < 22 {
        bail!("file too short to be a zip");
    }
    let start = bytes.len().saturating_sub(22 + u16::MAX as usize);
    for pos in (start..=bytes.len() - 22).rev() {
        if bytes[pos..pos + 4] == [0x50, 0x4b, 0x05, 0x06] {
            return Ok(pos);
        }
    }
    bail!("zip end-of-central-directory not found")
}

fn slice(bytes: &[u8], at: usize, len: usize) -> Result<&[u8]> {
    bytes
        .get(at..at + len)
        .ok_or_else(|| anyhow::anyhow!("zip truncated at {at}+{len}"))
}

fn u16_at(bytes: &[u8], at: usize) -> Result<u16> {
    Ok(u16::from_le_bytes(slice(bytes, at, 2)?.try_into().unwrap()))
}

fn u32_at(bytes: &[u8], at: usize) -> Result<u32> {
    Ok(u32::from_le_bytes(slice(bytes, at, 4)?.try_into().unwrap()))
}

/// CRC-32 (IEEE 802.3), table-driven.
pub(crate) fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in data {
        c = table[((c ^ u32::from(b)) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414f_a339);
    }

    #[test]
    fn rejects_non_zip() {
        assert!(read_stored_entries(b"not a zip at all, definitely!").is_err());
        assert!(read_stored_entries(b"").is_err());
    }
}
