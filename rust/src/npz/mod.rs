//! Minimal `.npy` / `.npz` reader (DESIGN.md S12).
//!
//! The build-time python exporters write weight archives with
//! `np.savez` (uncompressed, i.e. ZIP with STORED entries, each entry a
//! v1.0 `.npy`). This module implements exactly that subset — enough to
//! read every artifact this repo produces, with strict errors on
//! anything else (compressed entries, fortran order, exotic dtypes), so
//! silent misreads are impossible.

mod npy;
mod zip;

pub use npy::{DType, NpyArray};

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

/// An in-memory npz archive: name -> typed array.
#[derive(Debug)]
pub struct Npz {
    arrays: HashMap<String, NpyArray>,
}

impl Npz {
    pub fn read(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading npz {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let entries = zip::read_stored_entries(bytes)?;
        let mut arrays = HashMap::new();
        for (name, data) in entries {
            let key = name.strip_suffix(".npy").unwrap_or(&name).to_string();
            arrays.insert(key, npy::parse(&data)?);
        }
        Ok(Self { arrays })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.arrays.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    pub fn get(&self, name: &str) -> Result<&NpyArray> {
        self.arrays
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("array `{name}` missing (have {:?})", self.names()))
    }

    pub fn f32(&self, name: &str) -> Result<(&[usize], &[f32])> {
        let a = self.get(name)?;
        Ok((a.shape.as_slice(), a.as_f32()?))
    }

    pub fn i8(&self, name: &str) -> Result<(&[usize], &[i8])> {
        let a = self.get(name)?;
        Ok((a.shape.as_slice(), a.as_i8()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a minimal in-memory npz (one stored .npy) and read it back.
    fn fake_npz(entries: &[(&str, Vec<u8>)]) -> Vec<u8> {
        let mut out = Vec::new();
        let mut centrals = Vec::new();
        for (name, payload) in entries {
            let name_b = format!("{name}.npy");
            let offset = out.len() as u32;
            let crc = crate::npz::zip::crc32(payload);
            // local file header
            out.extend_from_slice(&0x04034b50u32.to_le_bytes());
            out.extend_from_slice(&20u16.to_le_bytes()); // version
            out.extend_from_slice(&0u16.to_le_bytes()); // flags
            out.extend_from_slice(&0u16.to_le_bytes()); // method = stored
            out.extend_from_slice(&[0; 4]); // time/date
            out.extend_from_slice(&crc.to_le_bytes());
            out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            out.extend_from_slice(&(name_b.len() as u16).to_le_bytes());
            out.extend_from_slice(&0u16.to_le_bytes()); // extra len
            out.extend_from_slice(name_b.as_bytes());
            out.extend_from_slice(payload);
            centrals.push((name_b, offset, payload.len() as u32, crc));
        }
        let cd_start = out.len() as u32;
        for (name_b, offset, size, crc) in &centrals {
            out.extend_from_slice(&0x02014b50u32.to_le_bytes());
            out.extend_from_slice(&[20, 0, 20, 0]); // versions
            out.extend_from_slice(&0u16.to_le_bytes()); // flags
            out.extend_from_slice(&0u16.to_le_bytes()); // method
            out.extend_from_slice(&[0; 4]); // time/date
            out.extend_from_slice(&crc.to_le_bytes());
            out.extend_from_slice(&size.to_le_bytes());
            out.extend_from_slice(&size.to_le_bytes());
            out.extend_from_slice(&(name_b.len() as u16).to_le_bytes());
            out.extend_from_slice(&[0; 12]); // extra/comment/disk/attrs(short)
            out.extend_from_slice(&offset.to_le_bytes());
            out.extend_from_slice(name_b.as_bytes());
        }
        let cd_len = out.len() as u32 - cd_start;
        out.extend_from_slice(&0x06054b50u32.to_le_bytes());
        out.extend_from_slice(&[0; 4]); // disk numbers
        out.extend_from_slice(&(centrals.len() as u16).to_le_bytes());
        out.extend_from_slice(&(centrals.len() as u16).to_le_bytes());
        out.extend_from_slice(&cd_len.to_le_bytes());
        out.extend_from_slice(&cd_start.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // comment
        out
    }

    fn npy_payload(descr: &str, shape: &str, data: &[u8]) -> Vec<u8> {
        let header = format!(
            "{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape}, }}"
        );
        let mut h = header.into_bytes();
        let total = 10 + h.len();
        let pad = (64 - (total + 1) % 64) % 64;
        h.extend(std::iter::repeat(b' ').take(pad));
        h.push(b'\n');
        let mut out = b"\x93NUMPY\x01\x00".to_vec();
        out.extend_from_slice(&(h.len() as u16).to_le_bytes());
        out.extend_from_slice(&h);
        out.extend_from_slice(data);
        out
    }

    #[test]
    fn roundtrip_f32_and_i8() {
        let f: Vec<f32> = vec![1.5, -2.0, 3.25, 0.0, 9.0, -1.0];
        let fb: Vec<u8> = f.iter().flat_map(|v| v.to_le_bytes()).collect();
        let i: Vec<i8> = vec![-128, -1, 0, 1, 127];
        let ib: Vec<u8> = i.iter().map(|&v| v as u8).collect();
        let npz_bytes = fake_npz(&[
            ("w", npy_payload("<f4", "(2, 3)", &fb)),
            ("q", npy_payload("|i1", "(5,)", &ib)),
        ]);
        let npz = Npz::from_bytes(&npz_bytes).unwrap();
        let (shape, data) = npz.f32("w").unwrap();
        assert_eq!(shape, &[2, 3]);
        assert_eq!(data, f.as_slice());
        let (shape, data) = npz.i8("q").unwrap();
        assert_eq!(shape, &[5]);
        assert_eq!(data, i.as_slice());
        assert!(npz.get("missing").is_err());
    }

    #[test]
    fn scalar_shape() {
        let fb = 7.5f32.to_le_bytes().to_vec();
        let npz_bytes = fake_npz(&[("s", npy_payload("<f4", "()", &fb))]);
        let npz = Npz::from_bytes(&npz_bytes).unwrap();
        let (shape, data) = npz.f32("s").unwrap();
        assert!(shape.is_empty());
        assert_eq!(data, &[7.5]);
    }
}
