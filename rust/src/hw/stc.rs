//! Sparse Tensor Core (paper Fig. 5, §5.3) with SPARQ on top.
//!
//! Ampere STCs accelerate 2:4 structured weight sparsity: every group of
//! four weights along the reduction axis stores only its two non-zero
//! survivors plus 2-bit coordinates. At execute time the coordinates
//! mux-select the two matching activations, and — the paper's
//! composition — *those two selected activations* form the vSPARQ pair.
//!
//! This module implements the weight compression (offline, per output
//! channel), the coordinate-select datapath, and a bit-exact GEMM that
//! the Table 6 evaluation runs on (mirrors `ref.stc_pairdot_ref`).

use crate::quant::bsparq::requant_weight;
use crate::quant::vsparq::trim_pair;
use crate::quant::SparqConfig;

/// One compressed 2:4 group for one output column: two surviving weights
/// and their 2-bit in-group coordinates (in ascending K order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Group24 {
    pub w: [i8; 2],
    pub coord: [u8; 2],
}

/// 2:4-compressed weight matrix (K x N dense -> K/4 groups x N).
#[derive(Clone, Debug)]
pub struct CompressedWeights {
    pub groups: Vec<Group24>, // row-major: (k/4, n)
    pub k: usize,
    pub n: usize,
}

/// Error for weights that are not 2:4 structured.
#[derive(Debug)]
pub struct NotStructured {
    pub group: usize,
    pub col: usize,
    pub nonzeros: usize,
}

impl std::fmt::Display for NotStructured {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "group {} col {} has {} non-zeros (2:4 allows at most 2)",
            self.group, self.col, self.nonzeros
        )
    }
}

impl std::error::Error for NotStructured {}

impl CompressedWeights {
    /// Compress a dense (K x N, row-major) i8 matrix. K % 4 must be 0 and
    /// every (group, column) must have <= 2 non-zeros.
    pub fn compress(w: &[i8], k: usize, n: usize) -> Result<Self, NotStructured> {
        assert_eq!(w.len(), k * n);
        assert_eq!(k % 4, 0, "STC requires K % 4 == 0");
        let g = k / 4;
        let mut groups = Vec::with_capacity(g * n);
        for gi in 0..g {
            for col in 0..n {
                let vals = [
                    w[(4 * gi) * n + col],
                    w[(4 * gi + 1) * n + col],
                    w[(4 * gi + 2) * n + col],
                    w[(4 * gi + 3) * n + col],
                ];
                let nz = vals.iter().filter(|&&v| v != 0).count();
                if nz > 2 {
                    return Err(NotStructured { group: gi, col, nonzeros: nz });
                }
                // survivors: the non-zeros, padded with leading zero slots
                let mut sel: Vec<u8> = (0..4u8).filter(|&i| vals[i as usize] != 0).collect();
                let mut fill = 0u8;
                while sel.len() < 2 {
                    // pick deterministic zero slots so coords are stable
                    while sel.contains(&fill) {
                        fill += 1;
                    }
                    sel.push(fill);
                    fill += 1;
                }
                sel.sort_unstable();
                groups.push(Group24 {
                    w: [vals[sel[0] as usize], vals[sel[1] as usize]],
                    coord: [sel[0], sel[1]],
                });
            }
        }
        Ok(Self { groups, k, n })
    }

    /// Storage footprint in bits (weights + coordinates) vs dense int8 —
    /// the 2x compression STC advertises (plus metadata).
    pub fn storage_bits(&self) -> (usize, usize) {
        let compressed = self.groups.len() * (2 * 8 + 2 * 2);
        let dense = self.k * self.n * 8;
        (compressed, dense)
    }
}

/// Statistics from an STC GEMM run.
#[derive(Clone, Copy, Debug, Default)]
pub struct StcStats {
    /// DP-unit cycles (each group = one dual-multiplier beat).
    pub cycles: u64,
    /// Activation pairs (post-selection) containing a zero — the
    /// opportunity §5.3 points out survives weight compression.
    pub pair_zero: u64,
    pub pairs: u64,
}

/// SPARQ-on-STC GEMM: `a (M x K, u8) * w24 -> (M x N, i32)`.
///
/// Per (group, column): coordinates select two activations; the pair is
/// vSPARQ-processed exactly like a dense pair (eq. 2) and multiplied by
/// the surviving weights. Bit-exact mirror of `ref.stc_pairdot_ref`.
pub fn stc_gemm(
    a: &[u8],
    w: &CompressedWeights,
    m: usize,
    cfg: SparqConfig,
) -> (Vec<i32>, StcStats) {
    let (k, n) = (w.k, w.n);
    assert_eq!(a.len(), m * k);
    let g = k / 4;
    let mut out = vec![0i32; m * n];
    let mut stats = StcStats::default();
    for mi in 0..m {
        let row = &a[mi * k..(mi + 1) * k];
        for col in 0..n {
            let mut acc = 0i32;
            for gi in 0..g {
                let grp = &w.groups[gi * n + col];
                let x0 = row[4 * gi + grp.coord[0] as usize];
                let x1 = row[4 * gi + grp.coord[1] as usize];
                let (y0, y1) = trim_pair(x0, x1, cfg);
                acc += i32::from(y0) * i32::from(requant_weight(grp.w[0], cfg.w_bits));
                acc += i32::from(y1) * i32::from(requant_weight(grp.w[1], cfg.w_bits));
                stats.pairs += 1;
                if x0 == 0 || x1 == 0 {
                    stats.pair_zero += 1;
                }
            }
            out[mi * n + col] = acc;
            // per output element: g groups = 2g products, the DP unit's
            // 4 dual multipliers retire 8 products (4 groups) per cycle
            // -> ceil(g/4) beats, but each beat is the dual-multiplier
            // wide beat, so the dense-equivalent count is ceil(g/2).
            stats.cycles += (g as u64).div_ceil(2);
        }
    }
    (out, stats)
}

/// Dense-equivalent cycles for the same GEMM on a non-sparse TC
/// (one 4-lane DP beat per 4 reduction elements): the 2x speedup STC
/// claims comes from only touching the K/2 surviving products.
pub fn dense_tc_cycles(m: usize, k: usize, n: usize) -> u64 {
    (m * n) as u64 * (k as u64).div_ceil(super::tensor_core::TC_LANES as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Make a (K x N) 2:4 matrix deterministically.
    fn w24(k: usize, n: usize) -> Vec<i8> {
        let mut w = vec![0i8; k * n];
        for gi in 0..k / 4 {
            for col in 0..n {
                // survivors at slots (gi+col)%4 and (gi+col+2)%4
                let s0 = (gi + col) % 4;
                let s1 = (gi + col + 2) % 4;
                w[(4 * gi + s0) * n + col] = ((gi * 13 + col * 7) % 250) as i8;
                w[(4 * gi + s1) * n + col] = -(((gi * 5 + col * 11) % 120) as i8);
            }
        }
        w
    }

    #[test]
    fn compress_roundtrip() {
        let (k, n) = (16, 3);
        let w = w24(k, n);
        let c = CompressedWeights::compress(&w, k, n).unwrap();
        assert_eq!(c.groups.len(), 4 * 3);
        // every survivor must match the dense matrix at its coordinate
        for gi in 0..4 {
            for col in 0..n {
                let grp = &c.groups[gi * n + col];
                for s in 0..2 {
                    assert_eq!(grp.w[s], w[(4 * gi + grp.coord[s] as usize) * n + col]);
                }
                assert!(grp.coord[0] < grp.coord[1]);
            }
        }
        let (cbits, dbits) = c.storage_bits();
        // weights halve (16 vs 32 bits per group); coordinates add 4
        assert_eq!(dbits, k * n * 8);
        assert_eq!(cbits, c.groups.len() * 20);
        assert!(cbits < dbits, "compressed must be smaller");
        assert_eq!((cbits - c.groups.len() * 4) * 2, dbits, "weights exactly halve");
    }

    #[test]
    fn rejects_dense_weights() {
        let w = vec![1i8; 8 * 2];
        assert!(CompressedWeights::compress(&w, 8, 2).is_err());
    }

    /// Scalar re-derivation of the STC pairdot for one output element.
    fn stc_ref(row: &[u8], w: &[i8], k: usize, n: usize, col: usize, cfg: SparqConfig) -> i32 {
        let mut acc = 0i32;
        for gi in 0..k / 4 {
            let idx: Vec<usize> =
                (0..4).filter(|&s| w[(4 * gi + s) * n + col] != 0).collect();
            let (i0, i1) = match idx.len() {
                0 => (0, 1),
                1 => {
                    if idx[0] == 0 {
                        (0, 1)
                    } else {
                        (0.min(idx[0]), idx[0])
                    }
                }
                _ => (idx[0], idx[1]),
            };
            let (x0, x1) = (row[4 * gi + i0], row[4 * gi + i1]);
            let (y0, y1) = trim_pair(x0, x1, cfg);
            acc += i32::from(y0) * i32::from(w[(4 * gi + i0) * n + col]);
            acc += i32::from(y1) * i32::from(w[(4 * gi + i1) * n + col]);
        }
        acc
    }

    #[test]
    fn gemm_matches_scalar_reference() {
        let (m, k, n) = (4, 16, 5);
        let w = w24(k, n);
        let c = CompressedWeights::compress(&w, k, n).unwrap();
        let a: Vec<u8> = (0..m * k)
            .map(|i| if i % 3 == 0 { 0 } else { ((i * 71) % 256) as u8 })
            .collect();
        for name in ["5opt_r", "2opt", "6opt_r", "7opt_r", "5opt_r_novs"] {
            let cfg = SparqConfig::named(name).unwrap();
            let (out, _) = stc_gemm(&a, &c, m, cfg);
            for mi in 0..m {
                for col in 0..n {
                    assert_eq!(
                        out[mi * n + col],
                        stc_ref(&a[mi * k..(mi + 1) * k], &w, k, n, col, cfg),
                        "{name} ({mi},{col})"
                    );
                }
            }
        }
    }

    #[test]
    fn a8w8_on_stc_equals_dense_dot() {
        // with no trimming, STC output must equal the dense dot product
        let (m, k, n) = (3, 12, 4);
        let w = w24(k, n);
        let c = CompressedWeights::compress(&w, k, n).unwrap();
        let a: Vec<u8> = (0..m * k).map(|i| ((i * 31) % 256) as u8).collect();
        let (out, stats) = stc_gemm(&a, &c, m, SparqConfig::A8W8);
        for mi in 0..m {
            for col in 0..n {
                let dense: i32 = (0..k)
                    .map(|r| i32::from(a[mi * k + r]) * i32::from(w[r * n + col]))
                    .sum();
                assert_eq!(out[mi * n + col], dense);
            }
        }
        assert_eq!(stats.pairs, (m * n * k / 4) as u64);
    }

    #[test]
    fn stc_halves_cycles_vs_dense_tc() {
        let (m, k, n) = (2, 64, 8);
        let w = w24(k, n);
        let c = CompressedWeights::compress(&w, k, n).unwrap();
        let a = vec![5u8; m * k];
        let (_, stats) = stc_gemm(&a, &c, m, SparqConfig::named("5opt").unwrap());
        assert_eq!(stats.cycles * 2, dense_tc_cycles(m, k, n));
    }
}
