//! Output-stationary systolic array (paper Fig. 3), cycle-level.
//!
//! An `rows x cols` grid of [`SparqPe`]s computes a GEMM tile: PE (i, j)
//! accumulates output element (i, j); activation pairs stream west->east
//! along rows, (doubled-bandwidth) weight pairs stream north->south
//! along columns, with the classic diagonal skew. We model time
//! explicitly — at global cycle `t`, PE (i, j) consumes reduction pair
//! `t - i - j` — so fill/drain latency and utilization come out of the
//! schedule rather than a formula (the formula is asserted in tests).
//!
//! For SPARQ the array consumes one activation *pair* per PE per cycle
//! (two MACs), which is the 2x-throughput premise the Table 5 area
//! ratios are normalized against.

use crate::quant::SparqConfig;

use super::pe::SparqPe;

/// GEMM tiling + cycle statistics for one array geometry.
#[derive(Clone, Copy, Debug)]
pub struct SystolicArray {
    pub rows: usize,
    pub cols: usize,
    pub cfg: SparqConfig,
}

/// Result of simulating a full GEMM on the array.
#[derive(Clone, Debug)]
pub struct SystolicRun {
    /// Row-major (M, N) int32 outputs — bit-exact SPARQ semantics.
    pub out: Vec<i32>,
    pub m: usize,
    pub n: usize,
    /// Total cycles including fill/drain skew, summed over tiles.
    pub cycles: u64,
    /// MAC slots actually used / total MAC slots (array utilization).
    pub utilization: f64,
    /// Pair-case counts aggregated over all PEs.
    pub both_zero: u64,
    pub zero_skip: u64,
    pub dual_trim: u64,
}

impl SystolicArray {
    pub fn new(rows: usize, cols: usize, cfg: SparqConfig) -> Self {
        Self { rows, cols, cfg }
    }

    /// Cycles to compute one (tm x tn x K) output-stationary tile:
    /// ceil(K/2) pair-beats plus the (tm - 1) + (tn - 1) skew, plus one
    /// cycle to latch. Drain of psums is overlapped with the next tile's
    /// fill (standard double-buffered readout), so it is not counted.
    pub fn tile_cycles(&self, tm: usize, tn: usize, k: usize) -> u64 {
        (k.div_ceil(2) + (tm - 1) + (tn - 1) + 1) as u64
    }

    /// Simulate `a (M x K, u8) * w (K x N, i8)` by tiling onto the array.
    ///
    /// Every PE runs the bit-exact Fig. 2 datapath; the cycle count uses
    /// the skewed schedule above per tile.
    pub fn gemm(&self, a: &[u8], w: &[i8], m: usize, k: usize, n: usize) -> SystolicRun {
        assert_eq!(a.len(), m * k);
        assert_eq!(w.len(), k * n);
        let mut out = vec![0i32; m * n];
        let mut cycles = 0u64;
        let (mut bz, mut zs, mut dt) = (0u64, 0u64, 0u64);
        let mut used_macs = 0u64;
        let mut slot_macs = 0u64;

        let mut pe = SparqPe::new(self.cfg);
        for ti in (0..m).step_by(self.rows) {
            let tm = self.rows.min(m - ti);
            for tj in (0..n).step_by(self.cols) {
                let tn = self.cols.min(n - tj);
                cycles += self.tile_cycles(tm, tn, k);
                // full array is powered for the tile regardless of edge cuts
                slot_macs += self.tile_cycles(self.rows, self.cols, k)
                    * (self.rows * self.cols * 2) as u64;
                for i in 0..tm {
                    for j in 0..tn {
                        pe.reset();
                        let row = &a[(ti + i) * k..(ti + i) * k + k];
                        let mut idx = 0;
                        while idx + 1 < k {
                            pe.cycle(
                                row[idx],
                                row[idx + 1],
                                w[idx * n + tj + j],
                                w[(idx + 1) * n + tj + j],
                            );
                            idx += 2;
                        }
                        if idx < k {
                            pe.cycle(row[idx], 0, w[idx * n + tj + j], 0);
                        }
                        out[(ti + i) * n + tj + j] = pe.psum();
                        used_macs += 2 * k.div_ceil(2) as u64;
                    }
                }
                bz += pe.stats.both_zero;
                zs += pe.stats.zero_skip;
                dt += pe.stats.dual_trim;
                pe.stats = Default::default();
            }
        }
        SystolicRun {
            out,
            m,
            n,
            cycles,
            utilization: used_macs as f64 / slot_macs.max(1) as f64,
            both_zero: bz,
            zero_skip: zs,
            dual_trim: dt,
        }
    }

    /// Cycles a *conventional* 8b-8b output-stationary array of the same
    /// geometry needs for the same GEMM (one MAC per PE per cycle) — the
    /// throughput baseline for the speedup the paper's design doubles.
    pub fn baseline_cycles(&self, m: usize, k: usize, n: usize) -> u64 {
        let mut cycles = 0u64;
        for ti in (0..m).step_by(self.rows) {
            let tm = self.rows.min(m - ti);
            for tj in (0..n).step_by(self.cols) {
                let tn = self.cols.min(n - tj);
                cycles += (k + (tm - 1) + (tn - 1) + 1) as u64;
            }
        }
        cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vsparq::sparq_dot;

    fn test_gemm(m: usize, k: usize, n: usize, cfg: &str) {
        let cfg = SparqConfig::named(cfg).unwrap();
        let a: Vec<u8> = (0..m * k)
            .map(|i| if i % 4 == 0 { 0 } else { ((i * 89) % 256) as u8 })
            .collect();
        let w: Vec<i8> = (0..k * n).map(|i| (((i * 41) % 255) as i32 - 127) as i8).collect();
        let sa = SystolicArray::new(4, 4, cfg);
        let run = sa.gemm(&a, &w, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let col: Vec<i8> = (0..k).map(|r| w[r * n + j]).collect();
                assert_eq!(
                    run.out[i * n + j],
                    sparq_dot(&a[i * k..(i + 1) * k], &col, cfg),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn gemm_bit_exact_against_quant_lib() {
        test_gemm(5, 12, 7, "5opt_r");
        test_gemm(4, 8, 4, "2opt");
        test_gemm(9, 17, 3, "6opt_r"); // odd K exercises the pad lane
        test_gemm(8, 16, 8, "7opt_r_novs");
    }

    #[test]
    fn cycle_formula() {
        let sa = SystolicArray::new(8, 8, SparqConfig::named("5opt").unwrap());
        // one exact tile: K/2 + skew(7+7) + 1
        assert_eq!(sa.tile_cycles(8, 8, 64), 32 + 14 + 1);
        // SPARQ halves the reduction beats vs the 8b-8b baseline
        let run = sa.gemm(&vec![1u8; 8 * 64], &vec![1i8; 64 * 8], 8, 64, 8);
        assert_eq!(run.cycles, 47);
        assert_eq!(sa.baseline_cycles(8, 64, 8), 64 + 14 + 1);
    }

    #[test]
    fn utilization_full_vs_ragged() {
        // slots include fill/drain skew, so even a perfectly tiled GEMM
        // sits below 1.0 — but ragged edge tiles must waste strictly more
        let sa = SystolicArray::new(4, 4, SparqConfig::named("5opt").unwrap());
        let full = sa.gemm(&vec![1u8; 4 * 64], &vec![1i8; 64 * 4], 4, 64, 4);
        assert!(full.utilization > 0.5 && full.utilization <= 1.0);
        // 5x5 output on a 4x4 array wastes slots in the edge tiles
        let ragged = sa.gemm(&vec![1u8; 5 * 64], &vec![1i8; 64 * 5], 5, 64, 5);
        assert!(
            ragged.utilization < full.utilization * 0.6,
            "ragged {} vs full {}",
            ragged.utilization,
            full.utilization
        );
    }

    #[test]
    fn speedup_vs_baseline_approaches_2x() {
        let sa = SystolicArray::new(16, 16, SparqConfig::named("5opt").unwrap());
        let (m, k, n) = (16, 1024, 16);
        let run = sa.gemm(&vec![7u8; m * k], &vec![1i8; k * n], m, k, n);
        let speedup = sa.baseline_cycles(m, k, n) as f64 / run.cycles as f64;
        assert!(speedup > 1.9, "speedup {speedup}");
    }
}
