//! Tensor-Core dot-product unit (paper Fig. 4, after Raihan et al.).
//!
//! A conventional TC DP unit multiplies four activation/weight pairs per
//! cycle and reduces them in an adder tree together with a carried
//! partial sum. The SPARQ variant replaces each multiplier with the
//! Fig. 2 dual 4b-8b unit and doubles the weight bandwidth, so one DP
//! unit consumes four activation *pairs* (eight reduction lanes) per
//! cycle.

use crate::quant::SparqConfig;

use super::pe::SparqPe;

/// Lanes (activation/weight pairs) per conventional TC DP unit.
pub const TC_LANES: usize = 4;

/// One SPARQ tensor-core DP unit.
#[derive(Clone, Debug)]
pub struct SparqDpUnit {
    pes: Vec<SparqPe>,
    pub cfg: SparqConfig,
}

/// Cycle/case statistics for a DP-unit run.
#[derive(Clone, Copy, Debug, Default)]
pub struct DpStats {
    pub cycles: u64,
    pub zero_skip: u64,
    pub dual_trim: u64,
    pub both_zero: u64,
}

impl SparqDpUnit {
    pub fn new(cfg: SparqConfig) -> Self {
        Self { pes: (0..TC_LANES).map(|_| SparqPe::new(cfg)).collect(), cfg }
    }

    /// Full dot product of length K: each cycle feeds 4 pairs (8 lanes).
    /// Returns (result, stats). Bit-exact SPARQ semantics.
    pub fn dot(&mut self, acts: &[u8], weights: &[i8]) -> (i32, DpStats) {
        assert_eq!(acts.len(), weights.len());
        for pe in &mut self.pes {
            pe.reset();
            pe.stats = Default::default();
        }
        let mut stats = DpStats::default();
        let step = 2 * TC_LANES;
        let mut base = 0;
        while base < acts.len() {
            for (lane, pe) in self.pes.iter_mut().enumerate() {
                let i = base + 2 * lane;
                if i >= acts.len() {
                    break;
                }
                let x0 = acts[i];
                let (x1, w1) = if i + 1 < acts.len() {
                    (acts[i + 1], weights[i + 1])
                } else {
                    (0, 0)
                };
                pe.cycle(x0, x1, weights[i], w1);
            }
            stats.cycles += 1;
            base += step;
        }
        // adder tree: reduce the four lane psums (associativity of i32
        // wrapping addition makes the tree order irrelevant)
        let result = self.pes.iter().map(SparqPe::psum).sum();
        for pe in &self.pes {
            stats.zero_skip += pe.stats.zero_skip;
            stats.dual_trim += pe.stats.dual_trim;
            stats.both_zero += pe.stats.both_zero;
        }
        (result, stats)
    }

    /// Cycles for a conventional 8b-8b TC DP unit on the same reduction.
    pub fn baseline_cycles(k: usize) -> u64 {
        k.div_ceil(TC_LANES) as u64
    }

    /// Fraction of pairs that kept full precision via zero-skip.
    pub fn zero_skip_rate(stats: &DpStats) -> f64 {
        let pairs = stats.zero_skip + stats.dual_trim + stats.both_zero;
        if pairs == 0 {
            return 0.0;
        }
        (stats.zero_skip + stats.both_zero) as f64 / pairs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vsparq::sparq_dot;

    #[test]
    fn dp_matches_quant_library() {
        for name in ["5opt_r", "3opt", "2opt_r", "6opt_r", "7opt_r"] {
            let cfg = SparqConfig::named(name).unwrap();
            let mut dp = SparqDpUnit::new(cfg);
            for k in [1usize, 7, 8, 9, 64, 130] {
                let acts: Vec<u8> = (0..k)
                    .map(|i| if i % 5 == 0 { 0 } else { ((i * 83 + 7) % 256) as u8 })
                    .collect();
                let w: Vec<i8> = (0..k).map(|i| (((i * 29) % 255) as i32 - 127) as i8).collect();
                let (y, _) = dp.dot(&acts, &w);
                assert_eq!(y, sparq_dot(&acts, &w, cfg), "{name} k={k}");
            }
        }
    }

    #[test]
    fn halves_cycles_vs_baseline() {
        let cfg = SparqConfig::named("5opt").unwrap();
        let mut dp = SparqDpUnit::new(cfg);
        let k = 256;
        let (_, stats) = dp.dot(&vec![9u8; k], &vec![1i8; k]);
        assert_eq!(stats.cycles, (k / 8) as u64);
        assert_eq!(SparqDpUnit::baseline_cycles(k), (k / 4) as u64);
    }

    #[test]
    fn zero_skip_rate_counts() {
        let cfg = SparqConfig::named("5opt").unwrap();
        let mut dp = SparqDpUnit::new(cfg);
        // alternate zero/non-zero: every pair zero-skips
        let acts: Vec<u8> = (0..64).map(|i| if i % 2 == 0 { 0 } else { 200 }).collect();
        let (_, stats) = dp.dot(&acts, &vec![1i8; 64]);
        assert!((SparqDpUnit::zero_skip_rate(&stats) - 1.0).abs() < 1e-9);
        assert_eq!(stats.dual_trim, 0);
    }
}
