//! The SPARQ processing element (paper Fig. 2) and its trim-and-round
//! front end.
//!
//! The PE datapath computes eq. (4):
//!
//! ```text
//!   2^opt1 * x_in1(4b) * w_in1(8b)  +  2^opt2 * x_in2(4b) * w_in2(8b)
//! ```
//!
//! with weight multiplexers that let both products share one weight.
//! Three operating cases per activation pair (eq. 2):
//!
//! * partner zero  — the non-zero activation spans both multipliers via
//!   the 8b-8b = 2x4b-8b identity (eq. 3): hi window bits at shift s+n,
//!   lo bits at shift s, both against the same weight (`MuxCtrl` set);
//! * both non-zero — each activation independently bSPARQ-trimmed to n
//!   bits with its own shift (ShiftCtrl) and its own weight;
//! * both zero     — the PE idles (contributes 0).
//!
//! The trim unit here is the "performed at a significantly lower
//! processing rate" block of §5: it turns raw 8-bit pairs into
//! [`PeControl`] words. Its decisions are exactly
//! [`crate::quant::vsparq::trim_pair`], which the tests assert.

use crate::quant::bsparq::{shift_for, trim_window};
use crate::quant::config::{Mode, SparqConfig};

/// Which eq.-2 case a pair decoded into (used by the statistics and the
/// cycle models).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairCase {
    BothZero,
    /// One zero: the other activation keeps its full 2n-bit budget.
    ZeroSkip,
    /// Both non-zero: both bSPARQ-trimmed to n bits.
    DualTrim,
}

/// Control word for one PE cycle — what the trim unit sends downstream
/// (data bits + ShiftCtrl + MuxCtrl metadata, §5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PeControl {
    /// n-bit window payloads (already rounded), values < 2^n.
    pub x1: u8,
    pub x2: u8,
    /// Dynamic shift amounts (ShiftCtrl).
    pub sh1: u8,
    pub sh2: u8,
    /// MuxCtrl: route w0 / w1 to the two multipliers.
    /// false = (w0, w1) independent products; true = both take the same
    /// weight selected by `shared_w1` (the eq.-3 split).
    pub shared: bool,
    pub shared_w1: bool,
    pub case: PairCase,
}

/// The trim-and-round front end for a fixed configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrimUnit {
    pub cfg: SparqConfig,
}

impl TrimUnit {
    pub fn new(cfg: SparqConfig) -> Self {
        assert!(
            cfg.mode != Mode::Uniform,
            "uniform baseline has no SPARQ PE decode"
        );
        Self { cfg }
    }

    /// Decode an activation pair into PE control signals.
    pub fn decode(&self, x0: u8, x1: u8) -> PeControl {
        let n = self.cfg.n_bits;
        debug_assert!(n < 8, "8-bit config needs no trim unit");
        if self.cfg.vsparq && x0 == 0 && x1 == 0 {
            return PeControl {
                x1: 0,
                x2: 0,
                sh1: 0,
                sh2: 0,
                shared: false,
                shared_w1: false,
                case: PairCase::BothZero,
            };
        }
        if self.cfg.vsparq && (x0 == 0 || x1 == 0) {
            // eq. 3 split: the surviving value, trimmed to a 2n-bit
            // window, spans both multipliers (hi half | lo half).
            let v = if x0 == 0 { x1 } else { x0 };
            let wide = (2 * n).min(8);
            let y = trim_window(v, wide, Mode::Full, self.cfg.round);
            let s = shift_for(v, wide, Mode::Full);
            let payload = y >> s; // < 2^(2n)
            let lo_mask = (1u16 << n) - 1;
            return PeControl {
                x1: (u16::from(payload) >> n) as u8,
                x2: (u16::from(payload) & lo_mask) as u8,
                sh1: s + n,
                sh2: s,
                shared: true,
                shared_w1: x0 == 0,
                case: PairCase::ZeroSkip,
            };
        }
        // both non-zero (or -vS): independent bSPARQ windows
        let y0 = trim_window(x0, n, self.cfg.mode, self.cfg.round);
        let y1 = trim_window(x1, n, self.cfg.mode, self.cfg.round);
        let s0 = shift_for(x0, n, self.cfg.mode);
        let s1 = shift_for(x1, n, self.cfg.mode);
        PeControl {
            x1: y0 >> s0,
            x2: y1 >> s1,
            sh1: s0,
            sh2: s1,
            shared: false,
            shared_w1: false,
            case: PairCase::DualTrim,
        }
    }
}

/// Cumulative PE statistics (drive the §5 sparsity discussion and F2).
#[derive(Clone, Copy, Debug, Default)]
pub struct PeStats {
    pub cycles: u64,
    pub both_zero: u64,
    pub zero_skip: u64,
    pub dual_trim: u64,
    pub macs: u64,
}

/// The Fig. 2 processing element: dual multiplier + shifters + 3-input
/// adder + psum register.
#[derive(Clone, Debug)]
pub struct SparqPe {
    trim: TrimUnit,
    psum: i32,
    pub stats: PeStats,
}

impl SparqPe {
    pub fn new(cfg: SparqConfig) -> Self {
        Self { trim: TrimUnit::new(cfg), psum: 0, stats: PeStats::default() }
    }

    pub fn reset(&mut self) {
        self.psum = 0;
    }

    pub fn psum(&self) -> i32 {
        self.psum
    }

    /// One cycle: consume an activation pair and its two weights.
    pub fn cycle(&mut self, x0: u8, x1: u8, w0: i8, w1: i8) {
        let ctl = self.trim.decode(x0, x1);
        let (w_a, w_b) = if ctl.shared {
            let w = if ctl.shared_w1 { w1 } else { w0 };
            (w, w)
        } else {
            (w0, w1)
        };
        // the two 4b-8b products, dynamically shifted (eq. 4)
        let p1 = (i32::from(ctl.x1) * i32::from(w_a)) << ctl.sh1;
        let p2 = (i32::from(ctl.x2) * i32::from(w_b)) << ctl.sh2;
        self.psum += p1 + p2;
        self.stats.cycles += 1;
        self.stats.macs += 2;
        match ctl.case {
            PairCase::BothZero => self.stats.both_zero += 1,
            PairCase::ZeroSkip => self.stats.zero_skip += 1,
            PairCase::DualTrim => self.stats.dual_trim += 1,
        }
    }

    /// Run a whole dot product through the PE (zero-padding odd tails).
    pub fn dot(&mut self, acts: &[u8], weights: &[i8]) -> i32 {
        assert_eq!(acts.len(), weights.len());
        self.reset();
        let mut i = 0;
        while i + 1 < acts.len() {
            self.cycle(acts[i], acts[i + 1], weights[i], weights[i + 1]);
            i += 2;
        }
        if i < acts.len() {
            self.cycle(acts[i], 0, weights[i], 0);
        }
        self.psum
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vsparq::{sparq_dot, trim_pair};

    fn all_cfgs() -> Vec<SparqConfig> {
        ["5opt", "5opt_r", "3opt", "3opt_r", "2opt", "2opt_r", "6opt_r", "7opt_r"]
            .iter()
            .map(|n| SparqConfig::named(n).unwrap())
            .collect()
    }

    #[test]
    fn decode_matches_trim_pair_reconstruction() {
        // reconstructing (x << sh) from the control word must equal the
        // quant-library trim for every pair and config
        for cfg in all_cfgs() {
            let tu = TrimUnit::new(cfg);
            for x0 in 0..=255u8 {
                for x1 in [0u8, 1, 16, 27, 128, 255] {
                    let ctl = tu.decode(x0, x1);
                    let (e0, e1) = trim_pair(x0, x1, cfg);
                    let (r0, r1) = match ctl.case {
                        PairCase::BothZero => (0u32, 0u32),
                        PairCase::ZeroSkip => {
                            let v = (u32::from(ctl.x1) << ctl.sh1)
                                + (u32::from(ctl.x2) << ctl.sh2);
                            if ctl.shared_w1 {
                                (0, v)
                            } else {
                                (v, 0)
                            }
                        }
                        PairCase::DualTrim => (
                            u32::from(ctl.x1) << ctl.sh1,
                            u32::from(ctl.x2) << ctl.sh2,
                        ),
                    };
                    assert_eq!(
                        (r0, r1),
                        (u32::from(e0), u32::from(e1)),
                        "cfg={cfg} x0={x0} x1={x1} ctl={ctl:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn payloads_fit_n_bits() {
        for cfg in all_cfgs() {
            let tu = TrimUnit::new(cfg);
            for x0 in 0..=255u8 {
                for x1 in [0u8, 3, 200] {
                    let ctl = tu.decode(x0, x1);
                    assert!(u16::from(ctl.x1) < (1 << cfg.n_bits), "{cfg} {x0} {x1}");
                    assert!(u16::from(ctl.x2) < (1 << cfg.n_bits), "{cfg} {x0} {x1}");
                }
            }
        }
    }

    #[test]
    fn pe_dot_equals_quant_library() {
        let acts: Vec<u8> = (0..512)
            .map(|i| if i % 3 == 0 { 0 } else { ((i * 73) % 256) as u8 })
            .collect();
        let weights: Vec<i8> = (0..512).map(|i| (((i * 57) % 255) as i32 - 127) as i8).collect();
        for cfg in all_cfgs() {
            let mut pe = SparqPe::new(cfg);
            assert_eq!(
                pe.dot(&acts, &weights),
                sparq_dot(&acts, &weights, cfg),
                "cfg={cfg}"
            );
        }
    }

    #[test]
    fn pe_odd_length_dot() {
        let cfg = SparqConfig::named("5opt_r").unwrap();
        let acts = [200u8, 13, 255];
        let w = [3i8, -7, 11];
        let mut pe = SparqPe::new(cfg);
        assert_eq!(pe.dot(&acts, &w), sparq_dot(&acts, &w, cfg));
    }

    #[test]
    fn stats_count_cases() {
        let cfg = SparqConfig::named("5opt").unwrap();
        let mut pe = SparqPe::new(cfg);
        pe.dot(&[0, 0, 0, 9, 9, 9], &[1, 1, 1, 1, 1, 1]);
        assert_eq!(pe.stats.both_zero, 1);
        assert_eq!(pe.stats.zero_skip, 1);
        assert_eq!(pe.stats.dual_trim, 1);
        assert_eq!(pe.stats.cycles, 3);
    }

    #[test]
    fn novs_never_zero_skips() {
        let cfg = SparqConfig::named("5opt_r_novs").unwrap();
        let mut pe = SparqPe::new(cfg);
        pe.dot(&[0, 9, 9, 0], &[1, 1, 1, 1]);
        assert_eq!(pe.stats.zero_skip, 0);
        assert_eq!(pe.stats.dual_trim, 2);
    }
}
