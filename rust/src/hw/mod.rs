//! Hardware models (paper §4 and §5.2) — DESIGN.md S7–S11.
//!
//! * [`pe`]          — the SPARQ processing element of Fig. 2: a dual
//!   n-bit x 8-bit multiplier with dynamic shift-left units, plus the
//!   trim-and-round front end that decodes an activation pair into PE
//!   control signals. Bit-exact against [`crate::quant`].
//! * [`systolic`]    — output-stationary systolic array (Fig. 3) at
//!   cycle granularity, built from [`pe::SparqPe`]s.
//! * [`tensor_core`] — the Tensor-Core dot-product unit (Fig. 4).
//! * [`stc`]         — Sparse Tensor Core (Fig. 5): 2:4 weight
//!   compression, coordinate mux-select, then vSPARQ on the survivors.
//! * [`area`]        — first-order gate-area model regenerating the
//!   relative-area comparison of Table 5.

pub mod area;
pub mod pe;
pub mod stc;
pub mod systolic;
pub mod tensor_core;

pub use pe::{PairCase, PeControl, SparqPe, TrimUnit};
