//! First-order gate-area model for the Table 5 comparison.
//!
//! The paper synthesizes SystemVerilog with a 65nm library; we cannot
//! run an EDA flow, so we estimate combinational area from standard
//! scaling laws (in NAND2-equivalent gate units):
//!
//! * array multiplier a x b bits:   `KM * a * b`        (partial-product
//!   cells dominate; linear in the bit-product),
//! * ripple/carry-select adder:     `KA * width * (inputs - 1)`,
//! * barrel shifter, `o` options:   `KS * width * ceil(log2 o)` (one
//!   2:1 mux layer per select stage),
//! * 2:1 mux:                       `KX * width`,
//! * flip-flop:                     `KR * width`.
//!
//! Table 5 normalizes area to MAC *throughput*: the SPARQ/2x4b PEs
//! retire two MACs per cycle, the 8b-8b baseline one. We report our
//! model's numbers next to the paper's (experiments::table5); the model
//! is anchored only by the component laws above — no per-row fitting —
//! so agreement in *ordering* and rough magnitude is the claim, and the
//! paper's two anchor points (1.00, 0.50) are checked in tests with a
//! generous tolerance.

use crate::quant::{Mode, SparqConfig};

// Gate-unit constants (NAND2 equivalents, 65nm-ish folklore values).
const KM: f64 = 1.0; // per multiplier bit-product cell
const KA: f64 = 1.1; // per adder bit per extra input
const KS: f64 = 0.45; // per shifter bit per mux stage
const KX: f64 = 0.45; // per 2:1 mux bit
const KR: f64 = 0.9; // per flip-flop bit

/// Accumulator width for int8 CNN dot products (the paper's SA psum).
const ACC_W: f64 = 24.0;

fn log2_ceil(o: u32) -> f64 {
    if o <= 1 {
        0.0
    } else {
        (32 - (o - 1).leading_zeros()) as f64
    }
}

/// Component breakdown of one PE (gate units).
#[derive(Clone, Copy, Debug, Default)]
pub struct PeArea {
    pub multipliers: f64,
    pub shifters: f64,
    pub adders: f64,
    pub muxes: f64,
    pub registers: f64,
    /// MACs retired per cycle (normalization denominator).
    pub macs_per_cycle: f64,
}

impl PeArea {
    pub fn total(&self) -> f64 {
        self.multipliers + self.shifters + self.adders + self.muxes + self.registers
    }

    /// Area normalized to MAC throughput.
    pub fn per_mac(&self) -> f64 {
        self.total() / self.macs_per_cycle
    }
}

/// Conventional 8b-8b output-stationary SA PE: one 8x8 multiplier, psum
/// accumulate, forwarding registers for activation and weight.
pub fn sa_baseline() -> PeArea {
    PeArea {
        multipliers: KM * 8.0 * 8.0,
        shifters: 0.0,
        adders: KA * ACC_W, // 2-input psum adder
        muxes: 0.0,
        registers: KR * (ACC_W + 8.0 + 8.0), // psum + act + weight fwd
        macs_per_cycle: 1.0,
    }
}

/// Static 2x4b-8b PE (the reference design of Table 5): two fixed 4b-8b
/// multipliers, 3-input psum adder, no shifters.
pub fn sa_2x4b() -> PeArea {
    PeArea {
        multipliers: KM * 2.0 * 4.0 * 8.0,
        shifters: 0.0,
        adders: KA * ACC_W * 2.0, // 3-input adder
        muxes: 0.0,
        registers: KR * (ACC_W + 2.0 * 4.0 + 2.0 * 8.0),
        macs_per_cycle: 2.0,
    }
}

/// SPARQ SA PE for a configuration (paper Fig. 2): two n-bit x 8-bit
/// multipliers, two dynamic shift-left units sized by the placement
/// option count, 3-input adder, weight-select muxes (vSPARQ only) and
/// the ShiftCtrl/MuxCtrl pipeline state.
pub fn sa_sparq(cfg: SparqConfig) -> PeArea {
    let n = f64::from(cfg.n_bits);
    let opts = u32::from(cfg.placement_options());
    // vSPARQ zero-skip adds the wide-window placements (eq. 3 split):
    // shifts reach (8 - n), one extra option beyond the narrow set for
    // Full mode; 3opt/2opt sets already contain shift 4.
    let shift_opts = if cfg.vsparq && cfg.mode == Mode::Full { opts + 1 } else { opts };
    let stages = log2_ceil(shift_opts);
    let prod_w = n + 8.0; // multiplier output width entering the shifter
    let meta_bits = 2.0 * log2_ceil(shift_opts) + if cfg.vsparq { 1.0 } else { 0.0 };
    PeArea {
        multipliers: KM * 2.0 * n * 8.0,
        shifters: KS * 2.0 * prod_w * stages,
        adders: KA * ACC_W * 2.0,
        muxes: if cfg.vsparq { KX * 2.0 * 8.0 } else { 0.0 },
        registers: KR * (ACC_W + 2.0 * n + 2.0 * 8.0 + meta_bits),
        macs_per_cycle: 2.0,
    }
}

/// Conventional TC DP unit (Fig. 4): four 8x8 multipliers + a 3-level
/// adder tree + the carried psum input. Per 4 MACs/cycle.
pub fn tc_baseline() -> PeArea {
    PeArea {
        multipliers: KM * 4.0 * 8.0 * 8.0,
        shifters: 0.0,
        // adder tree: 2 + 1 + 1(psum) two-input adders at ~ACC_W
        adders: KA * ACC_W * 4.0,
        muxes: 0.0,
        registers: KR * (ACC_W + 4.0 * 8.0 + 4.0 * 8.0),
        macs_per_cycle: 4.0,
    }
}

/// Static 2x4b-8b TC DP unit: eight 4b-8b multipliers (pairwise), wider
/// adder tree.
pub fn tc_2x4b() -> PeArea {
    PeArea {
        multipliers: KM * 8.0 * 4.0 * 8.0,
        shifters: 0.0,
        adders: KA * ACC_W * 8.0, // 8-leaf tree + psum
        muxes: 0.0,
        registers: KR * (ACC_W + 8.0 * 4.0 + 8.0 * 8.0),
        macs_per_cycle: 8.0,
    }
}

/// SPARQ TC DP unit: four Fig.-2 dual multipliers.
pub fn tc_sparq(cfg: SparqConfig) -> PeArea {
    let lane = sa_sparq(cfg);
    let n = f64::from(cfg.n_bits);
    PeArea {
        multipliers: 4.0 * lane.multipliers,
        shifters: 4.0 * lane.shifters,
        adders: KA * ACC_W * 8.0,
        muxes: 4.0 * lane.muxes,
        registers: KR * (ACC_W + 8.0 * n + 8.0 * 8.0)
            + 4.0 * (lane.registers - KR * (ACC_W + 2.0 * n + 2.0 * 8.0)),
        macs_per_cycle: 8.0,
    }
}

/// The standalone trim-and-round unit area relative to a conventional TC
/// (paper §5.3 reports 17% / 12% / 9% for 5opt / 3opt / 2opt): priority
/// encoder (leading-zero detect), rounding incrementer and window-select
/// mux per lane. The unit runs at the (lower) activation delivery rate,
/// so the per-lane logic is narrow: ~2 gates per encoder stage, half a
/// gate per incrementer bit, and a 0.15-gate/bit/option select tree —
/// first-order constants chosen from the same 65nm folklore as above.
pub fn trim_unit_relative_to_tc(cfg: SparqConfig) -> f64 {
    let opts = f64::from(cfg.placement_options());
    let n = f64::from(cfg.n_bits);
    let per_act =
        2.0 * log2_ceil(opts as u32 + 1) + 0.5 * n + 0.15 * n * opts;
    // 8 activations per SPARQ TC DP beat
    (8.0 * per_act) / tc_baseline().total()
}

/// One Table 5 row: (label, SA ratio, TC ratio).
pub fn table5_rows() -> Vec<(String, f64, f64)> {
    let base_sa = sa_baseline().per_mac();
    let base_tc = tc_baseline().per_mac();
    let mut rows = vec![
        ("8b-8b".to_string(), 1.0, 1.0),
        ("2x4b-8b".to_string(), sa_2x4b().per_mac() / base_sa, tc_2x4b().per_mac() / base_tc),
    ];
    for name in ["7opt_r", "6opt_r", "5opt_r", "3opt_r", "2opt_r"] {
        let cfg = SparqConfig::named(name).unwrap();
        rows.push((
            format!("{}opt", cfg.placement_options()),
            sa_sparq(cfg).per_mac() / base_sa,
            tc_sparq(cfg).per_mac() / base_tc,
        ));
    }
    for name in ["5opt_r_novs", "3opt_r_novs"] {
        let cfg = SparqConfig::named(name).unwrap();
        rows.push((
            format!("{}opt-vS", cfg.placement_options()),
            sa_sparq(cfg).per_mac() / base_sa,
            tc_sparq(cfg).per_mac() / base_tc,
        ));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ratio(name: &str) -> f64 {
        let base = sa_baseline().per_mac();
        sa_sparq(SparqConfig::named(name).unwrap()).per_mac() / base
    }

    #[test]
    fn anchor_2x4b_near_half() {
        let r = sa_2x4b().per_mac() / sa_baseline().per_mac();
        assert!(r > 0.45 && r < 0.70, "2x4b SA ratio {r} out of band");
        let rtc = tc_2x4b().per_mac() / tc_baseline().per_mac();
        assert!(rtc > 0.45 && rtc < 0.70, "2x4b TC ratio {rtc} out of band");
    }

    #[test]
    fn ordering_matches_paper() {
        // more placement options -> more shifter area (paper §5.2)
        assert!(ratio("2opt_r") < ratio("3opt_r"));
        assert!(ratio("3opt_r") < ratio("5opt_r"));
        // narrower data bits shrink the PE despite more options
        assert!(ratio("7opt_r") < ratio("6opt_r"));
        assert!(ratio("6opt_r") < ratio("5opt_r"));
        // dropping vSPARQ saves the muxes + metadata
        assert!(ratio("5opt_r_novs") < ratio("5opt_r"));
        assert!(ratio("3opt_r_novs") < ratio("3opt_r"));
        // every SPARQ variant sits between the two anchors
        let anchor = sa_2x4b().per_mac() / sa_baseline().per_mac();
        for n in ["2opt_r", "3opt_r", "5opt_r", "6opt_r", "7opt_r"] {
            assert!(ratio(n) > anchor, "{n} below static anchor");
            assert!(ratio(n) < 1.0, "{n} above 8b-8b baseline");
        }
    }

    #[test]
    fn trim_unit_small_and_ordered() {
        let t5 = trim_unit_relative_to_tc(SparqConfig::named("5opt_r").unwrap());
        let t3 = trim_unit_relative_to_tc(SparqConfig::named("3opt_r").unwrap());
        let t2 = trim_unit_relative_to_tc(SparqConfig::named("2opt_r").unwrap());
        // paper: 17% / 12% / 9%
        assert!(t2 < t3 && t3 < t5, "{t2} {t3} {t5}");
        assert!(t5 < 0.30, "trim unit should stay a small fraction: {t5}");
    }

    #[test]
    fn rows_complete() {
        let rows = table5_rows();
        assert_eq!(rows.len(), 9);
        assert_eq!(rows[0].1, 1.0);
    }
}
