//! Typed wrapper around a compiled PJRT executable.
//!
//! Artifacts are lowered with `return_tuple=True`, so every execution
//! returns a single tuple literal which is decomposed into [`TensorOut`]s
//! here. Inputs are [`TensorArg`]s — shape + contiguous host data —
//! converted to literals without intermediate copies via
//! `create_from_shape_and_untyped_data`.

use anyhow::Result;

/// A host tensor handed to the runtime (f32 or i32, C-contiguous).
#[derive(Clone, Debug)]
pub enum TensorArg {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl TensorArg {
    pub fn f32(dims: &[usize], data: Vec<f32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self::F32 { dims: dims.to_vec(), data }
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self::I32 { dims: dims.to_vec(), data }
    }

    pub fn elements(&self) -> usize {
        match self {
            Self::F32 { data, .. } => data.len(),
            Self::I32 { data, .. } => data.len(),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Self::F32 { dims, data } => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                dims,
                bytemuck_cast_slice_f32(data),
            ),
            Self::I32 { dims, data } => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                dims,
                bytemuck_cast_slice_i32(data),
            ),
        };
        lit.map_err(|e| anyhow::anyhow!("building literal: {e}"))
    }
}

fn bytemuck_cast_slice_f32(v: &[f32]) -> &[u8] {
    // SAFETY: reinterpreting f32 -> u8 only shrinks alignment, every
    // byte pattern is a valid u8, and the length covers exactly the
    // bytes of `v`; the borrow ties the output lifetime to the input.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

fn bytemuck_cast_slice_i32(v: &[i32]) -> &[u8] {
    // SAFETY: same argument as the f32 variant — alignment shrinks,
    // u8 has no invalid bit patterns, length is size_of_val(v).
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// One output tensor copied back to the host.
#[derive(Clone, Debug)]
pub struct TensorOut {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorOut {
    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            TensorData::I32(_) => panic!("output is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            TensorData::F32(_) => panic!("output is f32, expected i32"),
        }
    }

    fn from_literal(lit: xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("output shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("reading f32 output: {e}"))?,
            ),
            xla::ElementType::S32 => TensorData::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("reading i32 output: {e}"))?,
            ),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        };
        Ok(Self { dims, data })
    }
}

/// A compiled model entry point. `run` is `&self` and internally
/// synchronized by PJRT, so executables can be shared across the
/// coordinator's worker tasks via `Arc`.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: the xla crate wraps raw pointers without declaring Send; the
// PJRT CPU client serializes execution internally and the wrapper holds
// no host-side mutable state, so moving it across threads is sound.
unsafe impl Send for Executable {}
// SAFETY: `run` takes `&self` and all mutation happens behind PJRT's own
// internal synchronization, so concurrent shared access is sound.
unsafe impl Sync for Executable {}

impl Executable {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable) -> Self {
        Self { exe }
    }

    /// Execute with host inputs; returns the decomposed output tuple.
    pub fn run(&self, args: &[TensorArg]) -> Result<Vec<TensorOut>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow::anyhow!("untupling result: {e}"))?;
        parts.into_iter().map(TensorOut::from_literal).collect()
    }
}
