//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the rust coordinator.
//!
//! `artifacts/manifest.json` lists every exported model variant with its
//! HLO files (float / calib / sparq), weight archive and graph metadata.
//! This module parses it with a small hand-rolled JSON reader (the repo
//! keeps third-party dependencies to the ones baked into the image).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::JsonValue;

/// Which lowered entry point of a model to load.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// FP32 folded forward: f(img) -> (logits,)
    Float,
    /// Calibration pass: f(img) -> (max[L], mean[L])
    Calib,
    /// SPARQ forward: f(img, scales[L], cfg[5]) -> (logits,)
    Sparq,
}

impl ArtifactKind {
    fn key(self) -> &'static str {
        match self {
            Self::Float => "float",
            Self::Calib => "calib",
            Self::Sparq => "sparq",
        }
    }
}

/// One exported model variant (dense or 2:4-pruned).
#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    /// e.g. "resnet10" or "resnet10_p24"
    pub tag: String,
    pub arch: String,
    pub pruned: bool,
    /// number of quantized convs == length of the activation-scale vector
    pub quant_convs: usize,
    dir: PathBuf,
    files: std::collections::HashMap<String, String>,
    pub weights: String,
    pub meta: String,
}

impl ModelArtifacts {
    pub fn hlo_path(&self, kind: ArtifactKind) -> PathBuf {
        self.dir.join(&self.files[kind.key()])
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights)
    }

    pub fn meta_path(&self) -> PathBuf {
        self.dir.join(&self.meta)
    }
}

/// Parsed `manifest.json`.
#[derive(Debug)]
pub struct Manifest {
    pub models: Vec<ModelArtifacts>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = JsonValue::parse(&text).context("parsing manifest.json")?;
        let mut models = Vec::new();
        for row in root.as_array().context("manifest root must be an array")? {
            let files = row.get("files").context("manifest row missing `files`")?;
            let mut map = std::collections::HashMap::new();
            for kind in ["float", "calib", "sparq"] {
                map.insert(
                    kind.to_string(),
                    files.get(kind).and_then(|v| v.as_str()).context("bad file entry")?.to_string(),
                );
            }
            models.push(ModelArtifacts {
                tag: row.get("tag").and_then(|v| v.as_str()).context("tag")?.to_string(),
                arch: row.get("arch").and_then(|v| v.as_str()).context("arch")?.to_string(),
                pruned: row.get("pruned").and_then(|v| v.as_bool()).unwrap_or(false),
                quant_convs: row
                    .get("quant_convs")
                    .and_then(|v| v.as_f64())
                    .context("quant_convs")? as usize,
                dir: artifacts_dir.to_path_buf(),
                files: map,
                weights: row.get("weights").and_then(|v| v.as_str()).context("weights")?.to_string(),
                meta: row.get("meta").and_then(|v| v.as_str()).context("meta")?.to_string(),
            });
        }
        Ok(Self { models, dir: artifacts_dir.to_path_buf() })
    }

    pub fn get(&self, tag: &str) -> Result<&ModelArtifacts> {
        self.models
            .iter()
            .find(|m| m.tag == tag)
            .ok_or_else(|| anyhow::anyhow!("model `{tag}` not in manifest ({:?})", self.tags()))
    }

    pub fn tags(&self) -> Vec<&str> {
        self.models.iter().map(|m| m.tag.as_str()).collect()
    }

    /// Dense (unpruned) model tags — the Table 1–4 population.
    pub fn dense_tags(&self) -> Vec<&str> {
        self.models.iter().filter(|m| !m.pruned).map(|m| m.tag.as_str()).collect()
    }

    /// Pruned tags — the Table 6 population.
    pub fn pruned_tags(&self) -> Vec<&str> {
        self.models.iter().filter(|m| m.pruned).map(|m| m.tag.as_str()).collect()
    }
}
