//! L3 runtime — loads AOT artifacts (HLO text) and executes them on PJRT.
//!
//! The request path is: [`PjrtRuntime::cpu`] once at startup,
//! [`PjrtRuntime::load`] per artifact (compile is cached by artifact
//! path), then [`Executable::run`] per batch. Python never appears here;
//! the HLO text was produced at build time by `python/compile/aot.py`.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). All artifacts
//! are lowered with `return_tuple=True`, so outputs are unwrapped from a
//! tuple literal here.

mod artifacts;
mod executable;

pub use artifacts::{ArtifactKind, Manifest, ModelArtifacts};
pub use executable::{Executable, TensorArg, TensorData, TensorOut};

/// Marker substring carried by every error the offline `xla` stub
/// (rust/crates/xla) raises. Artifact-gated tests match on it to tell
/// "offline build — skip" from a genuine runtime failure. Kept here —
/// not re-exported from `xla` — so swapping the stub for the real
/// bindings stays a manifest-only change; must stay in sync with
/// `STUB_UNAVAILABLE` in rust/crates/xla/src/lib.rs.
pub const PJRT_STUB_MARKER: &str = "xla_extension is not available in this offline build";

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A PJRT client plus a compile cache keyed by artifact path.
///
/// Compilation of a full-model HLO takes O(100 ms)–O(s); the cache makes
/// `load` idempotent so the coordinator can request executables lazily.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
}

impl PjrtRuntime {
    /// CPU PJRT client (the only backend in this environment).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO-text artifact; cached per canonical path.
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        let key = path
            .canonicalize()
            .with_context(|| format!("artifact not found: {}", path.display()))?;
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            key.to_str().expect("artifact path must be utf-8"),
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e}", key.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", key.display()))?;
        let exe = std::sync::Arc::new(Executable::new(exe));
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of cached executables (metrics).
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
