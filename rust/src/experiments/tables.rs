//! Table runners — each regenerates one paper table over the mini zoo.
//!
//! All accuracy tables share a context holding the PJRT runtime, the
//! artifact manifest, the eval dataset and a per-model calibration
//! cache, so a full `sparq-cli all` run calibrates each model once.

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::{calibrate, evaluate_native, evaluate_pjrt, scales_for_policy};
use crate::data::Dataset;
use crate::hw::area;
use crate::model::{EngineMode, Graph, Weights};
use crate::quant::baselines::{table3_baselines, ScalePolicy};
use crate::quant::minmax::CalibStats;
use crate::quant::SparqConfig;
use crate::runtime::{Manifest, PjrtRuntime};

use super::paper;
use super::report::{fmt_acc, fmt_delta, Table};

/// Shared state for the experiment suite.
pub struct ExperimentCtx {
    pub rt: PjrtRuntime,
    pub manifest: Manifest,
    pub eval: Dataset,
    pub calib_ds: Dataset,
    pub batch: usize,
    pub eval_limit: usize,
    pub calib_images: usize,
    calib_cache: HashMap<String, CalibStats>,
    fp32_cache: HashMap<String, f64>,
}

impl ExperimentCtx {
    pub fn new(artifacts: &std::path::Path, eval_limit: usize, calib_images: usize) -> Result<Self> {
        let manifest = Manifest::load(artifacts)?;
        let eval = Dataset::load(&artifacts.join("test.bin"))?;
        let calib_ds = Dataset::load(&artifacts.join("train.bin"))?;
        Ok(Self {
            rt: PjrtRuntime::cpu()?,
            manifest,
            eval,
            calib_ds,
            batch: 64,
            eval_limit,
            calib_images,
            calib_cache: HashMap::new(),
            fp32_cache: HashMap::new(),
        })
    }

    /// Calibration stats for a model (cached).
    pub fn calib(&mut self, tag: &str) -> Result<CalibStats> {
        if let Some(s) = self.calib_cache.get(tag) {
            return Ok(s.clone());
        }
        let model = self.manifest.get(tag)?.clone();
        let stats =
            calibrate(&self.rt, &model, &self.calib_ds, self.batch, self.calib_images)?;
        self.calib_cache.insert(tag.to_string(), stats.clone());
        Ok(stats)
    }

    /// FP32 top-1 for a model (cached) — the baseline every delta uses.
    pub fn fp32_acc(&mut self, tag: &str) -> Result<f64> {
        if let Some(&a) = self.fp32_cache.get(tag) {
            return Ok(a);
        }
        let model = self.manifest.get(tag)?.clone();
        let rep = evaluate_pjrt(
            &self.rt, &model, &self.eval, self.batch, &[], None, self.eval_limit,
        )?;
        self.fp32_cache.insert(tag.to_string(), rep.accuracy());
        Ok(rep.accuracy())
    }

    /// SPARQ-path accuracy under a config + scale policy.
    pub fn quant_acc(&mut self, tag: &str, cfg: SparqConfig, policy: ScalePolicy) -> Result<f64> {
        let stats = self.calib(tag)?;
        let scales = scales_for_policy(&stats, policy, cfg.n_bits);
        let model = self.manifest.get(tag)?.clone();
        let rep = evaluate_pjrt(
            &self.rt, &model, &self.eval, self.batch, &scales, Some(cfg), self.eval_limit,
        )?;
        Ok(rep.accuracy())
    }

    /// Native-engine accuracy (used by Table 6's STC datapath).
    pub fn native_acc(&mut self, tag: &str, cfg: SparqConfig, mode: EngineMode) -> Result<f64> {
        let stats = self.calib(tag)?;
        let scales = scales_for_policy(&stats, ScalePolicy::MinMax, cfg.n_bits);
        let model = self.manifest.get(tag)?.clone();
        let graph = Graph::load(&model.meta_path())?;
        let weights = Weights::load(&model.weights_path())?;
        let rep = evaluate_native(
            &graph, &weights, &self.eval, self.batch, &scales, cfg, mode, self.eval_limit,
        )?;
        Ok(rep.accuracy())
    }
}

/// Table 1: FP32 / A8W8 / A4W8 / A8W4 absolute top-1 per model.
pub fn table1(ctx: &mut ExperimentCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 1 — top-1 accuracy under base quantization precisions",
        &["model", "FP32", "A8W8", "A4W8", "A8W4"],
    );
    for tag in ctx.manifest.dense_tags().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        let fp32 = ctx.fp32_acc(&tag)?;
        let mut cells = vec![tag.clone(), fmt_acc(fp32)];
        for name in ["a8w8", "a4w8", "a8w4"] {
            let acc =
                ctx.quant_acc(&tag, SparqConfig::named(name).unwrap(), ScalePolicy::MinMax)?;
            cells.push(fmt_acc(acc));
        }
        t.row(cells);
    }
    Ok(t)
}

/// Table 2: the 9-config SPARQ grid, reported as deltas vs FP32.
pub fn table2(ctx: &mut ExperimentCtx) -> Result<Table> {
    let grid = SparqConfig::table2_grid();
    let mut headers: Vec<&str> = vec!["model"];
    headers.extend(grid.iter().map(|(n, _)| *n));
    let mut t = Table::new(
        "Table 2 — SPARQ degradation vs FP32 ({5,3,2}opt x {Trim, +R, +R-vS})",
        &headers,
    );
    for tag in ctx.manifest.dense_tags().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        let fp32 = ctx.fp32_acc(&tag)?;
        let mut cells = vec![tag.clone()];
        for (_, cfg) in &grid {
            let acc = ctx.quant_acc(&tag, *cfg, ScalePolicy::MinMax)?;
            cells.push(fmt_delta(acc - fp32));
        }
        t.row(cells);
    }
    let mut paper_row = vec!["paper:ResNet-18".to_string()];
    for (name, _) in &grid {
        paper_row.push(paper::lookup(&paper::TABLE2_RESNET18, name));
    }
    t.row(paper_row);
    Ok(t)
}

/// Table 3: SPARQ vs baselines (SySMT / ACIQ-clip / naive uniform).
pub fn table3(ctx: &mut ExperimentCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 3 — SPARQ vs 4-bit PTQ baselines (delta vs FP32)",
        &["model", "5opt+R", "3opt+R", "2opt+R", "sysmt", "aciq4", "naive_a4w8", "naive_a8w4"],
    );
    for tag in ctx.manifest.dense_tags().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        let fp32 = ctx.fp32_acc(&tag)?;
        let mut cells = vec![tag.clone()];
        for name in ["5opt_r", "3opt_r", "2opt_r"] {
            let acc =
                ctx.quant_acc(&tag, SparqConfig::named(name).unwrap(), ScalePolicy::MinMax)?;
            cells.push(fmt_delta(acc - fp32));
        }
        for b in table3_baselines() {
            let acc = ctx.quant_acc(&tag, b.cfg, b.policy)?;
            cells.push(fmt_delta(acc - fp32));
        }
        t.row(cells);
    }
    Ok(t)
}

/// Table 4: 3-bit (6opt) / 2-bit (7opt), with and without vSPARQ, plus
/// the uniform 3/2-bit baselines the paper compares against.
pub fn table4(ctx: &mut ExperimentCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 4 — sub-4-bit SPARQ (delta vs FP32)",
        &["model", "3b(6opt)", "2b(7opt)", "3b-vS", "2b-vS", "uniform3b", "uniform2b"],
    );
    let configs = ["6opt_r", "7opt_r", "6opt_r_novs", "7opt_r_novs", "a3w8", "a2w8"];
    for tag in ctx.manifest.dense_tags().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        let fp32 = ctx.fp32_acc(&tag)?;
        let mut cells = vec![tag.clone()];
        for name in configs {
            let acc =
                ctx.quant_acc(&tag, SparqConfig::named(name).unwrap(), ScalePolicy::MinMax)?;
            cells.push(fmt_delta(acc - fp32));
        }
        t.row(cells);
    }
    let mut paper_row = vec!["paper:ResNet-18".to_string()];
    for name in configs {
        paper_row.push(paper::lookup(&paper::TABLE4_RESNET18, name));
    }
    t.row(paper_row);
    Ok(t)
}

/// Table 5: relative PE area (model) next to the paper's synthesis.
pub fn table5() -> Table {
    let mut t = Table::new(
        "Table 5 — relative area normalized to MAC throughput",
        &["design", "SA (model)", "SA (paper)", "TC (model)", "TC (paper)"],
    );
    let model_rows = area::table5_rows();
    for ((label, sa, tc), (plabel, psa, ptc)) in model_rows.iter().zip(paper::TABLE5.iter()) {
        debug_assert_eq!(label.replace("opt-vS", "opt-vS"), *plabel.to_string());
        t.row(vec![
            label.clone(),
            format!("{sa:.2}"),
            format!("{psa:.2}"),
            format!("{tc:.2}"),
            format!("{ptc:.2}"),
        ]);
    }
    t
}

/// Table 6: SPARQ on STC (2:4-pruned models), via the native STC engine.
pub fn table6(ctx: &mut ExperimentCtx) -> Result<Table> {
    let mut t = Table::new(
        "Table 6 — SPARQ on Sparse Tensor Cores (2:4 pruned models)",
        &["model", "FP32", "A8W8", "5opt", "3opt", "2opt", "3b(6opt)", "2b(7opt)"],
    );
    for tag in ctx.manifest.pruned_tags().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        let fp32 = ctx.fp32_acc(&tag)?;
        let a8w8 = ctx.native_acc(&tag, SparqConfig::A8W8, EngineMode::Stc)?;
        let mut cells = vec![tag.clone(), fmt_acc(fp32), fmt_acc(a8w8)];
        for name in ["5opt_r", "3opt_r", "2opt_r", "6opt_r", "7opt_r"] {
            let acc =
                ctx.native_acc(&tag, SparqConfig::named(name).unwrap(), EngineMode::Stc)?;
            cells.push(fmt_delta(acc - fp32));
        }
        t.row(cells);
    }
    let mut paper_row = vec!["paper:ResNet-18".into(), "69.77%".into(), "69.79%".into()];
    for name in ["5opt_r", "3opt_r", "2opt_r", "6opt_r", "7opt_r"] {
        paper_row.push(paper::lookup(&paper::TABLE6_RESNET18, name));
    }
    t.row(paper_row);
    Ok(t)
}
