//! Experiment reproductions (DESIGN.md §6) — one runner per paper
//! table/figure, each emitting the same rows the paper reports plus the
//! paper's own numbers for side-by-side comparison.

pub mod paper;
pub mod report;
pub mod stats;
pub mod tables;

pub use report::Table;
pub use stats::{toggle_stats, ToggleStats};
pub use tables::{table1, table2, table3, table4, table5, table6, ExperimentCtx};
