//! The paper's published numbers (NeurIPS 2021, Tables 1–6 and §5.1
//! statistics), embedded for side-by-side comparison in our reports.
//! Our substrate differs (synthetic dataset, mini zoo — see DESIGN.md
//! §3), so the comparison is of *shape*: orderings, signs and rough
//! magnitudes, which EXPERIMENTS.md walks through claim by claim.

/// Paper Table 2 — ResNet-18 row (the canonical example): relative
/// degradation for {5,3,2}opt x {Trim, +R, +R -vS}.
pub const TABLE2_RESNET18: [(&str, f64); 9] = [
    ("5opt", -0.0011),
    ("5opt_r", -0.0007),
    ("5opt_r_novs", -0.0011),
    ("3opt", -0.0022),
    ("3opt_r", -0.0014),
    ("3opt_r_novs", -0.0048),
    ("2opt", -0.0287),
    ("2opt_r", -0.0137),
    ("2opt_r_novs", -0.0202),
];

/// Paper Table 4 — ResNet-18: 3-bit/2-bit with and without vSPARQ.
pub const TABLE4_RESNET18: [(&str, f64); 4] = [
    ("6opt_r", -0.0021),
    ("7opt_r", -0.0164),
    ("6opt_r_novs", -0.0051),
    ("7opt_r_novs", -0.0257),
];

/// Paper Table 5 — relative area per MAC throughput (SA, TC).
pub const TABLE5: [(&str, f64, f64); 9] = [
    ("8b-8b", 1.00, 1.00),
    ("2x4b-8b", 0.50, 0.50),
    ("7opt", 0.59, 0.58),
    ("6opt", 0.66, 0.63),
    ("5opt", 0.72, 0.72),
    ("3opt", 0.61, 0.66),
    ("2opt", 0.57, 0.61),
    ("5opt-vS", 0.62, 0.67),
    ("3opt-vS", 0.59, 0.61),
];

/// Paper §5.1: toggle probability of bits 7..4 among non-zero ResNet-18
/// activations (ILSVRC-2012), and the derived >= 1-of-4-MSBs-toggled
/// probability.
pub const TOGGLE_BITS_7_TO_4: [f64; 4] = [0.005, 0.092, 0.338, 0.448];
pub const TOGGLE_ANY_MSB: f64 = 0.67;

/// Paper §5.3: trim-unit area relative to a conventional TC.
pub const TRIM_UNIT_REL: [(&str, f64); 3] = [("5opt", 0.17), ("3opt", 0.12), ("2opt", 0.09)];

/// Paper Table 6 — STC relative degradation (ResNet-18 row).
pub const TABLE6_RESNET18: [(&str, f64); 5] = [
    ("5opt_r", -0.0013),
    ("3opt_r", -0.0034),
    ("2opt_r", -0.0159),
    ("6opt_r", -0.0041),
    ("7opt_r", -0.0192),
];

/// Look up a paper value by key; empty string when the paper has no
/// number for that cell (rendered as "-").
pub fn lookup(table: &[(&str, f64)], key: &str) -> String {
    table
        .iter()
        .find(|(k, _)| *k == key)
        .map(|(_, v)| format!("{:+.2}%", v * 100.0))
        .unwrap_or_else(|| "-".to_string())
}
