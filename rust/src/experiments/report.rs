//! Plain-text table rendering + JSON export for experiment results.

use crate::json::JsonValue;

/// A rendered experiment table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text table (markdown-compatible).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = format!("## {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn to_json(&self) -> JsonValue {
        crate::json_obj! {
            "title" => self.title.clone(),
            "headers" => self.headers.clone(),
            "rows" => JsonValue::Array(
                self.rows.iter().cloned().map(JsonValue::from).collect()
            ),
        }
    }
}

/// Format an accuracy delta the way the paper prints them (+0.04% /
/// -1.37%).
pub fn fmt_delta(delta: f64) -> String {
    format!("{:+.2}%", delta * 100.0)
}

/// Format an absolute accuracy (69.76%).
pub fn fmt_acc(acc: f64) -> String {
    format!("{:.2}%", acc * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["model", "acc"]);
        t.row(vec!["resnet10".into(), "-0.10%".into()]);
        let s = t.render();
        assert!(s.contains("| model    | acc    |"));
        assert!(s.contains("| resnet10 | -0.10% |"));
    }

    #[test]
    fn delta_formatting() {
        assert_eq!(fmt_delta(-0.0137), "-1.37%");
        assert_eq!(fmt_delta(0.0004), "+0.04%");
        assert_eq!(fmt_acc(0.6976), "69.76%");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
