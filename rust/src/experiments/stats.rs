//! Activation bit/value statistics (experiment F2, paper §2 and §5.1).
//!
//! The paper motivates bSPARQ with toggle statistics: for non-zero
//! ResNet-18 activations, bits 7/6/5/4 toggle 0.5/9.2/33.8/44.8% of the
//! time, so ~67% of non-zero activations have a toggled MSB nibble while
//! 90% of the time the top two bits are quiet. We re-measure exactly
//! these quantities on our zoo by tracing the uniform-quantized im2col
//! activations through the native engine.

use anyhow::Result;

use crate::data::Dataset;
use crate::model::{Engine, EngineMode, Graph, TraceSink, Weights};
use crate::quant::vsparq::pair_zero_fraction;
use crate::quant::SparqConfig;

/// Aggregated bit-level statistics over traced activations.
#[derive(Clone, Debug, Default)]
pub struct ToggleStats {
    /// Count of activations with bit b set (b = 0..7), non-zero only.
    pub bit_toggles: [u64; 8],
    pub nonzero: u64,
    pub total: u64,
    /// Activations whose 4-bit MSB nibble has any toggled bit.
    pub msb_nibble_toggled: u64,
    /// Activations whose top two bits are both clear (non-zero only).
    pub top2_quiet: u64,
    /// vSPARQ opportunity: pairs with at least one zero.
    pub pair_zero_sum: f64,
    pub pair_batches: u64,
}

impl ToggleStats {
    pub fn zero_fraction(&self) -> f64 {
        1.0 - self.nonzero as f64 / self.total.max(1) as f64
    }

    /// P(bit b toggled | activation non-zero).
    pub fn bit_prob(&self, b: usize) -> f64 {
        self.bit_toggles[b] as f64 / self.nonzero.max(1) as f64
    }

    /// P(any of bits 7..4 toggled | non-zero) — the paper's 67% figure.
    pub fn any_msb_prob(&self) -> f64 {
        self.msb_nibble_toggled as f64 / self.nonzero.max(1) as f64
    }

    /// P(bits 7 and 6 both clear | non-zero) — the paper's 90% figure.
    pub fn top2_quiet_prob(&self) -> f64 {
        self.top2_quiet as f64 / self.nonzero.max(1) as f64
    }

    /// Mean fraction of activation pairs containing a zero.
    pub fn pair_zero_prob(&self) -> f64 {
        self.pair_zero_sum / self.pair_batches.max(1) as f64
    }
}

impl TraceSink for ToggleStats {
    fn record(&mut self, _layer: &str, acts_q: &[u8]) {
        for &x in acts_q {
            self.total += 1;
            if x == 0 {
                continue;
            }
            self.nonzero += 1;
            for (b, tally) in self.bit_toggles.iter_mut().enumerate() {
                if x & (1 << b) != 0 {
                    *tally += 1;
                }
            }
            if x & 0xf0 != 0 {
                self.msb_nibble_toggled += 1;
            }
            if x & 0xc0 == 0 {
                self.top2_quiet += 1;
            }
        }
        self.pair_zero_sum += pair_zero_fraction(acts_q);
        self.pair_batches += 1;
    }
}

/// Trace `images` eval images through the native engine at A8W8 and
/// collect toggle statistics (quantization grid = min-max scales).
pub fn toggle_stats(
    graph: &Graph,
    weights: &Weights,
    ds: &Dataset,
    scales: &[f32],
    images: usize,
    batch: usize,
) -> Result<ToggleStats> {
    let engine = Engine::new(graph, weights, SparqConfig::A8W8, scales, EngineMode::Dense)?;
    let mut stats = ToggleStats::default();
    let mut buf = Vec::new();
    let mut start = 0usize;
    while start < images.min(ds.n) {
        let take = batch.min(images.min(ds.n) - start);
        ds.batch_f32_into(start, take, &mut buf);
        engine.forward_traced(&buf, take, &mut stats)?;
        start += take;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_counts_bits() {
        let mut s = ToggleStats::default();
        s.record("l", &[0, 0b1000_0000, 0b0000_1111, 0b0011_0000]);
        assert_eq!(s.total, 4);
        assert_eq!(s.nonzero, 3);
        assert_eq!(s.bit_toggles[7], 1);
        assert_eq!(s.bit_toggles[0], 1);
        assert_eq!(s.msb_nibble_toggled, 2); // 0x80 and 0x30
        assert_eq!(s.top2_quiet, 2); // 0x0f and 0x30
        assert!((s.zero_fraction() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn probabilities_normalized() {
        let mut s = ToggleStats::default();
        s.record("l", &[255; 16]);
        assert!((s.any_msb_prob() - 1.0).abs() < 1e-12);
        assert!((s.bit_prob(7) - 1.0).abs() < 1e-12);
        assert_eq!(s.top2_quiet_prob(), 0.0);
        assert_eq!(s.pair_zero_prob(), 0.0);
    }
}
