//! Dataset loader — mirrors `python/compile/data.py::write_bin`.
//!
//! Layout (little-endian):
//! `MAGIC("SPRQDS1\0") | n u32 | h u32 | w u32 | c u32 | nclasses u32 |
//!  images u8[n*h*w*c] | labels u8[n]`

use std::path::Path;

use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 8] = b"SPRQDS1\x00";

/// A labelled image set, pixels in u8 NHWC.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub num_classes: usize,
    pub images: Vec<u8>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading dataset {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 28 || &bytes[..8] != MAGIC {
            bail!("bad dataset magic");
        }
        let rd = |at: usize| -> usize {
            u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize
        };
        let (n, h, w, c, num_classes) = (rd(8), rd(12), rd(16), rd(20), rd(24));
        let img_len = n * h * w * c;
        let expect = 28 + img_len + n;
        if bytes.len() != expect {
            bail!("dataset length mismatch: {} != {}", bytes.len(), expect);
        }
        let images = bytes[28..28 + img_len].to_vec();
        let labels = bytes[28 + img_len..].to_vec();
        if let Some(&bad) = labels.iter().find(|&&l| l as usize >= num_classes) {
            bail!("label {bad} out of range (nclasses={num_classes})");
        }
        Ok(Self { n, h, w, c, num_classes, images, labels })
    }

    /// Pixels of image `i` as normalized f32 in [0, 1] (the only input
    /// preprocessing anywhere — mirrors `data.normalize`).
    pub fn image_f32(&self, i: usize) -> Vec<f32> {
        let stride = self.h * self.w * self.c;
        self.images[i * stride..(i + 1) * stride]
            .iter()
            .map(|&p| f32::from(p) / 255.0)
            .collect()
    }

    /// Fill `out` with a normalized batch `[count, h, w, c]`, recycling
    /// images modulo `n` (used to pad the final partial batch).
    pub fn batch_f32_into(&self, start: usize, count: usize, out: &mut Vec<f32>) {
        let stride = self.h * self.w * self.c;
        out.clear();
        out.reserve(count * stride);
        for j in 0..count {
            let i = (start + j) % self.n;
            out.extend(
                self.images[i * stride..(i + 1) * stride]
                    .iter()
                    .map(|&p| f32::from(p) / 255.0),
            );
        }
    }

    pub fn label(&self, i: usize) -> usize {
        self.labels[i] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(n: usize, h: usize, w: usize, c: usize, k: usize) -> Vec<u8> {
        let mut b = MAGIC.to_vec();
        for v in [n, h, w, c, k] {
            b.extend_from_slice(&(v as u32).to_le_bytes());
        }
        b.extend((0..n * h * w * c).map(|i| (i % 256) as u8));
        b.extend((0..n).map(|i| (i % k) as u8));
        b
    }

    #[test]
    fn roundtrip() {
        let d = Dataset::from_bytes(&fake(5, 4, 4, 3, 10)).unwrap();
        assert_eq!((d.n, d.h, d.w, d.c, d.num_classes), (5, 4, 4, 3, 10));
        assert_eq!(d.image_f32(0)[1], 1.0 / 255.0);
        assert_eq!(d.label(3), 3);
        let mut buf = Vec::new();
        d.batch_f32_into(3, 4, &mut buf); // wraps modulo n
        assert_eq!(buf.len(), 4 * 4 * 4 * 3);
        assert_eq!(buf[..48], d.image_f32(3)[..]);
        assert_eq!(buf[96..144], d.image_f32(0)[..]); // wrapped
    }

    #[test]
    fn rejects_corrupt() {
        assert!(Dataset::from_bytes(b"short").is_err());
        let mut bad = fake(5, 4, 4, 3, 10);
        bad.truncate(bad.len() - 1);
        assert!(Dataset::from_bytes(&bad).is_err());
        let mut bad_label = fake(5, 4, 4, 3, 10);
        let len = bad_label.len();
        bad_label[len - 1] = 99;
        assert!(Dataset::from_bytes(&bad_label).is_err());
    }
}
