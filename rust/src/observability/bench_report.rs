//! Versioned, serde-free schema for `BENCH_*.json` perf artifacts.
//!
//! SPARQ's claims are speed-vs-accuracy numbers; this module turns the
//! speed half into machine-checkable files instead of prose. A
//! [`BenchReport`] is one benchmark run: a host fingerprint (so numbers
//! from different machines are never compared blindly) plus one
//! [`BenchSection`] per measured surface — kernel, engine, router,
//! HTTP edge, policy variant. `benches/hotpath.rs` and
//! `examples/serve_bench.rs --bench-json` both emit this format, and
//! [`crate::observability::budget`] gates CI on it.
//!
//! Serialization goes through the in-repo [`crate::json`] parser in
//! both directions, and [`BenchReport::from_json`] is *strict*: an
//! unknown version, a duplicate or empty section name, or a missing /
//! non-finite / negative metric is an error, not a default — a perf
//! artifact that silently lost fields is worse than no artifact.
//!
//! Metric semantics: `0.0` means **not measured** for that section
//! (e.g. a kernel section has no queue, an HTTP section no GMAC/s).
//! Budgets treat 0-valued baseline metrics as unconstrained for the
//! same reason.
//!
//! The quantiles recorded here (`p50_us`/`p99_us`) are **whole-run**
//! statistics: each section's latencies over its full measurement
//! window, the right shape for regression trajectories. They are
//! deliberately *not* the control-plane signal — the SLO degradation
//! ladder ([`crate::coordinator::slo`]) steers on the batcher's
//! sliding-window view ([`crate::observability::WindowedHist`],
//! surfaced as `recent_p99_us` on `/v1/metrics`), because a
//! since-start quantile is far too stale to react to a load spike.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::BatcherSnapshot;
use crate::json::JsonValue;
use crate::json_obj;

/// Schema identifier embedded in every report; bump on breaking change.
pub const SCHEMA_VERSION: &str = "sparq-bench/1";

/// Queue-health counters for sections that run through a batcher
/// (router / HTTP sections); all-zero for compute-only sections.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// High-water mark of the bounded queue during the section.
    pub depth_peak: u64,
    /// Requests shed (oldest dropped under `ShedOldest` overload).
    pub shed: u64,
    /// Requests expired past their queue-wait deadline.
    pub expired: u64,
    /// Requests rejected at submit (`RejectNewest` overload).
    pub rejected: u64,
}

impl QueueStats {
    /// Lift the batcher's live counters into report form.
    pub fn from_snapshot(s: &BatcherSnapshot) -> Self {
        Self {
            depth_peak: s.peak_queue_depth,
            shed: s.shed,
            expired: s.expired,
            rejected: s.rejected,
        }
    }

    pub fn to_json(&self) -> JsonValue {
        json_obj! {
            "depth_peak" => self.depth_peak as usize,
            "shed" => self.shed as usize,
            "expired" => self.expired as usize,
            "rejected" => self.rejected as usize,
        }
    }

    pub fn from_json(v: &JsonValue, ctx: &str) -> Result<Self> {
        Ok(Self {
            depth_peak: req_metric(v, "depth_peak", ctx)? as u64,
            shed: req_metric(v, "shed", ctx)? as u64,
            expired: req_metric(v, "expired", ctx)? as u64,
            rejected: req_metric(v, "rejected", ctx)? as u64,
        })
    }
}

/// One measured surface of the system. Fields that a section does not
/// measure stay `0.0` / zeroed (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchSection {
    /// Unique section name, e.g. `kernel_blocked_mt`, `http_edge`.
    pub name: String,
    /// Images (or requests, for serving sections) per second.
    pub img_per_s: f64,
    /// Effective GEMM throughput, giga-MACs per second.
    pub gmac_per_s: f64,
    /// Median latency per unit of work, microseconds.
    pub p50_us: f64,
    /// 99th-percentile latency, microseconds.
    pub p99_us: f64,
    /// Batcher queue health over the section (serving sections only).
    pub queue: QueueStats,
    /// Storage bits per activation under the section's quantization
    /// config (paper §5.1 model, [`crate::quant::footprint`]).
    pub bits_per_act: f64,
}

impl BenchSection {
    /// A section with every metric unmeasured; fill in what applies.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            img_per_s: 0.0,
            gmac_per_s: 0.0,
            p50_us: 0.0,
            p99_us: 0.0,
            queue: QueueStats::default(),
            bits_per_act: 0.0,
        }
    }

    pub fn to_json(&self) -> JsonValue {
        json_obj! {
            "name" => self.name.as_str(),
            "img_per_s" => self.img_per_s,
            "gmac_per_s" => self.gmac_per_s,
            "p50_us" => self.p50_us,
            "p99_us" => self.p99_us,
            "queue" => self.queue.to_json(),
            "bits_per_act" => self.bits_per_act,
        }
    }

    pub fn from_json(v: &JsonValue) -> Result<Self> {
        let name = v
            .get("name")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| anyhow!("section missing string `name`"))?;
        if name.is_empty() {
            bail!("section name must be non-empty");
        }
        let ctx = &format!("section `{name}`");
        let queue = v
            .get("queue")
            .ok_or_else(|| anyhow!("{ctx}: missing `queue` object"))?;
        Ok(Self {
            name: name.to_string(),
            img_per_s: req_metric(v, "img_per_s", ctx)?,
            gmac_per_s: req_metric(v, "gmac_per_s", ctx)?,
            p50_us: req_metric(v, "p50_us", ctx)?,
            p99_us: req_metric(v, "p99_us", ctx)?,
            queue: QueueStats::from_json(queue, ctx)?,
            bits_per_act: req_metric(v, "bits_per_act", ctx)?,
        })
    }
}

/// Where the numbers came from. Budgets are only meaningful per host;
/// the fingerprint is what makes cross-machine comparison an explicit
/// decision instead of an accident.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostFingerprint {
    /// `available_parallelism` on the measuring host.
    pub cores: usize,
    /// Raw `SPARQ_THREADS` value at measure time ("" = unset).
    pub sparq_threads: String,
    /// Commit the build came from; "unknown" outside a checkout.
    pub git_sha: String,
}

impl HostFingerprint {
    /// Fingerprint the current process: core count, thread override,
    /// and the git commit (CI's `GITHUB_SHA` wins; otherwise the
    /// nearest enclosing `.git` is read directly — no `git` subprocess
    /// so benches stay exec-free).
    pub fn detect() -> Self {
        Self {
            cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            sparq_threads: std::env::var("SPARQ_THREADS").unwrap_or_default(),
            git_sha: detect_git_sha(),
        }
    }

    pub fn to_json(&self) -> JsonValue {
        json_obj! {
            "cores" => self.cores,
            "sparq_threads" => self.sparq_threads.as_str(),
            "git_sha" => self.git_sha.as_str(),
        }
    }

    pub fn from_json(v: &JsonValue) -> Result<Self> {
        let req_str = |key: &str| {
            v.get(key)
                .and_then(JsonValue::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("host fingerprint missing string `{key}`"))
        };
        let cores = v
            .get("cores")
            .and_then(JsonValue::as_usize)
            .ok_or_else(|| anyhow!("host fingerprint missing numeric `cores`"))?;
        Ok(Self { cores, sparq_threads: req_str("sparq_threads")?, git_sha: req_str("git_sha")? })
    }
}

fn detect_git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    let mut dir = std::env::current_dir().ok();
    while let Some(d) = dir {
        if let Ok(head) = std::fs::read_to_string(d.join(".git/HEAD")) {
            let head = head.trim();
            let Some(refname) = head.strip_prefix("ref: ") else {
                return head.to_string(); // detached HEAD: the sha itself
            };
            if let Ok(sha) = std::fs::read_to_string(d.join(".git").join(refname)) {
                return sha.trim().to_string();
            }
            if let Ok(packed) = std::fs::read_to_string(d.join(".git/packed-refs")) {
                for line in packed.lines() {
                    let mut it = line.split_whitespace();
                    if let (Some(sha), Some(name)) = (it.next(), it.next()) {
                        if name == refname {
                            return sha.to_string();
                        }
                    }
                }
            }
            return "unknown".to_string();
        }
        dir = d.parent().map(Path::to_path_buf);
    }
    "unknown".to_string()
}

/// One benchmark run: fingerprint + sections, in emission order.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub host: HostFingerprint,
    pub sections: Vec<BenchSection>,
}

impl BenchReport {
    /// An empty report fingerprinting the current host.
    pub fn new() -> Self {
        Self { host: HostFingerprint::detect(), sections: Vec::new() }
    }

    /// Append a section; duplicate names are a caller bug and panic
    /// here rather than surviving to a confusing budget-check error.
    pub fn push(&mut self, section: BenchSection) {
        assert!(
            self.section(&section.name).is_none(),
            "duplicate bench section `{}`",
            section.name
        );
        self.sections.push(section);
    }

    pub fn section(&self, name: &str) -> Option<&BenchSection> {
        self.sections.iter().find(|s| s.name == name)
    }

    pub fn to_json(&self) -> JsonValue {
        json_obj! {
            "version" => SCHEMA_VERSION,
            "host" => self.host.to_json(),
            "sections" => self.sections.iter().map(BenchSection::to_json).collect::<Vec<_>>(),
        }
    }

    /// Strict schema validation — see module docs for what's rejected.
    pub fn from_json(v: &JsonValue) -> Result<Self> {
        let version = v
            .get("version")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| anyhow!("report missing string `version`"))?;
        if version != SCHEMA_VERSION {
            bail!("unsupported report version `{version}` (want `{SCHEMA_VERSION}`)");
        }
        let host = HostFingerprint::from_json(
            v.get("host").ok_or_else(|| anyhow!("report missing `host` object"))?,
        )?;
        let raw = v
            .get("sections")
            .and_then(JsonValue::as_array)
            .ok_or_else(|| anyhow!("report missing `sections` array"))?;
        let mut sections = Vec::with_capacity(raw.len());
        let mut seen = std::collections::BTreeSet::new();
        for s in raw {
            let s = BenchSection::from_json(s)?;
            if !seen.insert(s.name.clone()) {
                bail!("duplicate section name `{}`", s.name);
            }
            sections.push(s);
        }
        Ok(Self { host, sections })
    }

    /// Parse + validate report text (the `--validate-report` seam).
    pub fn parse(text: &str) -> Result<Self> {
        Self::from_json(&JsonValue::parse(text).context("report is not valid JSON")?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")
            .with_context(|| format!("writing bench report to {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report from {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("invalid bench report {}", path.display()))
    }
}

impl Default for BenchReport {
    fn default() -> Self {
        Self::new()
    }
}

/// Required metric field: present, numeric, finite, non-negative.
fn req_metric(v: &JsonValue, key: &str, ctx: &str) -> Result<f64> {
    let f = v
        .get(key)
        .and_then(JsonValue::as_f64)
        .ok_or_else(|| anyhow!("{ctx}: missing numeric `{key}`"))?;
    if !f.is_finite() || f < 0.0 {
        bail!("{ctx}: `{key}` must be finite and >= 0, got {f}");
    }
    Ok(f)
}

/// Wall-clock summary of repeated timed iterations, microseconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Timing {
    pub iters: usize,
    pub min_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
}

impl Timing {
    /// Units-of-work per second at the *median* iteration time — the
    /// robust throughput estimate the report sections carry.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        if self.p50_us <= 0.0 {
            return 0.0;
        }
        units_per_iter / (self.p50_us * 1e-6)
    }
}

/// Time `iters` runs of `f` after `warmup` untimed runs; nearest-rank
/// percentiles over the per-iteration wall times.
pub fn time_iters(warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples_us = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples_us.sort_by(f64::total_cmp);
    let rank = |q: f64| {
        let idx = ((iters as f64 * q).ceil() as usize).clamp(1, iters) - 1;
        samples_us[idx]
    };
    Timing {
        iters,
        min_us: samples_us[0],
        p50_us: rank(0.50),
        p99_us: rank(0.99),
        mean_us: samples_us.iter().sum::<f64>() / iters as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_report() -> BenchReport {
        // Distinct non-zero values in every single field so the
        // round-trip test catches any dropped or swapped field.
        let mut r = BenchReport {
            host: HostFingerprint {
                cores: 12,
                sparq_threads: "4".to_string(),
                git_sha: "abc123def".to_string(),
            },
            sections: Vec::new(),
        };
        r.push(BenchSection {
            name: "kernel_blocked_mt".to_string(),
            img_per_s: 123.5,
            gmac_per_s: 45.25,
            p50_us: 810.5,
            p99_us: 990.75,
            queue: QueueStats { depth_peak: 7, shed: 3, expired: 2, rejected: 1 },
            bits_per_act: 7.5,
        });
        r.push(BenchSection::new("engine_fwd_1t"));
        r
    }

    #[test]
    fn json_round_trip_preserves_every_field() {
        let r = full_report();
        let text = r.to_json().to_string();
        let back = BenchReport::parse(&text).expect("round trip parse");
        assert_eq!(back, r);
        // and serialization is stable across a second trip
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn save_load_round_trip() {
        let r = full_report();
        let path = std::env::temp_dir().join("sparq_bench_report_test.json");
        r.save(&path).unwrap();
        let back = BenchReport::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, r);
    }

    #[test]
    fn validation_rejects_bad_reports() {
        let good = full_report().to_json().to_string();
        // wrong version
        let bad = good.replace(SCHEMA_VERSION, "sparq-bench/999");
        assert!(BenchReport::parse(&bad).unwrap_err().to_string().contains("version"));
        // missing metric field
        let bad = good.replace("\"gmac_per_s\":45.25,", "");
        assert!(BenchReport::parse(&bad).unwrap_err().to_string().contains("gmac_per_s"));
        // negative metric
        let bad = good.replace("\"img_per_s\":123.5", "\"img_per_s\":-1");
        assert!(BenchReport::parse(&bad).unwrap_err().to_string().contains("img_per_s"));
        // duplicate section names
        let bad = good.replace("engine_fwd_1t", "kernel_blocked_mt");
        assert!(BenchReport::parse(&bad).unwrap_err().to_string().contains("duplicate"));
        // empty section name
        let bad = good.replace("engine_fwd_1t", "");
        assert!(BenchReport::parse(&bad).is_err());
        // not JSON at all
        assert!(BenchReport::parse("not json").is_err());
    }

    #[test]
    fn push_panics_on_duplicate_section() {
        let mut r = full_report();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            r.push(BenchSection::new("engine_fwd_1t"));
        }));
        assert!(result.is_err());
    }

    #[test]
    fn detect_fingerprints_this_checkout() {
        let h = HostFingerprint::detect();
        assert!(h.cores >= 1);
        // Tests run from inside the repo: either CI's GITHUB_SHA or a
        // real 40-hex sha from .git must be found.
        assert_ne!(h.git_sha, "unknown", "tests run inside a git checkout");
        assert!(h.git_sha.len() >= 7, "{}", h.git_sha);
    }

    #[test]
    fn queue_stats_lift_from_snapshot() {
        let s = BatcherSnapshot {
            peak_queue_depth: 9,
            shed: 4,
            expired: 2,
            rejected: 1,
            ..BatcherSnapshot::default()
        };
        let q = QueueStats::from_snapshot(&s);
        assert_eq!(q, QueueStats { depth_peak: 9, shed: 4, expired: 2, rejected: 1 });
    }

    #[test]
    fn time_iters_percentiles_are_ordered() {
        let t = time_iters(2, 25, || {
            std::hint::black_box((0..2000u64).sum::<u64>());
        });
        assert_eq!(t.iters, 25);
        assert!(t.min_us <= t.p50_us);
        assert!(t.p50_us <= t.p99_us);
        assert!(t.min_us <= t.mean_us);
        assert!(t.mean_us > 0.0);
        assert!(t.throughput(32.0) > 0.0);
        // single iteration: every statistic is that one sample
        let one = time_iters(0, 1, || {
            std::hint::black_box((0..2000u64).sum::<u64>());
        });
        assert_eq!(one.min_us, one.p50_us);
        assert_eq!(one.p50_us, one.p99_us);
        assert_eq!(one.p99_us, one.mean_us);
    }
}
