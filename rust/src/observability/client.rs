//! Minimal blocking HTTP/1.1 GET/POST client for JSON endpoints.
//!
//! This is the collector side of the ops story: `examples/ops_top.rs`
//! polls `GET /v1/metrics` over a real socket with this client, the
//! bench harness uses it to scrape the front door it just stood up,
//! and the POST side drives `POST /v1/models/{name}/reload` from
//! tooling and the CI rollout smoke. It deliberately speaks only the
//! subset the in-repo [`crate::coordinator::http`] server emits —
//! `Content-Length`-framed responses over a fresh connection — so it
//! stays a page of code with zero dependencies, but it is a real
//! network client: everything goes through the OS socket layer, not an
//! in-process shortcut.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::json::JsonValue;

/// Upper bound on accepted response bodies; a metrics payload is a few
/// KB, so anything near this limit is a protocol error, not data.
const MAX_BODY: usize = 4 << 20;

/// `GET http://{addr}{path}`, expect a 200 with a JSON body, parse it.
/// `timeout` bounds connect and each socket read/write individually.
pub fn http_get_json(addr: &str, path: &str, timeout: Duration) -> Result<JsonValue> {
    let (status, body) = http_get(addr, path, timeout)?;
    if status != 200 {
        bail!("GET {path} on {addr}: HTTP {status} — {body}");
    }
    JsonValue::parse(&body).with_context(|| format!("GET {path} on {addr}: body is not JSON"))
}

/// `GET http://{addr}{path}` returning `(status, body)` uninterpreted.
pub fn http_get(addr: &str, path: &str, timeout: Duration) -> Result<(u16, String)> {
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    exchange(addr, req.as_bytes(), timeout)
}

/// `POST http://{addr}{path}` with a JSON `body`, expect a 2xx and
/// parse the JSON reply. The seam tooling uses to drive
/// `POST /v1/models/{name}/reload` and `POST /v1/infer/{model}`.
pub fn http_post_json(
    addr: &str,
    path: &str,
    body: &JsonValue,
    timeout: Duration,
) -> Result<JsonValue> {
    let (status, reply) = http_post(addr, path, &body.to_string(), timeout)?;
    if !(200..300).contains(&status) {
        bail!("POST {path} on {addr}: HTTP {status} — {reply}");
    }
    JsonValue::parse(&reply).with_context(|| format!("POST {path} on {addr}: body is not JSON"))
}

/// `POST http://{addr}{path}` with `body` as `application/json`,
/// returning `(status, body)` uninterpreted.
pub fn http_post(addr: &str, path: &str, body: &str, timeout: Duration) -> Result<(u16, String)> {
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    exchange(addr, req.as_bytes(), timeout)
}

/// One request/response over a fresh connection.
fn exchange(addr: &str, request: &[u8], timeout: Duration) -> Result<(u16, String)> {
    use std::net::ToSocketAddrs;
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .with_context(|| format!("{addr} resolved to no address"))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.write_all(request).context("writing request")?;

    let mut raw = Vec::new();
    let mut chunk = [0u8; 8192];
    loop {
        // Connection: close framing with a Content-Length cross-check
        // below; stop early if a response ever exceeds the body cap.
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                raw.extend_from_slice(&chunk[..n]);
                if raw.len() > MAX_BODY {
                    bail!("response from {addr} exceeds {MAX_BODY} bytes");
                }
            }
            Err(e) => return Err(e).context("reading response"),
        }
    }
    parse_response(&raw, addr)
}

fn parse_response(raw: &[u8], addr: &str) -> Result<(u16, String)> {
    let text = std::str::from_utf8(raw).context("response is not UTF-8")?;
    let (head, body) = text
        .split_once("\r\n\r\n")
        .with_context(|| format!("no header/body separator in response from {addr}"))?;
    let status_line = head.lines().next().unwrap_or_default();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("malformed status line `{status_line}` from {addr}"))?;
    // Trust Content-Length over connection teardown when present: a
    // truncated read should be an error, not a mangled JSON parse.
    let content_length = head
        .lines()
        .skip(1)
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse::<usize>().ok());
    let body = match content_length {
        Some(len) if body.len() < len => {
            bail!("truncated response from {addr}: got {} of {len} body bytes", body.len())
        }
        Some(len) => &body[..len],
        None => body,
    };
    Ok((status, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_framed_response() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nContent-Length: 13\r\n\r\n{\"depth\": 42}";
        let (status, body) = parse_response(raw, "test").unwrap();
        assert_eq!(status, 200);
        assert_eq!(JsonValue::parse(&body).unwrap().get("depth").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn content_length_truncates_trailing_bytes() {
        let raw = b"HTTP/1.1 404 Not Found\r\ncontent-length: 2\r\n\r\n{}extra";
        let (status, body) = parse_response(raw, "test").unwrap();
        assert_eq!(status, 404);
        assert_eq!(body, "{}");
    }

    #[test]
    fn short_body_is_a_truncation_error() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Length: 99\r\n\r\n{}";
        let err = parse_response(raw, "test").unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn garbage_is_rejected() {
        assert!(parse_response(b"not http at all", "test").is_err());
        assert!(parse_response(b"HTTP/1.1 banana\r\n\r\n", "test").is_err());
    }

    /// POST framing over a real loopback socket: the one-shot server
    /// thread captures the raw request, asserts the body arrived with
    /// correct `Content-Length` framing, and answers 202.
    #[test]
    fn post_sends_framed_json_body_and_reads_reply() {
        use crate::json_obj;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || -> Vec<u8> {
            let (mut stream, _) = listener.accept().unwrap();
            let mut raw = Vec::new();
            let mut chunk = [0u8; 4096];
            // Read until the framed request is complete (headers + the
            // declared body length).
            loop {
                let n = stream.read(&mut chunk).unwrap();
                raw.extend_from_slice(&chunk[..n]);
                let Some(head_end) =
                    raw.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
                else {
                    continue;
                };
                let head = std::str::from_utf8(&raw[..head_end]).unwrap();
                let len: usize = head
                    .lines()
                    .filter_map(|l| l.split_once(':'))
                    .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
                    .and_then(|(_, v)| v.trim().parse().ok())
                    .unwrap();
                if raw.len() >= head_end + len {
                    break;
                }
            }
            stream
                .write_all(
                    b"HTTP/1.1 202 Accepted\r\nContent-Length: 21\r\n\r\n{\"status\":\"accepted\"}",
                )
                .unwrap();
            raw
        });
        let body = json_obj! { "source" => "perturb", "amplitude" => 2usize };
        let reply =
            http_post_json(&addr, "/v1/models/m/reload", &body, Duration::from_secs(5)).unwrap();
        assert_eq!(reply.get("status").and_then(JsonValue::as_str), Some("accepted"));
        let raw = String::from_utf8(server.join().unwrap()).unwrap();
        assert!(raw.starts_with("POST /v1/models/m/reload HTTP/1.1\r\n"), "{raw}");
        assert!(raw.contains("Content-Type: application/json\r\n"), "{raw}");
        let payload = body.to_string();
        assert!(raw.contains(&format!("Content-Length: {}\r\n", payload.len())), "{raw}");
        assert!(raw.ends_with(&payload), "{raw}");
    }

    // The live-front-door path (a reload POST answered by the real
    // event loop) is covered in tests/http_server.rs and by
    // `serve_bench --reload-smoke` in CI.
}
