//! Fixed-bucket latency histogram — the one latency data structure the
//! whole serving stack records into.
//!
//! Buckets are powers of two in microseconds: bucket `i` counts
//! durations in `(2^(i-1), 2^i]` µs (everything at or below 1 µs lands
//! in bucket 1; the last bucket is a catch-all for everything above
//! `2^22` µs ≈ 4.2 s). 24 buckets cover sub-microsecond kernel
//! iterations through multi-second stalls in 192 bytes with no
//! allocation on the record path, which is why every shard can afford
//! one per replica.
//!
//! The histogram started life inside `coordinator::server`; it moved
//! here when the perf harness made latency a first-class reported
//! artifact — the same type now backs [`ServerMetrics`]
//! (`crate::coordinator::ServerMetrics`), the per-shard router metrics,
//! the `GET /v1/metrics` bucketed JSON and the `BENCH_*.json` sections
//! (see [`crate::observability::bench_report`]).

use std::time::Duration;

use crate::json::JsonValue;
use crate::json_obj;

/// Number of power-of-two buckets (see module docs for the layout).
pub const HIST_BUCKETS: usize = 24;

/// Latency histogram with fixed microsecond buckets (powers of two).
#[derive(Default, Debug, Clone, PartialEq, Eq)]
pub struct LatencyHist {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl LatencyHist {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as u64).min(HIST_BUCKETS as u64 - 1) as usize;
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn mean_us(&self) -> f64 {
        self.sum_us as f64 / self.count.max(1) as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// The raw bucket counts; bucket `i`'s upper bound is `2^i` µs.
    pub fn buckets(&self) -> &[u64; HIST_BUCKETS] {
        &self.buckets
    }

    /// Approximate quantile: the upper bound of the bucket holding the
    /// nearest-rank sample, clamped to the observed maximum so it never
    /// reports a latency larger than anything actually recorded.
    ///
    /// Edge cases are exact, not approximate: an empty histogram
    /// returns 0; with one sample every quantile is that sample; with
    /// all-equal samples every quantile is the common value (clamping
    /// collapses the bucket bound onto the true max).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if c > 0 && seen >= target {
                return (1u64 << i).min(self.max_us);
            }
        }
        self.max_us
    }

    /// Accumulate another histogram into this one (the router's merged
    /// aggregate view; bench sections merging per-shard recordings).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Bucketed JSON for `GET /v1/metrics`: summary quantiles plus one
    /// `{le_us, count}` entry per *non-empty* bucket (empty buckets are
    /// elided so an idle shard serializes to a handful of bytes).
    pub fn to_json(&self) -> JsonValue {
        let buckets: Vec<JsonValue> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| json_obj! { "le_us" => (1u64 << i) as usize, "count" => c as usize })
            .collect();
        json_obj! {
            "count" => self.count as usize,
            "mean_us" => self.mean_us(),
            "p50_us" => self.quantile_us(0.50) as usize,
            "p99_us" => self.quantile_us(0.99) as usize,
            "max_us" => self.max_us as usize,
            "buckets" => buckets,
        }
    }
}

/// Sliding-window latency view: a ring of bucketed sub-windows
/// ("slices") over [`LatencyHist`], rotated on a microsecond time base
/// and merged on read.
///
/// The cumulative [`LatencyHist`] answers "what has this shard done
/// since boot" — useful for reports, useless for control: an hour of
/// healthy traffic drowns the last 200 ms of overload. `WindowedHist`
/// keeps the most recent `window` of samples by spreading them over
/// `slices` sub-histograms; recording and reading both advance the
/// ring, dropping whole slices as they age out, so a quantile read
/// reflects roughly the last `window` (expiry is slice-granular: a
/// sample lives between `window - window/slices` and `window`).
///
/// The core API is pure compute over explicit microsecond timestamps
/// (`record_at` / `merged_at`) — no internal clock — so the SLO
/// hysteresis logic built on it stays deterministic in tests and runs
/// under the Miri CI leg. Callers that live on a wall clock (the
/// batcher worker) convert via an `Instant` epoch they own.
#[derive(Debug, Clone)]
pub struct WindowedHist {
    slices: Vec<LatencyHist>,
    /// Width of one sub-window in µs (>= 1).
    slice_us: u64,
    /// Ring index of the slice receiving samples "now".
    head: usize,
    /// Slice number (`now_us / slice_us`) the head corresponds to.
    head_epoch: u64,
}

impl WindowedHist {
    /// A window of `window_us` split into `slices` sub-histograms.
    /// Both must be nonzero; slice width is rounded up so `slices`
    /// sub-windows always cover at least `window_us`.
    pub fn new(window_us: u64, slices: usize) -> Self {
        assert!(slices >= 1, "WindowedHist needs at least one slice");
        assert!(window_us >= 1, "WindowedHist needs a nonzero window");
        Self {
            slices: vec![LatencyHist::default(); slices],
            slice_us: (window_us / slices as u64).max(1),
            head: 0,
            head_epoch: 0,
        }
    }

    /// The span a merged read covers, in µs (slice width × slice count).
    pub fn window_us(&self) -> u64 {
        self.slice_us * self.slices.len() as u64
    }

    /// Rotate the ring forward to the slice containing `now_us`,
    /// clearing every slice that ages out on the way. Time running
    /// backwards (callers with non-monotonic sampling) is clamped: the
    /// ring never rewinds, late samples land in the current head.
    fn advance_to(&mut self, now_us: u64) {
        let epoch = now_us / self.slice_us;
        if epoch <= self.head_epoch {
            return;
        }
        let steps = epoch - self.head_epoch;
        let n = self.slices.len() as u64;
        if steps >= n {
            // Gap longer than the whole window: nothing survives.
            for s in &mut self.slices {
                *s = LatencyHist::default();
            }
        } else {
            for _ in 0..steps {
                self.head = (self.head + 1) % self.slices.len();
                self.slices[self.head] = LatencyHist::default();
            }
        }
        self.head_epoch = epoch;
    }

    /// Record a sample observed at `now_us` (µs since the caller's
    /// epoch).
    pub fn record_at(&mut self, now_us: u64, d: Duration) {
        self.advance_to(now_us);
        self.slices[self.head].record(d);
    }

    /// Merge the live slices into one histogram covering roughly the
    /// last `window_us()` before `now_us`. Advances the ring first, so
    /// an idle period expires stale samples even with no new records.
    pub fn merged_at(&mut self, now_us: u64) -> LatencyHist {
        self.advance_to(now_us);
        let mut out = LatencyHist::default();
        for s in &self.slices {
            out.merge(s);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHist::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn empty_histogram_reports_zero_not_garbage() {
        let h = LatencyHist::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_us(0.0), 0);
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.quantile_us(1.0), 0);
        assert_eq!(h.max_us(), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn single_sample_every_quantile_is_that_sample() {
        // 100_000 µs sits in the (65536, 131072] bucket whose raw upper
        // bound (131072) exceeds the sample — the max clamp must bring
        // every quantile back to the exact recorded value.
        let mut h = LatencyHist::default();
        h.record(Duration::from_micros(100_000));
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_us(q), 100_000, "q={q}");
        }
        assert_eq!(h.mean_us(), 100_000.0);
    }

    #[test]
    fn two_samples_split_their_quantiles() {
        let mut h = LatencyHist::default();
        h.record(Duration::from_micros(3)); // bucket (2, 4]
        h.record(Duration::from_micros(900)); // bucket (512, 1024]
        // p50 = nearest rank 1 = the small sample's bucket bound (4);
        // p99 = rank 2 = the large sample, clamped to the true max.
        assert_eq!(h.quantile_us(0.5), 4);
        assert_eq!(h.quantile_us(0.99), 900);
        assert_eq!(h.max_us(), 900);
    }

    #[test]
    fn all_equal_samples_collapse_to_the_common_value() {
        let mut h = LatencyHist::default();
        for _ in 0..1000 {
            h.record(Duration::from_micros(777));
        }
        for q in [0.01, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.quantile_us(q), 777, "q={q}");
        }
        assert_eq!(h.mean_us(), 777.0);
    }

    #[test]
    fn zero_duration_lands_in_the_first_real_bucket() {
        let mut h = LatencyHist::default();
        h.record(Duration::from_micros(0));
        assert_eq!(h.count(), 1);
        // bucket index 1 (us clamped to 1), bound 2, clamped to max 0.
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.buckets()[1], 1);
    }

    #[test]
    fn merge_sums_counts_and_takes_max() {
        let mut a = LatencyHist::default();
        let mut b = LatencyHist::default();
        for us in [10u64, 20, 30] {
            a.record(Duration::from_micros(us));
        }
        for us in [1000u64, 2000] {
            b.record(Duration::from_micros(us));
        }
        let mut both = LatencyHist::default();
        for us in [10u64, 20, 30, 1000, 2000] {
            both.record(Duration::from_micros(us));
        }
        a.merge(&b);
        assert_eq!(a, both, "merge must equal recording everything into one histogram");
        assert_eq!(a.count(), 5);
        assert_eq!(a.max_us(), 2000);
    }

    #[test]
    fn json_view_elides_empty_buckets_and_carries_quantiles() {
        let mut h = LatencyHist::default();
        for us in [100u64, 100, 3000] {
            h.record(Duration::from_micros(us));
        }
        let v = h.to_json();
        assert_eq!(v.get("count").and_then(|c| c.as_usize()), Some(3));
        assert_eq!(v.get("max_us").and_then(|c| c.as_usize()), Some(3000));
        assert_eq!(
            v.get("p50_us").and_then(|c| c.as_usize()),
            Some(h.quantile_us(0.5) as usize)
        );
        let buckets = v.get("buckets").and_then(|b| b.as_array()).unwrap();
        assert_eq!(buckets.len(), 2, "only non-empty buckets serialize: {v:?}");
        let counts: u64 = buckets
            .iter()
            .map(|b| b.get("count").and_then(|c| c.as_usize()).unwrap() as u64)
            .sum();
        assert_eq!(counts, 3);
        // every le_us is a power of two
        for b in buckets {
            let le = b.get("le_us").and_then(|c| c.as_usize()).unwrap();
            assert!(le.is_power_of_two(), "{le}");
        }
    }

    // ------------------------------------------------------------ //
    // WindowedHist: sliding-window boundaries (pure compute, runs   //
    // under the Miri CI leg)                                        //
    // ------------------------------------------------------------ //

    #[test]
    fn window_within_one_window_matches_cumulative() {
        let mut w = WindowedHist::new(1_000, 4); // 4 slices x 250 µs
        let mut reference = LatencyHist::default();
        for (t, us) in [(0u64, 10u64), (100, 20), (300, 30), (700, 40)] {
            w.record_at(t, Duration::from_micros(us));
            reference.record(Duration::from_micros(us));
        }
        assert_eq!(w.merged_at(999), reference, "inside the window nothing expires");
        assert_eq!(w.window_us(), 1_000);
    }

    #[test]
    fn samples_expire_slice_by_slice_at_exact_boundaries() {
        let mut w = WindowedHist::new(1_000, 4); // slice width 250 µs
        w.record_at(0, Duration::from_micros(10)); // slice epoch 0
        w.record_at(250, Duration::from_micros(20)); // slice epoch 1
        // At t=999 (epoch 3) both slices are still inside the 4-slice ring.
        assert_eq!(w.merged_at(999).count(), 2);
        // At t=1000 (epoch 4) slice 0 ages out — exactly one boundary step.
        assert_eq!(w.merged_at(1_000).count(), 1);
        assert_eq!(w.merged_at(1_000).max_us(), 20);
        // At t=1250 (epoch 5) slice 1 follows.
        assert_eq!(w.merged_at(1_250).count(), 0);
    }

    #[test]
    fn boundary_sample_lands_in_the_new_slice_not_the_old() {
        let mut w = WindowedHist::new(1_000, 4);
        w.record_at(249, Duration::from_micros(10)); // last µs of slice 0
        w.record_at(250, Duration::from_micros(20)); // first µs of slice 1
        // When slice 0 expires (epoch 4), only the 250 µs sample survives.
        let m = w.merged_at(1_000);
        assert_eq!((m.count(), m.max_us()), (1, 20));
    }

    #[test]
    fn gap_longer_than_the_window_clears_everything() {
        let mut w = WindowedHist::new(1_000, 4);
        for t in [0u64, 300, 600, 900] {
            w.record_at(t, Duration::from_micros(50));
        }
        assert_eq!(w.merged_at(900).count(), 4);
        // An idle stretch of 10 windows expires everything, even with
        // no intervening records (merged_at itself advances the ring).
        assert_eq!(w.merged_at(11_000).count(), 0);
        // …and the ring keeps working afterwards.
        w.record_at(11_100, Duration::from_micros(5));
        assert_eq!(w.merged_at(11_100).count(), 1);
    }

    #[test]
    fn time_running_backwards_is_clamped_not_a_rewind() {
        let mut w = WindowedHist::new(1_000, 4);
        w.record_at(600, Duration::from_micros(10));
        // A non-monotonic caller: the late sample lands in the current
        // head slice instead of resurrecting an expired one.
        w.record_at(100, Duration::from_micros(20));
        let m = w.merged_at(600);
        assert_eq!(m.count(), 2);
        // Both expire together with the head slice.
        assert_eq!(w.merged_at(600 + 1_000).count(), 0);
    }

    #[test]
    fn window_quantiles_track_recent_load_not_history() {
        let mut w = WindowedHist::new(1_000, 4);
        // An old burst of slow samples…
        for i in 0..100u64 {
            w.record_at(i, Duration::from_micros(100_000));
        }
        assert!(w.merged_at(100).quantile_us(0.99) >= 100_000);
        // …followed by a window of fast traffic: the windowed p99
        // recovers once the slow slice ages out, which is exactly what
        // the cumulative histogram cannot do.
        for t in (1_100..2_100u64).step_by(50) {
            w.record_at(t, Duration::from_micros(50));
        }
        assert!(w.merged_at(2_100).quantile_us(0.99) <= 64);
    }

    #[test]
    fn degenerate_windows_are_still_valid() {
        // One slice: a plain histogram that clears on every boundary.
        let mut w = WindowedHist::new(100, 1);
        w.record_at(0, Duration::from_micros(7));
        assert_eq!(w.merged_at(99).count(), 1);
        assert_eq!(w.merged_at(100).count(), 0);
        // Window narrower than the slice count: slice width clamps to
        // 1 µs and the effective window is `slices` µs.
        let w2 = WindowedHist::new(2, 8);
        assert_eq!(w2.window_us(), 8);
    }
}
