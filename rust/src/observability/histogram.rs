//! Fixed-bucket latency histogram — the one latency data structure the
//! whole serving stack records into.
//!
//! Buckets are powers of two in microseconds: bucket `i` counts
//! durations in `(2^(i-1), 2^i]` µs (everything at or below 1 µs lands
//! in bucket 1; the last bucket is a catch-all for everything above
//! `2^22` µs ≈ 4.2 s). 24 buckets cover sub-microsecond kernel
//! iterations through multi-second stalls in 192 bytes with no
//! allocation on the record path, which is why every shard can afford
//! one per replica.
//!
//! The histogram started life inside `coordinator::server`; it moved
//! here when the perf harness made latency a first-class reported
//! artifact — the same type now backs [`ServerMetrics`]
//! (`crate::coordinator::ServerMetrics`), the per-shard router metrics,
//! the `GET /v1/metrics` bucketed JSON and the `BENCH_*.json` sections
//! (see [`crate::observability::bench_report`]).

use std::time::Duration;

use crate::json::JsonValue;
use crate::json_obj;

/// Number of power-of-two buckets (see module docs for the layout).
pub const HIST_BUCKETS: usize = 24;

/// Latency histogram with fixed microsecond buckets (powers of two).
#[derive(Default, Debug, Clone, PartialEq, Eq)]
pub struct LatencyHist {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl LatencyHist {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as u64).min(HIST_BUCKETS as u64 - 1) as usize;
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn mean_us(&self) -> f64 {
        self.sum_us as f64 / self.count.max(1) as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// The raw bucket counts; bucket `i`'s upper bound is `2^i` µs.
    pub fn buckets(&self) -> &[u64; HIST_BUCKETS] {
        &self.buckets
    }

    /// Approximate quantile: the upper bound of the bucket holding the
    /// nearest-rank sample, clamped to the observed maximum so it never
    /// reports a latency larger than anything actually recorded.
    ///
    /// Edge cases are exact, not approximate: an empty histogram
    /// returns 0; with one sample every quantile is that sample; with
    /// all-equal samples every quantile is the common value (clamping
    /// collapses the bucket bound onto the true max).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if c > 0 && seen >= target {
                return (1u64 << i).min(self.max_us);
            }
        }
        self.max_us
    }

    /// Accumulate another histogram into this one (the router's merged
    /// aggregate view; bench sections merging per-shard recordings).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Bucketed JSON for `GET /v1/metrics`: summary quantiles plus one
    /// `{le_us, count}` entry per *non-empty* bucket (empty buckets are
    /// elided so an idle shard serializes to a handful of bytes).
    pub fn to_json(&self) -> JsonValue {
        let buckets: Vec<JsonValue> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| json_obj! { "le_us" => (1u64 << i) as usize, "count" => c as usize })
            .collect();
        json_obj! {
            "count" => self.count as usize,
            "mean_us" => self.mean_us(),
            "p50_us" => self.quantile_us(0.50) as usize,
            "p99_us" => self.quantile_us(0.99) as usize,
            "max_us" => self.max_us as usize,
            "buckets" => buckets,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHist::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn empty_histogram_reports_zero_not_garbage() {
        let h = LatencyHist::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_us(0.0), 0);
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.quantile_us(1.0), 0);
        assert_eq!(h.max_us(), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn single_sample_every_quantile_is_that_sample() {
        // 100_000 µs sits in the (65536, 131072] bucket whose raw upper
        // bound (131072) exceeds the sample — the max clamp must bring
        // every quantile back to the exact recorded value.
        let mut h = LatencyHist::default();
        h.record(Duration::from_micros(100_000));
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_us(q), 100_000, "q={q}");
        }
        assert_eq!(h.mean_us(), 100_000.0);
    }

    #[test]
    fn two_samples_split_their_quantiles() {
        let mut h = LatencyHist::default();
        h.record(Duration::from_micros(3)); // bucket (2, 4]
        h.record(Duration::from_micros(900)); // bucket (512, 1024]
        // p50 = nearest rank 1 = the small sample's bucket bound (4);
        // p99 = rank 2 = the large sample, clamped to the true max.
        assert_eq!(h.quantile_us(0.5), 4);
        assert_eq!(h.quantile_us(0.99), 900);
        assert_eq!(h.max_us(), 900);
    }

    #[test]
    fn all_equal_samples_collapse_to_the_common_value() {
        let mut h = LatencyHist::default();
        for _ in 0..1000 {
            h.record(Duration::from_micros(777));
        }
        for q in [0.01, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.quantile_us(q), 777, "q={q}");
        }
        assert_eq!(h.mean_us(), 777.0);
    }

    #[test]
    fn zero_duration_lands_in_the_first_real_bucket() {
        let mut h = LatencyHist::default();
        h.record(Duration::from_micros(0));
        assert_eq!(h.count(), 1);
        // bucket index 1 (us clamped to 1), bound 2, clamped to max 0.
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.buckets()[1], 1);
    }

    #[test]
    fn merge_sums_counts_and_takes_max() {
        let mut a = LatencyHist::default();
        let mut b = LatencyHist::default();
        for us in [10u64, 20, 30] {
            a.record(Duration::from_micros(us));
        }
        for us in [1000u64, 2000] {
            b.record(Duration::from_micros(us));
        }
        let mut both = LatencyHist::default();
        for us in [10u64, 20, 30, 1000, 2000] {
            both.record(Duration::from_micros(us));
        }
        a.merge(&b);
        assert_eq!(a, both, "merge must equal recording everything into one histogram");
        assert_eq!(a.count(), 5);
        assert_eq!(a.max_us(), 2000);
    }

    #[test]
    fn json_view_elides_empty_buckets_and_carries_quantiles() {
        let mut h = LatencyHist::default();
        for us in [100u64, 100, 3000] {
            h.record(Duration::from_micros(us));
        }
        let v = h.to_json();
        assert_eq!(v.get("count").and_then(|c| c.as_usize()), Some(3));
        assert_eq!(v.get("max_us").and_then(|c| c.as_usize()), Some(3000));
        assert_eq!(
            v.get("p50_us").and_then(|c| c.as_usize()),
            Some(h.quantile_us(0.5) as usize)
        );
        let buckets = v.get("buckets").and_then(|b| b.as_array()).unwrap();
        assert_eq!(buckets.len(), 2, "only non-empty buckets serialize: {v:?}");
        let counts: u64 = buckets
            .iter()
            .map(|b| b.get("count").and_then(|c| c.as_usize()).unwrap() as u64)
            .sum();
        assert_eq!(counts, 3);
        // every le_us is a power of two
        for b in buckets {
            let le = b.get("le_us").and_then(|c| c.as_usize()).unwrap();
            assert!(le.is_power_of_two(), "{le}");
        }
    }
}
