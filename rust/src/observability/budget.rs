//! Falsifiable perf budgets over [`BenchReport`]s.
//!
//! A budget file (`BENCH_BASELINE.json`) pins, per section, the
//! throughput floors and latency ceilings a run must stay inside, with
//! one relative `tolerance` knob per section. CI runs
//! `serve_bench --check-budgets` against the committed baseline and
//! fails the build on any [`Violation`] — perf regressions become red
//! X's instead of silent drift across PRs.
//!
//! Semantics, chosen so a budget can never pass vacuously by accident:
//!
//! * throughput metrics (`img_per_s`, `gmac_per_s`) are **floors**:
//!   `measured >= baseline * (1 - tolerance)`;
//! * latency metrics (`p50_us`, `p99_us`) are **ceilings**:
//!   `measured <= baseline * (1 + tolerance)`;
//! * a baseline metric of `0` means *unconstrained* (mirrors the
//!   report's "0 = not measured" convention);
//! * a budget naming a section the report does not contain is itself a
//!   violation — deleting a bench section cannot green the build.

use std::fmt;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::bench_report::BenchReport;
use crate::json::JsonValue;

/// Budget-file schema identifier; shares the report's major version.
pub const BUDGET_VERSION: &str = "sparq-budget/1";

/// Constraints for one report section. Zero-valued metrics are
/// unconstrained; `tolerance` is the relative slack applied to every
/// constrained metric in this section.
#[derive(Clone, Debug, PartialEq)]
pub struct SectionBudget {
    /// Name of the [`super::bench_report::BenchSection`] this gates.
    pub section: String,
    /// Relative slack in `[0, 1)`: 0.10 = allow 10% regression.
    pub tolerance: f64,
    /// Throughput floor before tolerance, images (requests) per second.
    pub img_per_s: f64,
    /// Throughput floor before tolerance, giga-MACs per second.
    pub gmac_per_s: f64,
    /// Latency ceiling before tolerance, microseconds.
    pub p50_us: f64,
    /// Latency ceiling before tolerance, microseconds.
    pub p99_us: f64,
}

impl SectionBudget {
    fn from_json(v: &JsonValue) -> Result<Self> {
        let section = v
            .get("section")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| anyhow!("budget entry missing string `section`"))?
            .to_string();
        if section.is_empty() {
            bail!("budget section name must be non-empty");
        }
        let num = |key: &str| -> Result<f64> {
            let f = v
                .get(key)
                .and_then(JsonValue::as_f64)
                .ok_or_else(|| anyhow!("budget `{section}`: missing numeric `{key}`"))?;
            if !f.is_finite() || f < 0.0 {
                bail!("budget `{section}`: `{key}` must be finite and >= 0, got {f}");
            }
            Ok(f)
        };
        let tolerance = num("tolerance")?;
        if tolerance >= 1.0 {
            bail!("budget `{section}`: tolerance {tolerance} must be < 1 (it is relative slack)");
        }
        Ok(Self {
            section,
            tolerance,
            img_per_s: num("img_per_s")?,
            gmac_per_s: num("gmac_per_s")?,
            p50_us: num("p50_us")?,
            p99_us: num("p99_us")?,
        })
    }
}

/// Parsed `BENCH_BASELINE.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct BudgetFile {
    pub budgets: Vec<SectionBudget>,
}

impl BudgetFile {
    pub fn parse(text: &str) -> Result<Self> {
        let v = JsonValue::parse(text).context("budget file is not valid JSON")?;
        let version = v
            .get("version")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| anyhow!("budget file missing string `version`"))?;
        if version != BUDGET_VERSION {
            bail!("unsupported budget version `{version}` (want `{BUDGET_VERSION}`)");
        }
        let raw = v
            .get("budgets")
            .and_then(JsonValue::as_array)
            .ok_or_else(|| anyhow!("budget file missing `budgets` array"))?;
        let mut budgets = Vec::with_capacity(raw.len());
        let mut seen = std::collections::BTreeSet::new();
        for b in raw {
            let b = SectionBudget::from_json(b)?;
            if !seen.insert(b.section.clone()) {
                bail!("duplicate budget for section `{}`", b.section);
            }
            budgets.push(b);
        }
        Ok(Self { budgets })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading budget file from {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("invalid budget file {}", path.display()))
    }
}

/// One budget breach, with the numbers needed to act on it from a CI
/// log alone: the section, the metric, the bound after tolerance, and
/// what the run actually measured.
#[derive(Clone, Debug, PartialEq)]
pub struct Violation {
    pub section: String,
    pub metric: String,
    /// The bound after applying tolerance (floor or ceiling per metric).
    pub bound: f64,
    /// The measured value (NaN when the section was missing entirely).
    pub got: f64,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.got.is_nan() {
            write!(f, "section `{}`: required by budget but missing from the report", self.section)
        } else {
            write!(
                f,
                "section `{}`: {} = {:.3} breaches the budget bound {:.3}",
                self.section, self.metric, self.got, self.bound
            )
        }
    }
}

/// Check a report against budgets; an empty result is a pass.
pub fn check(report: &BenchReport, budgets: &BudgetFile) -> Vec<Violation> {
    let mut violations = Vec::new();
    for b in &budgets.budgets {
        let Some(s) = report.section(&b.section) else {
            violations.push(Violation {
                section: b.section.clone(),
                metric: "section".to_string(),
                bound: 0.0,
                got: f64::NAN,
            });
            continue;
        };
        let mut floor = |metric: &str, baseline: f64, got: f64| {
            let bound = baseline * (1.0 - b.tolerance);
            if baseline > 0.0 && got < bound {
                violations.push(Violation {
                    section: b.section.clone(),
                    metric: metric.to_string(),
                    bound,
                    got,
                });
            }
        };
        floor("img_per_s", b.img_per_s, s.img_per_s);
        floor("gmac_per_s", b.gmac_per_s, s.gmac_per_s);
        let mut ceiling = |metric: &str, baseline: f64, got: f64| {
            let bound = baseline * (1.0 + b.tolerance);
            if baseline > 0.0 && got > bound {
                violations.push(Violation {
                    section: b.section.clone(),
                    metric: metric.to_string(),
                    bound,
                    got,
                });
            }
        };
        ceiling("p50_us", b.p50_us, s.p50_us);
        ceiling("p99_us", b.p99_us, s.p99_us);
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::observability::bench_report::{BenchSection, HostFingerprint};

    fn report_with(name: &str, img: f64, p99: f64) -> BenchReport {
        let mut r = BenchReport {
            host: HostFingerprint {
                cores: 4,
                sparq_threads: String::new(),
                git_sha: "test".to_string(),
            },
            sections: Vec::new(),
        };
        let mut s = BenchSection::new(name);
        s.img_per_s = img;
        s.p99_us = p99;
        r.push(s);
        r
    }

    fn budget_text(section: &str, tol: f64, img: f64, p99: f64) -> String {
        format!(
            r#"{{"version":"{BUDGET_VERSION}","budgets":[
                {{"section":"{section}","tolerance":{tol},
                  "img_per_s":{img},"gmac_per_s":0,"p50_us":0,"p99_us":{p99}}}]}}"#
        )
    }

    #[test]
    fn within_tolerance_passes() {
        let budgets = BudgetFile::parse(&budget_text("engine", 0.10, 1000.0, 500.0)).unwrap();
        // 5% slower throughput and 5% higher tail: inside the 10% band.
        let v = check(&report_with("engine", 950.0, 525.0), &budgets);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn throughput_floor_violation_names_section_and_metric() {
        let budgets = BudgetFile::parse(&budget_text("engine", 0.10, 1000.0, 0.0)).unwrap();
        let v = check(&report_with("engine", 800.0, 9999.0), &budgets);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].section, "engine");
        assert_eq!(v[0].metric, "img_per_s");
        assert!((v[0].bound - 900.0).abs() < 1e-9);
        let msg = v[0].to_string();
        assert!(msg.contains("engine") && msg.contains("img_per_s"), "{msg}");
    }

    #[test]
    fn latency_ceiling_violation_fires_upward() {
        let budgets = BudgetFile::parse(&budget_text("engine", 0.10, 0.0, 500.0)).unwrap();
        // Low latency is fine...
        assert!(check(&report_with("engine", 0.0, 100.0), &budgets).is_empty());
        // ...high latency breaches the ceiling.
        let v = check(&report_with("engine", 0.0, 600.0), &budgets);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].metric, "p99_us");
        assert!((v[0].bound - 550.0).abs() < 1e-9);
    }

    #[test]
    fn zero_baseline_metric_is_unconstrained() {
        let budgets = BudgetFile::parse(&budget_text("engine", 0.0, 0.0, 0.0)).unwrap();
        // Report measured nothing at all — still a pass: every metric
        // in this budget is 0 = unconstrained.
        assert!(check(&report_with("engine", 0.0, 0.0), &budgets).is_empty());
    }

    #[test]
    fn missing_section_is_a_violation_not_a_pass() {
        let budgets = BudgetFile::parse(&budget_text("kernel", 0.5, 1.0, 0.0)).unwrap();
        let v = check(&report_with("engine", 1e9, 0.0), &budgets);
        assert_eq!(v.len(), 1);
        assert!(v[0].got.is_nan());
        assert!(v[0].to_string().contains("missing from the report"));
    }

    #[test]
    fn budget_file_validation() {
        // wrong version
        let bad = budget_text("e", 0.1, 1.0, 1.0).replace(BUDGET_VERSION, "nope/9");
        assert!(BudgetFile::parse(&bad).unwrap_err().to_string().contains("version"));
        // tolerance >= 1 rejected
        let bad = budget_text("e", 1.5, 1.0, 1.0);
        assert!(BudgetFile::parse(&bad).unwrap_err().to_string().contains("tolerance"));
        // duplicate sections rejected
        let dup = format!(
            r#"{{"version":"{BUDGET_VERSION}","budgets":[
                {{"section":"e","tolerance":0.1,"img_per_s":0,"gmac_per_s":0,"p50_us":0,"p99_us":0}},
                {{"section":"e","tolerance":0.1,"img_per_s":0,"gmac_per_s":0,"p50_us":0,"p99_us":0}}]}}"#
        );
        assert!(BudgetFile::parse(&dup).unwrap_err().to_string().contains("duplicate"));
        // missing metric key rejected
        let bad = budget_text("e", 0.1, 1.0, 1.0).replace("\"gmac_per_s\":0,", "");
        assert!(BudgetFile::parse(&bad).unwrap_err().to_string().contains("gmac_per_s"));
    }

    #[test]
    fn multiple_violations_all_reported() {
        let text = format!(
            r#"{{"version":"{BUDGET_VERSION}","budgets":[
                {{"section":"a","tolerance":0.0,"img_per_s":100,"gmac_per_s":0,"p50_us":10,"p99_us":10}},
                {{"section":"b","tolerance":0.0,"img_per_s":100,"gmac_per_s":0,"p50_us":0,"p99_us":0}}]}}"#
        );
        let budgets = BudgetFile::parse(&text).unwrap();
        let mut r = report_with("a", 50.0, 20.0); // img floor + p99 ceiling breached
        r.sections[0].p50_us = 20.0; // p50 ceiling breached too
        let v = check(&r, &budgets); // section b missing entirely
        assert_eq!(v.len(), 4, "{v:?}");
    }
}
