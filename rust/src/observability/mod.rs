//! Observability + continuous-perf subsystem (ROADMAP "perf harness").
//!
//! SPARQ's results are speed-vs-accuracy trade-offs, so performance
//! numbers are artifacts here, not log lines. This module owns the
//! pieces that make them first-class and regression-gated:
//!
//! * [`histogram`]    — the fixed-bucket [`LatencyHist`] every layer of
//!   the serving stack records into, now also serialized (bucketed)
//!   over `GET /v1/metrics`, plus the sliding-window [`WindowedHist`]
//!   the SLO degradation ladder reads recent p99 from
//!   (`coordinator::slo`).
//! * [`bench_report`] — the versioned `BENCH_*.json` schema
//!   ([`BenchReport`]) emitted by `benches/hotpath.rs` and
//!   `serve_bench --bench-json`, with strict parse-side validation.
//! * [`budget`]       — falsifiable per-section budgets
//!   (`BENCH_BASELINE.json`); `serve_bench --check-budgets` turns any
//!   [`budget::Violation`] into a non-zero CI exit.
//! * [`client`]       — the blocking HTTP JSON client behind
//!   `examples/ops_top.rs`'s live dashboard (GET) and the rollout
//!   tooling driving `POST /v1/models/{name}/reload`.
//!
//! See README's "Continuous perf harness" section for the operator
//! workflow (recording baselines, overriding budgets per host).

pub mod bench_report;
pub mod budget;
pub mod client;
pub mod histogram;

pub use bench_report::{
    time_iters, BenchReport, BenchSection, HostFingerprint, QueueStats, Timing, SCHEMA_VERSION,
};
pub use budget::{check, BudgetFile, SectionBudget, Violation, BUDGET_VERSION};
pub use client::{http_get, http_get_json, http_post, http_post_json};
pub use histogram::{LatencyHist, WindowedHist, HIST_BUCKETS};
