//! sparq-cli — the L3 coordinator entry point.
//!
//! Subcommands (arg parsing is hand-rolled; clap is not in the image's
//! offline crate set):
//!
//! ```text
//! sparq-cli table1|table2|table3|table4|table5|table6   one paper table
//! sparq-cli all                                         every table + stats
//! sparq-cli stats  [--model TAG]                        toggle statistics (F2)
//! sparq-cli eval   --model TAG [--config NAME]          one accuracy eval
//! sparq-cli calibrate --model TAG                       print scales
//! sparq-cli sim    [--m M --k K --n N --config NAME]    SA/TC cycle sim
//! sparq-cli trim   VALUE...                             Figure 1 walkthrough
//!
//! common flags: --artifacts DIR (default ./artifacts)
//!               --eval-limit N (default 2000) --calib-images N (default 2048)
//! ```

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use sparq::coordinator::scales_for_policy;
use sparq::experiments::{self, ExperimentCtx};
use sparq::hw::area;
use sparq::hw::systolic::SystolicArray;
use sparq::model::{Graph, Weights};
use sparq::quant::baselines::ScalePolicy;
use sparq::quant::bsparq::{shift_for, trim_window};
use sparq::quant::{Mode, SparqConfig};

const USAGE: &str = "sparq-cli <subcommand> [flags]

subcommands:
  table1..table6    regenerate one paper table
  all               every table + toggle stats
  stats             activation bit statistics (exp. F2)
  eval              --model TAG [--config NAME]
  calibrate         --model TAG
  sim               [--m M --k K --n N --config NAME --sparsity-pct P]
  trim              [VALUE...]   Figure 1 walkthrough

common flags:
  --artifacts DIR     (default ./artifacts)
  --eval-limit N      (default 2000)
  --calib-images N    (default 2048)";

/// Minimal `--key value` / positional argument splitter.
struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                let val = argv.get(i + 1).with_context(|| format!("--{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Ok(Self { flags, positional })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }
}

fn ctx_from(args: &Args) -> Result<ExperimentCtx> {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    ExperimentCtx::new(
        &artifacts,
        args.usize_or("eval-limit", 2000)?,
        args.usize_or("calib-images", 2048)?,
    )
}

fn config_arg(args: &Args) -> Result<SparqConfig> {
    let name = args.get("config").unwrap_or("5opt_r");
    SparqConfig::named(name)
        .with_context(|| format!("unknown config `{name}` (see quant::config for names)"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "table1" => print_table(&experiments::table1(&mut ctx_from(&args)?)?),
        "table2" => print_table(&experiments::table2(&mut ctx_from(&args)?)?),
        "table3" => print_table(&experiments::table3(&mut ctx_from(&args)?)?),
        "table4" => print_table(&experiments::table4(&mut ctx_from(&args)?)?),
        "table5" => print_table(&experiments::table5()),
        "table6" => print_table(&experiments::table6(&mut ctx_from(&args)?)?),
        "all" => {
            let mut ctx = ctx_from(&args)?;
            print_table(&experiments::table1(&mut ctx)?);
            print_table(&experiments::table2(&mut ctx)?);
            print_table(&experiments::table3(&mut ctx)?);
            print_table(&experiments::table4(&mut ctx)?);
            print_table(&experiments::table5());
            print_table(&experiments::table6(&mut ctx)?);
            cmd_stats(&args)?;
        }
        "stats" => cmd_stats(&args)?,
        "eval" => cmd_eval(&args)?,
        "calibrate" => cmd_calibrate(&args)?,
        "sim" => cmd_sim(&args)?,
        "trim" => cmd_trim(&args)?,
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => bail!("unknown subcommand `{other}` (try `sparq-cli help`)"),
    }
    Ok(())
}

fn print_table(t: &experiments::Table) {
    println!("{}", t.render());
}

fn cmd_stats(args: &Args) -> Result<()> {
    let mut ctx = ctx_from(args)?;
    let tags: Vec<String> = match args.get("model") {
        Some(t) => vec![t.to_string()],
        None => ctx.manifest.dense_tags().iter().map(|s| s.to_string()).collect(),
    };
    let mut t = experiments::Table::new(
        "F2 — activation bit statistics (non-zero activations, A8W8 grid)",
        &["model", "zero-frac", "b7", "b6", "b5", "b4", "any-MSB", "top2-quiet", "pair-zero"],
    );
    for tag in tags {
        let stats = ctx.calib(&tag)?;
        let scales = scales_for_policy(&stats, ScalePolicy::MinMax, 8);
        let model = ctx.manifest.get(&tag)?.clone();
        let graph = Graph::load(&model.meta_path())?;
        let weights = Weights::load(&model.weights_path())?;
        let ts = experiments::toggle_stats(&graph, &weights, &ctx.eval, &scales, 256, 32)?;
        t.row(vec![
            tag.clone(),
            format!("{:.3}", ts.zero_fraction()),
            format!("{:.3}", ts.bit_prob(7)),
            format!("{:.3}", ts.bit_prob(6)),
            format!("{:.3}", ts.bit_prob(5)),
            format!("{:.3}", ts.bit_prob(4)),
            format!("{:.3}", ts.any_msb_prob()),
            format!("{:.3}", ts.top2_quiet_prob()),
            format!("{:.3}", ts.pair_zero_prob()),
        ]);
    }
    t.row(vec![
        "paper:ResNet-18".into(),
        "-".into(),
        "0.005".into(),
        "0.092".into(),
        "0.338".into(),
        "0.448".into(),
        "0.670".into(),
        "0.900".into(),
        "-".into(),
    ]);
    print_table(&t);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let mut ctx = ctx_from(args)?;
    let tag = args.get("model").context("--model TAG required")?.to_string();
    let fp32 = ctx.fp32_acc(&tag)?;
    println!("{tag}: FP32 top-1 = {fp32:.4}");
    if args.get("config").is_some() {
        let cfg = config_arg(args)?;
        let acc = ctx.quant_acc(&tag, cfg, ScalePolicy::MinMax)?;
        println!("{tag}: {cfg} top-1 = {:.4} (delta {:+.4})", acc, acc - fp32);
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let mut ctx = ctx_from(args)?;
    let tag = args.get("model").context("--model TAG required")?.to_string();
    let stats = ctx.calib(&tag)?;
    println!("layer maxes:  {:?}", stats.maxes);
    println!("layer means:  {:?}", stats.layer_means());
    println!("act scales:   {:?}", stats.scales());
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let m = args.usize_or("m", 64)?;
    let k = args.usize_or("k", 576)?;
    let n = args.usize_or("n", 64)?;
    let cfg = config_arg(args)?;
    let sparsity = args.usize_or("sparsity-pct", 40)? as f64 / 100.0;
    // deterministic synthetic operands
    let a: Vec<u8> = (0..m * k)
        .map(|i| {
            let h = (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 33;
            if (h % 1000) as f64 / 1000.0 < sparsity {
                0
            } else {
                (h % 256) as u8
            }
        })
        .collect();
    let w: Vec<i8> = (0..k * n)
        .map(|i| ((((i as u64).wrapping_mul(0xbf58_476d_1ce4_e5b9) >> 33) % 255) as i32 - 127) as i8)
        .collect();
    let sa = SystolicArray::new(16, 16, cfg);
    let run = sa.gemm(&a, &w, m, k, n);
    println!("systolic 16x16, GEMM {m}x{k}x{n}, config {cfg}:");
    println!("  cycles             {}", run.cycles);
    println!("  8b-8b baseline     {}", sa.baseline_cycles(m, k, n));
    println!(
        "  speedup            {:.2}x",
        sa.baseline_cycles(m, k, n) as f64 / run.cycles as f64
    );
    println!("  utilization        {:.3}", run.utilization);
    let pairs = run.both_zero + run.zero_skip + run.dual_trim;
    println!(
        "  pair cases         zero-skip {:.1}%  dual-trim {:.1}%  both-zero {:.1}%",
        100.0 * run.zero_skip as f64 / pairs as f64,
        100.0 * run.dual_trim as f64 / pairs as f64,
        100.0 * run.both_zero as f64 / pairs as f64,
    );
    let pe = area::sa_sparq(cfg);
    println!(
        "  PE area/MAC        {:.2} (8b-8b = 1.00)",
        pe.per_mac() / area::sa_baseline().per_mac()
    );
    Ok(())
}

/// Figure 1 walkthrough: show the chosen window per placement mode.
fn cmd_trim(args: &Args) -> Result<()> {
    let values: Vec<u8> = if args.positional.is_empty() {
        vec![27, 44, 96, 213]
    } else {
        args.positional
            .iter()
            .map(|s| s.parse::<u8>().context("trim values must be 0..=255"))
            .collect::<Result<_>>()?
    };
    println!("Figure 1 — 8b->4b window placement (window shown in brackets)\n");
    for v in values {
        println!("value {v:3} = {v:08b}");
        for (label, mode) in [("5opt", Mode::Full), ("3opt", Mode::Opt3), ("2opt", Mode::Opt2)] {
            let s = shift_for(v, 4, mode) as usize;
            let trimmed = trim_window(v, 4, mode, false);
            let rounded = trim_window(v, 4, mode, true);
            let bits = format!("{v:08b}");
            let hi = 8 - s - 4;
            let marked = format!("{}[{}]{}", &bits[..hi], &bits[hi..hi + 4], &bits[hi + 4..]);
            println!("  {label}: {marked}  trim -> {trimmed:3}  +R -> {rounded:3}");
        }
        println!();
    }
    Ok(())
}
