//! Accuracy evaluation drivers — the loops behind Tables 1–4 and 6.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::model::{Engine, EngineMode, Graph, Weights};
use crate::quant::{QuantPolicy, SparqConfig};
use crate::runtime::{ArtifactKind, ModelArtifacts, PjrtRuntime, TensorArg};

/// One evaluation outcome.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub tag: String,
    pub config: String,
    pub correct: usize,
    pub total: usize,
    pub seconds: f64,
}

impl EvalReport {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }
}

/// Precomputed reference predictions — the "compute the reference
/// logits once" seam for per-candidate sweeps.
///
/// A sensitivity sweep evaluates dozens of candidate policies against
/// the same A8W8 reference; re-running the reference engine per
/// candidate would dominate the sweep cost. Build this once with
/// [`ReferenceTop1::from_engine`] (or wrap predictions you already
/// have via [`ReferenceTop1::from_preds`]) and hand it to
/// [`evaluate_policy_vs_reference`] / [`evaluate_engine_vs_reference`];
/// `correct` then counts agreement with the stored predictions instead
/// of dataset labels.
#[derive(Clone, Debug)]
pub struct ReferenceTop1 {
    preds: Vec<usize>,
}

impl ReferenceTop1 {
    /// Run `engine` over the first `limit` dataset rows and record its
    /// top-1 predictions.
    pub fn from_engine(engine: &Engine, ds: &Dataset, batch: usize, limit: usize) -> Result<Self> {
        let classes = engine.graph().num_classes;
        let n = ds.n.min(limit);
        let mut preds = Vec::with_capacity(n);
        let mut buf = Vec::new();
        let mut scratch = crate::model::Scratch::default();
        let mut start = 0usize;
        while start < n {
            let take = batch.min(n - start);
            ds.batch_f32_into(start, take, &mut buf);
            let logits = engine.forward_scratch(&buf, take, &mut scratch)?;
            preds.extend(top1(&logits, classes));
            start += take;
        }
        Ok(Self { preds })
    }

    /// Wrap predictions computed elsewhere (e.g. a traced calibration
    /// pass that produced per-layer statistics and logits in one go).
    pub fn from_preds(preds: Vec<usize>) -> Self {
        Self { preds }
    }

    /// Number of rows covered; vs-reference evals score exactly this
    /// many rows.
    pub fn len(&self) -> usize {
        self.preds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.preds.is_empty()
    }

    /// The stored per-row predictions.
    pub fn preds(&self) -> &[usize] {
        &self.preds
    }
}

/// Per-row argmax — shared with the registry's canary shadow-compare
/// ([`super::registry`]), so rollout agreement and eval accuracy are
/// measured by the same machinery.
pub(crate) fn top1(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes.max(1))
        .map(|row| {
            // total_cmp gives NaN a defined order, so a NaN logit (a
            // broken executor, not this crate's math) yields a wrong
            // class for that row instead of a panic mid-eval.
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map_or(0, |(i, _)| i)
        })
        .collect()
}

/// Evaluate through the PJRT request path.
///
/// * `config = None` runs the FP32 float artifact;
/// * `config = Some(cfg)` runs the sparq artifact with the given runtime
///   config and activation scales.
///
/// `limit` caps the number of evaluated images (the paper uses the full
/// validation set; our default eval split is 2K images).
pub fn evaluate_pjrt(
    rt: &PjrtRuntime,
    model: &ModelArtifacts,
    ds: &Dataset,
    batch: usize,
    scales: &[f32],
    config: Option<SparqConfig>,
    limit: usize,
) -> Result<EvalReport> {
    let kind = if config.is_some() { ArtifactKind::Sparq } else { ArtifactKind::Float };
    let exe = rt.load(&model.hlo_path(kind))?;
    let n = ds.n.min(limit);
    let t0 = Instant::now();
    let mut correct = 0usize;
    let mut buf = Vec::new();
    let mut start = 0usize;
    while start < n {
        let take = batch.min(n - start); // final batch padded below
        ds.batch_f32_into(start, batch, &mut buf);
        let img = TensorArg::f32(&[batch, ds.h, ds.w, ds.c], buf.clone());
        let out = match config {
            None => exe.run(&[img])?,
            Some(cfg) => {
                if scales.len() != model.quant_convs {
                    bail!("scale vector length {} != {}", scales.len(), model.quant_convs);
                }
                exe.run(&[
                    img,
                    TensorArg::f32(&[scales.len()], scales.to_vec()),
                    TensorArg::i32(&[5], cfg.to_vec().to_vec()),
                ])?
            }
        };
        let logits = out[0].as_f32();
        let classes = out[0].dims[1];
        for (i, pred) in top1(logits, classes).into_iter().take(take).enumerate() {
            if pred == ds.label(start + i) {
                correct += 1;
            }
        }
        start += take;
    }
    Ok(EvalReport {
        tag: model.tag.clone(),
        config: config.map_or_else(|| "fp32".to_string(), |c| c.to_string()),
        correct,
        total: n,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Evaluate through the native engine (dense or STC datapath). Builds
/// a throwaway engine; callers that already hold one (or a shared
/// `Arc<ModelParams>` replica) should use [`evaluate_with_engine`] so
/// per-config sweeps don't rebuild the prepared weight tables.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_native(
    graph: &Graph,
    weights: &Weights,
    ds: &Dataset,
    batch: usize,
    scales: &[f32],
    cfg: SparqConfig,
    mode: EngineMode,
    limit: usize,
) -> Result<EvalReport> {
    let policy = QuantPolicy::uniform(cfg);
    evaluate_policy_native(graph, weights, ds, batch, scales, policy, mode, limit)
}

/// Evaluate a per-layer [`QuantPolicy`] through the native engine: the
/// policy's per-layer LUT/weight tables are prepared once, then the
/// shared eval loop runs. This is the harness behind per-layer accuracy
/// sweeps (keep-the-edges-at-8-bit vs uniform low-bit, paper-Table-2
/// grids per layer, …).
#[allow(clippy::too_many_arguments)]
pub fn evaluate_policy_native(
    graph: &Graph,
    weights: &Weights,
    ds: &Dataset,
    batch: usize,
    scales: &[f32],
    policy: QuantPolicy,
    mode: EngineMode,
    limit: usize,
) -> Result<EvalReport> {
    let engine = Engine::with_policy(graph, weights, policy, scales, mode)?;
    evaluate_with_engine(&engine, ds, batch, limit)
}

/// Evaluate a per-layer [`QuantPolicy`] against precomputed reference
/// predictions instead of dataset labels — the sweep-facing twin of
/// [`evaluate_policy_native`]. `correct / total` is then top-1
/// *agreement* with the reference over the rows it covers.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_policy_vs_reference(
    graph: &Graph,
    weights: &Weights,
    ds: &Dataset,
    batch: usize,
    scales: &[f32],
    policy: QuantPolicy,
    mode: EngineMode,
    reference: &ReferenceTop1,
) -> Result<EvalReport> {
    let engine = Engine::with_policy(graph, weights, policy, scales, mode)?;
    evaluate_engine_vs_reference(&engine, ds, batch, reference)
}

/// Evaluate an existing engine handle against precomputed reference
/// predictions; covers `reference.len()` rows.
pub fn evaluate_engine_vs_reference(
    engine: &Engine,
    ds: &Dataset,
    batch: usize,
    reference: &ReferenceTop1,
) -> Result<EvalReport> {
    eval_engine_loop(engine, ds, batch, reference.len(), Some(reference.preds()))
}

/// Evaluate an existing engine handle — the parameter-sharing path:
/// the engine may be a cheap replica over shared [`crate::model::ModelParams`],
/// so nothing is cloned or re-prepared here.
pub fn evaluate_with_engine(
    engine: &Engine,
    ds: &Dataset,
    batch: usize,
    limit: usize,
) -> Result<EvalReport> {
    eval_engine_loop(engine, ds, batch, limit, None)
}

/// Shared eval loop: score each row's top-1 either against the dataset
/// label (`reference = None`) or a precomputed reference prediction.
/// Callers guarantee `reference.len() >= ds.n.min(limit)` (both public
/// entry points derive `limit` from the reference itself).
fn eval_engine_loop(
    engine: &Engine,
    ds: &Dataset,
    batch: usize,
    limit: usize,
    reference: Option<&[usize]>,
) -> Result<EvalReport> {
    let graph = engine.graph();
    let mut n = ds.n.min(limit);
    if let Some(r) = reference {
        n = n.min(r.len());
    }
    let t0 = Instant::now();
    let mut correct = 0usize;
    let mut buf = Vec::new();
    // One scratch for the whole eval: steady-state batches reuse the
    // quantize/im2col/pack/accumulate buffers allocation-free.
    let mut scratch = crate::model::Scratch::default();
    let mut start = 0usize;
    while start < n {
        let take = batch.min(n - start);
        ds.batch_f32_into(start, take, &mut buf);
        let logits = engine.forward_scratch(&buf, take, &mut scratch)?;
        for (i, pred) in top1(&logits, graph.num_classes).into_iter().enumerate() {
            let want = match reference {
                Some(r) => r[start + i],
                None => ds.label(start + i),
            };
            if pred == want {
                correct += 1;
            }
        }
        start += take;
    }
    Ok(EvalReport {
        tag: format!("{}[native-{:?}]", graph.arch, engine.mode()),
        // Policy display: uniform engines print their config alone
        // ("5opt/4b+R"); per-layer policies append the override stack
        // ("A4W8+R[first=A8W8,last=A8W8]").
        config: engine.policy().to_string(),
        correct,
        total: n,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_picks_max() {
        let logits = [0.1f32, 0.9, 0.0, 3.0, -1.0, 2.0];
        assert_eq!(top1(&logits, 3), vec![1, 0]);
    }

    #[test]
    fn report_accuracy() {
        let r = EvalReport {
            tag: "t".into(),
            config: "c".into(),
            correct: 3,
            total: 4,
            seconds: 0.0,
        };
        assert!((r.accuracy() - 0.75).abs() < 1e-12);
    }

    /// The PTQ-literature ordering the policy API exists for: keeping
    /// the sensitive first/last quantized layers at 8 bits must beat
    /// uniform 4-bit on the demo model. The A8W8 reference predictions
    /// are computed **once** ([`ReferenceTop1`]) and every candidate is
    /// scored against them — the same seam the sensitivity sweep uses —
    /// so the 8-bit policy scores 100% by construction, edge8's
    /// perturbation sources (only the middle layer) are a strict subset
    /// of uniform 4-bit's (every layer), and the run is deterministic.
    #[test]
    fn edge_8bit_policy_beats_uniform_4bit_on_the_demo_model() {
        use crate::model::demo::{synth_dataset, synth_model};
        use crate::quant::LayerSelector;
        let (graph, weights, scales) = synth_model();
        let ds = synth_dataset(&graph, &weights, &scales, 512);
        let reference = {
            let a8 = Engine::with_policy(
                &graph,
                &weights,
                QuantPolicy::named("a8w8").unwrap(),
                &scales,
                EngineMode::Dense,
            )
            .unwrap();
            let r = ReferenceTop1::from_engine(&a8, &ds, 32, ds.n).unwrap();
            // synth_dataset labels *are* the A8W8 predictions, so the
            // reference must reproduce them exactly.
            let on_labels = evaluate_engine_vs_reference(&a8, &ds, 32, &r).unwrap();
            assert_eq!(on_labels.correct, ds.n, "A8W8 must agree with itself exactly");
            assert_eq!(r.len(), ds.n);
            r
        };
        let run = |policy: QuantPolicy| {
            evaluate_policy_vs_reference(
                &graph,
                &weights,
                &ds,
                32,
                &scales,
                policy,
                EngineMode::Dense,
                &reference,
            )
            .unwrap()
        };
        // Uniform 4-bit (activations AND weights) vs the same base with
        // the first/last quantized convs kept at 8 bits.
        let a4w4 = SparqConfig::named("a4w4").unwrap();
        let uniform4 = run(QuantPolicy::uniform(a4w4));
        let edge8 = run(
            QuantPolicy::builder(a4w4)
                .set(LayerSelector::First, SparqConfig::A8W8)
                .set(LayerSelector::Last, SparqConfig::A8W8)
                .build()
                .unwrap(),
        );
        assert!(
            uniform4.correct < ds.n,
            "uniform 4-bit fully agreeing with A8W8 makes this test vacuous"
        );
        // the acceptance ordering: first/last-at-8-bit beats uniform 4-bit
        assert!(
            edge8.correct > uniform4.correct,
            "edge8 ({}/{}) must beat uniform a4w4 ({}/{})",
            edge8.correct,
            ds.n,
            uniform4.correct,
            ds.n
        );
        // report strings carry the resolved policy for humans
        assert_eq!(edge8.config, "A4W4+R[first=A8W8,last=A8W8]");
        assert_eq!(uniform4.config, "A4W4+R");
    }
}
