//! Accuracy evaluation drivers — the loops behind Tables 1–4 and 6.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::model::{Engine, EngineMode, Graph, Weights};
use crate::quant::{QuantPolicy, SparqConfig};
use crate::runtime::{ArtifactKind, ModelArtifacts, PjrtRuntime, TensorArg};

/// One evaluation outcome.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub tag: String,
    pub config: String,
    pub correct: usize,
    pub total: usize,
    pub seconds: f64,
}

impl EvalReport {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }
}

/// Per-row argmax — shared with the registry's canary shadow-compare
/// ([`super::registry`]), so rollout agreement and eval accuracy are
/// measured by the same machinery.
pub(crate) fn top1(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes.max(1))
        .map(|row| {
            // total_cmp gives NaN a defined order, so a NaN logit (a
            // broken executor, not this crate's math) yields a wrong
            // class for that row instead of a panic mid-eval.
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map_or(0, |(i, _)| i)
        })
        .collect()
}

/// Evaluate through the PJRT request path.
///
/// * `config = None` runs the FP32 float artifact;
/// * `config = Some(cfg)` runs the sparq artifact with the given runtime
///   config and activation scales.
///
/// `limit` caps the number of evaluated images (the paper uses the full
/// validation set; our default eval split is 2K images).
pub fn evaluate_pjrt(
    rt: &PjrtRuntime,
    model: &ModelArtifacts,
    ds: &Dataset,
    batch: usize,
    scales: &[f32],
    config: Option<SparqConfig>,
    limit: usize,
) -> Result<EvalReport> {
    let kind = if config.is_some() { ArtifactKind::Sparq } else { ArtifactKind::Float };
    let exe = rt.load(&model.hlo_path(kind))?;
    let n = ds.n.min(limit);
    let t0 = Instant::now();
    let mut correct = 0usize;
    let mut buf = Vec::new();
    let mut start = 0usize;
    while start < n {
        let take = batch.min(n - start); // final batch padded below
        ds.batch_f32_into(start, batch, &mut buf);
        let img = TensorArg::f32(&[batch, ds.h, ds.w, ds.c], buf.clone());
        let out = match config {
            None => exe.run(&[img])?,
            Some(cfg) => {
                if scales.len() != model.quant_convs {
                    bail!("scale vector length {} != {}", scales.len(), model.quant_convs);
                }
                exe.run(&[
                    img,
                    TensorArg::f32(&[scales.len()], scales.to_vec()),
                    TensorArg::i32(&[5], cfg.to_vec().to_vec()),
                ])?
            }
        };
        let logits = out[0].as_f32();
        let classes = out[0].dims[1];
        for (i, pred) in top1(logits, classes).into_iter().take(take).enumerate() {
            if pred == ds.label(start + i) {
                correct += 1;
            }
        }
        start += take;
    }
    Ok(EvalReport {
        tag: model.tag.clone(),
        config: config.map_or_else(|| "fp32".to_string(), |c| c.to_string()),
        correct,
        total: n,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Evaluate through the native engine (dense or STC datapath). Builds
/// a throwaway engine; callers that already hold one (or a shared
/// `Arc<ModelParams>` replica) should use [`evaluate_with_engine`] so
/// per-config sweeps don't rebuild the prepared weight tables.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_native(
    graph: &Graph,
    weights: &Weights,
    ds: &Dataset,
    batch: usize,
    scales: &[f32],
    cfg: SparqConfig,
    mode: EngineMode,
    limit: usize,
) -> Result<EvalReport> {
    let policy = QuantPolicy::uniform(cfg);
    evaluate_policy_native(graph, weights, ds, batch, scales, policy, mode, limit)
}

/// Evaluate a per-layer [`QuantPolicy`] through the native engine: the
/// policy's per-layer LUT/weight tables are prepared once, then the
/// shared eval loop runs. This is the harness behind per-layer accuracy
/// sweeps (keep-the-edges-at-8-bit vs uniform low-bit, paper-Table-2
/// grids per layer, …).
#[allow(clippy::too_many_arguments)]
pub fn evaluate_policy_native(
    graph: &Graph,
    weights: &Weights,
    ds: &Dataset,
    batch: usize,
    scales: &[f32],
    policy: QuantPolicy,
    mode: EngineMode,
    limit: usize,
) -> Result<EvalReport> {
    let engine = Engine::with_policy(graph, weights, policy, scales, mode)?;
    evaluate_with_engine(&engine, ds, batch, limit)
}

/// Evaluate an existing engine handle — the parameter-sharing path:
/// the engine may be a cheap replica over shared [`crate::model::ModelParams`],
/// so nothing is cloned or re-prepared here.
pub fn evaluate_with_engine(
    engine: &Engine,
    ds: &Dataset,
    batch: usize,
    limit: usize,
) -> Result<EvalReport> {
    let graph = engine.graph();
    let n = ds.n.min(limit);
    let t0 = Instant::now();
    let mut correct = 0usize;
    let mut buf = Vec::new();
    // One scratch for the whole eval: steady-state batches reuse the
    // quantize/im2col/pack/accumulate buffers allocation-free.
    let mut scratch = crate::model::Scratch::default();
    let mut start = 0usize;
    while start < n {
        let take = batch.min(n - start);
        ds.batch_f32_into(start, take, &mut buf);
        let logits = engine.forward_scratch(&buf, take, &mut scratch)?;
        for (i, pred) in top1(&logits, graph.num_classes).into_iter().enumerate() {
            if pred == ds.label(start + i) {
                correct += 1;
            }
        }
        start += take;
    }
    Ok(EvalReport {
        tag: format!("{}[native-{:?}]", graph.arch, engine.mode()),
        // Policy display: uniform engines print their config alone
        // ("5opt/4b+R"); per-layer policies append the override stack
        // ("A4W8+R[first=A8W8,last=A8W8]").
        config: engine.policy().to_string(),
        correct,
        total: n,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_picks_max() {
        let logits = [0.1f32, 0.9, 0.0, 3.0, -1.0, 2.0];
        assert_eq!(top1(&logits, 3), vec![1, 0]);
    }

    #[test]
    fn report_accuracy() {
        let r = EvalReport {
            tag: "t".into(),
            config: "c".into(),
            correct: 3,
            total: 4,
            seconds: 0.0,
        };
        assert!((r.accuracy() - 0.75).abs() < 1e-12);
    }

    /// The PTQ-literature ordering the policy API exists for: keeping
    /// the sensitive first/last quantized layers at 8 bits must beat
    /// uniform 4-bit on the demo model. Labels come from the A8W8
    /// reference itself ([`crate::model::demo::synth_dataset`]), so the
    /// 8-bit policy scores 100% by construction, edge8's perturbation
    /// sources (only the middle layer) are a strict subset of uniform
    /// 4-bit's (every layer), and the run is fully deterministic.
    #[test]
    fn edge_8bit_policy_beats_uniform_4bit_on_the_demo_model() {
        use crate::model::demo::{synth_dataset, synth_model};
        use crate::quant::LayerSelector;
        let (graph, weights, scales) = synth_model();
        let ds = synth_dataset(&graph, &weights, &scales, 512);
        let run = |policy: QuantPolicy| {
            evaluate_policy_native(
                &graph,
                &weights,
                &ds,
                32,
                &scales,
                policy,
                EngineMode::Dense,
                ds.n,
            )
            .unwrap()
        };
        let a8 = run(QuantPolicy::named("a8w8").unwrap());
        assert_eq!(a8.correct, ds.n, "A8W8 must match its own labels exactly");
        // Uniform 4-bit (activations AND weights) vs the same base with
        // the first/last quantized convs kept at 8 bits.
        let a4w4 = SparqConfig::named("a4w4").unwrap();
        let uniform4 = run(QuantPolicy::uniform(a4w4));
        let edge8 = run(
            QuantPolicy::builder(a4w4)
                .set(LayerSelector::First, SparqConfig::A8W8)
                .set(LayerSelector::Last, SparqConfig::A8W8)
                .build()
                .unwrap(),
        );
        assert!(
            uniform4.correct < ds.n,
            "uniform 4-bit fully agreeing with A8W8 makes this test vacuous"
        );
        // the acceptance ordering: first/last-at-8-bit beats uniform 4-bit
        assert!(
            edge8.correct > uniform4.correct,
            "edge8 ({}/{}) must beat uniform a4w4 ({}/{})",
            edge8.correct,
            ds.n,
            uniform4.correct,
            ds.n
        );
        // report strings carry the resolved policy for humans
        assert_eq!(edge8.config, "A4W4+R[first=A8W8,last=A8W8]");
        assert_eq!(uniform4.config, "A4W4+R");
    }
}
