//! Accuracy evaluation drivers — the loops behind Tables 1–4 and 6.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::model::{Engine, EngineMode, Graph, Weights};
use crate::quant::SparqConfig;
use crate::runtime::{ArtifactKind, ModelArtifacts, PjrtRuntime, TensorArg};

/// One evaluation outcome.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub tag: String,
    pub config: String,
    pub correct: usize,
    pub total: usize,
    pub seconds: f64,
}

impl EvalReport {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }
}

fn top1(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

/// Evaluate through the PJRT request path.
///
/// * `config = None` runs the FP32 float artifact;
/// * `config = Some(cfg)` runs the sparq artifact with the given runtime
///   config and activation scales.
///
/// `limit` caps the number of evaluated images (the paper uses the full
/// validation set; our default eval split is 2K images).
pub fn evaluate_pjrt(
    rt: &PjrtRuntime,
    model: &ModelArtifacts,
    ds: &Dataset,
    batch: usize,
    scales: &[f32],
    config: Option<SparqConfig>,
    limit: usize,
) -> Result<EvalReport> {
    let kind = if config.is_some() { ArtifactKind::Sparq } else { ArtifactKind::Float };
    let exe = rt.load(&model.hlo_path(kind))?;
    let n = ds.n.min(limit);
    let t0 = Instant::now();
    let mut correct = 0usize;
    let mut buf = Vec::new();
    let mut start = 0usize;
    while start < n {
        let take = batch.min(n - start); // final batch padded below
        ds.batch_f32_into(start, batch, &mut buf);
        let img = TensorArg::f32(&[batch, ds.h, ds.w, ds.c], buf.clone());
        let out = match config {
            None => exe.run(&[img])?,
            Some(cfg) => {
                if scales.len() != model.quant_convs {
                    bail!("scale vector length {} != {}", scales.len(), model.quant_convs);
                }
                exe.run(&[
                    img,
                    TensorArg::f32(&[scales.len()], scales.to_vec()),
                    TensorArg::i32(&[5], cfg.to_vec().to_vec()),
                ])?
            }
        };
        let logits = out[0].as_f32();
        let classes = out[0].dims[1];
        for (i, pred) in top1(logits, classes).into_iter().take(take).enumerate() {
            if pred == ds.label(start + i) {
                correct += 1;
            }
        }
        start += take;
    }
    Ok(EvalReport {
        tag: model.tag.clone(),
        config: config.map_or_else(|| "fp32".to_string(), |c| c.to_string()),
        correct,
        total: n,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Evaluate through the native engine (dense or STC datapath). Builds
/// a throwaway engine; callers that already hold one (or a shared
/// `Arc<ModelParams>` replica) should use [`evaluate_with_engine`] so
/// per-config sweeps don't rebuild the prepared weight tables.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_native(
    graph: &Graph,
    weights: &Weights,
    ds: &Dataset,
    batch: usize,
    scales: &[f32],
    cfg: SparqConfig,
    mode: EngineMode,
    limit: usize,
) -> Result<EvalReport> {
    let engine = Engine::new(graph, weights, cfg, scales, mode)?;
    evaluate_with_engine(&engine, ds, batch, limit)
}

/// Evaluate an existing engine handle — the parameter-sharing path:
/// the engine may be a cheap replica over shared [`crate::model::ModelParams`],
/// so nothing is cloned or re-prepared here.
pub fn evaluate_with_engine(
    engine: &Engine,
    ds: &Dataset,
    batch: usize,
    limit: usize,
) -> Result<EvalReport> {
    let graph = engine.graph();
    let n = ds.n.min(limit);
    let t0 = Instant::now();
    let mut correct = 0usize;
    let mut buf = Vec::new();
    // One scratch for the whole eval: steady-state batches reuse the
    // quantize/im2col/pack/accumulate buffers allocation-free.
    let mut scratch = crate::model::Scratch::default();
    let mut start = 0usize;
    while start < n {
        let take = batch.min(n - start);
        ds.batch_f32_into(start, take, &mut buf);
        let logits = engine.forward_scratch(&buf, take, &mut scratch)?;
        for (i, pred) in top1(&logits, graph.num_classes).into_iter().enumerate() {
            if pred == ds.label(start + i) {
                correct += 1;
            }
        }
        start += take;
    }
    Ok(EvalReport {
        tag: format!("{}[native-{:?}]", graph.arch, engine.mode()),
        config: engine.cfg().to_string(),
        correct,
        total: n,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_picks_max() {
        let logits = [0.1f32, 0.9, 0.0, 3.0, -1.0, 2.0];
        assert_eq!(top1(&logits, 3), vec![1, 0]);
    }

    #[test]
    fn report_accuracy() {
        let r = EvalReport {
            tag: "t".into(),
            config: "c".into(),
            correct: 3,
            total: 4,
            seconds: 0.0,
        };
        assert!((r.accuracy() - 0.75).abs() < 1e-12);
    }
}
