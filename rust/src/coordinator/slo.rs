//! SLO-driven degradation ladder — the policy layer behind
//! load-adaptive precision serving.
//!
//! SPARQ variants of one model share a single weights allocation and
//! differ only in bits-per-activation, with the accuracy cost of each
//! step down quantified (PAPER.md Table 2). That gives this stack a
//! knob no ordinary inference server has: under overload it can
//! *degrade quality instead of shedding traffic*. An [`SloPolicy`]
//! makes the knob first-class:
//!
//! * a per-model **ladder** of variant names, rung 0 the default
//!   (full-quality) variant, each later rung a cheaper operating point
//!   — the router validates at install time that every rung exists and
//!   that `footprint_bits` never increases along the ladder;
//! * **trigger thresholds** on the serving rung's live pressure: total
//!   queue depth across its shards, and windowed p99 latency (the
//!   sliding [`WindowedHist`] view — the cumulative histogram is too
//!   stale for control);
//! * **hysteresis** (a `recover_margin` band: recovery requires
//!   pressure to fall *below* `margin × threshold`, not merely below
//!   the threshold) plus a **minimum dwell** between transitions, so a
//!   noisy signal can't flap the ladder.
//!
//! The decision state machine ([`LadderState`]) is pure compute over
//! explicit microsecond timestamps — no internal clock, no locks, no
//! I/O — so the hysteresis unit tests below run under the Miri CI leg
//! byte-for-byte as they run natively. The router owns the wall clock
//! (an `Instant` epoch per installed policy) and the pressure sampling;
//! see `InferenceRouter::set_slo_policy` and the dispatch seam in
//! `coordinator/router.rs`.
//!
//! Like [`QuantPolicy`](crate::quant::QuantPolicy), an `SloPolicy` is
//! validated on construction and JSON-round-trippable ([`to_json`] /
//! [`from_json`]) — `POST /v1/models/{name}/slo` carries exactly this
//! encoding.
//!
//! [`to_json`]: SloPolicy::to_json
//! [`from_json`]: SloPolicy::from_json
//! [`WindowedHist`]: crate::observability::WindowedHist

use anyhow::{bail, Context, Result};

use crate::json::JsonValue;
use crate::json_obj;

/// A validated per-model degradation ladder plus its trigger and
/// recovery parameters. Construct with [`SloPolicy::new`] or parse the
/// wire encoding with [`SloPolicy::from_json`]; both validate.
#[derive(Clone, Debug, PartialEq)]
pub struct SloPolicy {
    ladder: Vec<String>,
    max_queue_depth: u64,
    max_p99_us: u64,
    dwell_us: u64,
    recover_margin: f64,
}

/// One pressure observation for the serving rung: live queue depth
/// summed across its shards, and the merged sliding-window p99.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PressureSample {
    pub queue_depth: u64,
    pub p99_us: u64,
}

impl SloPolicy {
    /// Build a validated policy.
    ///
    /// * `ladder` — ≥ 2 distinct, non-empty variant names (no `@`);
    ///   rung 0 must be the model's default variant (the router checks
    ///   that, plus footprint ordering, against its registry at install
    ///   time — name-level validation happens here).
    /// * `max_queue_depth` / `max_p99_us` — trigger thresholds; `0`
    ///   disables that trigger, but at least one must be enabled.
    /// * `dwell_us` — minimum time between ladder transitions (the
    ///   very first transition after install is exempt, so a policy
    ///   installed *during* an overload acts immediately).
    /// * `recover_margin` — hysteresis band in `(0, 1]`: stepping back
    ///   up requires every enabled pressure signal at or below
    ///   `margin × threshold`.
    pub fn new(
        ladder: Vec<String>,
        max_queue_depth: u64,
        max_p99_us: u64,
        dwell_us: u64,
        recover_margin: f64,
    ) -> Result<Self> {
        if ladder.len() < 2 {
            bail!(
                "SLO ladder needs at least 2 rungs (default + one cheaper variant), got {:?}",
                ladder
            );
        }
        for (i, rung) in ladder.iter().enumerate() {
            if rung.is_empty() || rung.contains('@') {
                bail!("SLO ladder rung {i} is not a valid variant name: `{rung}`");
            }
            if ladder[..i].contains(rung) {
                bail!("SLO ladder repeats variant `{rung}` (rung {i})");
            }
        }
        if max_queue_depth == 0 && max_p99_us == 0 {
            bail!("SLO policy disables both triggers (max_queue_depth and max_p99_us are 0)");
        }
        if !(recover_margin > 0.0 && recover_margin <= 1.0) {
            bail!("recover_margin must be in (0, 1], got {recover_margin}");
        }
        Ok(Self { ladder, max_queue_depth, max_p99_us, dwell_us, recover_margin })
    }

    /// The ladder, rung 0 first (the default variant).
    pub fn ladder(&self) -> &[String] {
        &self.ladder
    }

    pub fn max_queue_depth(&self) -> u64 {
        self.max_queue_depth
    }

    pub fn max_p99_us(&self) -> u64 {
        self.max_p99_us
    }

    pub fn dwell_us(&self) -> u64 {
        self.dwell_us
    }

    pub fn recover_margin(&self) -> f64 {
        self.recover_margin
    }

    /// Does this sample breach an enabled trigger threshold?
    pub fn breaches(&self, s: &PressureSample) -> bool {
        (self.max_queue_depth > 0 && s.queue_depth > self.max_queue_depth)
            || (self.max_p99_us > 0 && s.p99_us > self.max_p99_us)
    }

    /// Is this sample inside the recovery band — every enabled signal
    /// at or below `recover_margin × threshold`? Between [`breaches`]
    /// and `clears` lies the hysteresis band where the rung holds.
    ///
    /// [`breaches`]: SloPolicy::breaches
    pub fn clears(&self, s: &PressureSample) -> bool {
        let depth_ok = self.max_queue_depth == 0
            || (s.queue_depth as f64) <= self.recover_margin * self.max_queue_depth as f64;
        let p99_ok = self.max_p99_us == 0
            || (s.p99_us as f64) <= self.recover_margin * self.max_p99_us as f64;
        depth_ok && p99_ok
    }

    /// The wire encoding: `{ladder, max_queue_depth, max_p99_us,
    /// dwell_us, recover_margin}`.
    pub fn to_json(&self) -> JsonValue {
        let ladder: Vec<JsonValue> =
            self.ladder.iter().map(|r| JsonValue::from(r.as_str())).collect();
        json_obj! {
            "ladder" => ladder,
            "max_queue_depth" => self.max_queue_depth as usize,
            "max_p99_us" => self.max_p99_us as usize,
            "dwell_us" => self.dwell_us as usize,
            "recover_margin" => self.recover_margin,
        }
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse and validate the wire encoding.
    pub fn from_json(text: &str) -> Result<Self> {
        Self::from_json_value(&JsonValue::parse(text)?)
    }

    pub fn from_json_value(v: &JsonValue) -> Result<Self> {
        let ladder_json = v
            .get("ladder")
            .and_then(JsonValue::as_array)
            .context("SLO policy missing `ladder` array")?;
        let mut ladder = Vec::with_capacity(ladder_json.len());
        for (i, rung) in ladder_json.iter().enumerate() {
            let name = rung
                .as_str()
                .with_context(|| format!("SLO ladder rung {i} must be a variant name string"))?;
            ladder.push(name.to_string());
        }
        let u64_field = |key: &str| -> Result<u64> {
            match v.get(key) {
                None => Ok(0),
                Some(x) => {
                    let f = x
                        .as_f64()
                        .with_context(|| format!("SLO field `{key}` must be a number"))?;
                    if !(f >= 0.0 && f.fract() == 0.0) {
                        bail!("SLO field `{key}` must be a non-negative integer, got {f}");
                    }
                    Ok(f as u64)
                }
            }
        };
        let max_queue_depth = u64_field("max_queue_depth")?;
        let max_p99_us = u64_field("max_p99_us")?;
        let dwell_us = u64_field("dwell_us")?;
        let recover_margin = match v.get("recover_margin") {
            None => 0.5,
            Some(x) => x.as_f64().context("SLO field `recover_margin` must be a number")?,
        };
        Self::new(ladder, max_queue_depth, max_p99_us, dwell_us, recover_margin)
    }
}

/// The per-model decision state machine: current rung, transition
/// bookkeeping, and time-in-degraded-mode accounting. Pure compute over
/// caller-supplied microsecond timestamps (monotone-clamped), so it is
/// deterministic in tests and Miri-interpretable.
#[derive(Clone, Debug, Default)]
pub struct LadderState {
    rung: usize,
    /// Timestamp of the last rung change; dwell gates on this.
    last_change_us: u64,
    /// Last timestamp observed, for degraded-time accumulation.
    last_seen_us: u64,
    /// True once any transition has happened — the first transition
    /// after install is exempt from dwell (see [`SloPolicy::new`]).
    transitioned: bool,
    time_degraded_us: u64,
    steps_down: u64,
    steps_up: u64,
}

impl LadderState {
    pub fn new() -> Self {
        Self::default()
    }

    /// The current ladder rung (0 = default variant).
    pub fn rung(&self) -> usize {
        self.rung
    }

    pub fn degraded(&self) -> bool {
        self.rung > 0
    }

    /// Transitions toward cheaper rungs / back toward the default.
    pub fn steps_down(&self) -> u64 {
        self.steps_down
    }

    pub fn steps_up(&self) -> u64 {
        self.steps_up
    }

    /// Cumulative µs spent off the default rung, as of the last
    /// [`touch`]/[`step`].
    ///
    /// [`touch`]: LadderState::touch
    /// [`step`]: LadderState::step
    pub fn time_degraded_us(&self) -> u64 {
        self.time_degraded_us
    }

    /// Advance the degraded-time clock to `now_us` without making a
    /// decision (metrics reads). Time running backwards is clamped.
    pub fn touch(&mut self, now_us: u64) {
        let now = now_us.max(self.last_seen_us);
        if self.rung > 0 {
            self.time_degraded_us += now - self.last_seen_us;
        }
        self.last_seen_us = now;
    }

    /// One control decision at `now_us` against `sample`; returns the
    /// rung to serve. Breaching samples step one rung down the ladder
    /// (cheaper), samples inside the recovery band step one rung back
    /// up, anything in the hysteresis band between holds — and no
    /// transition happens within `dwell_us` of the previous one (the
    /// first after install excepted).
    pub fn step(&mut self, policy: &SloPolicy, now_us: u64, sample: PressureSample) -> usize {
        self.touch(now_us);
        let now = self.last_seen_us;
        // Defensive clamp: a swapped-in shorter ladder must never index
        // out of range (set_slo_policy resets state, so this is belt
        // and braces).
        self.rung = self.rung.min(policy.ladder().len() - 1);
        let dwell_over =
            !self.transitioned || now.saturating_sub(self.last_change_us) >= policy.dwell_us();
        if !dwell_over {
            return self.rung;
        }
        if policy.breaches(&sample) && self.rung + 1 < policy.ladder().len() {
            self.rung += 1;
            self.steps_down += 1;
            self.last_change_us = now;
            self.transitioned = true;
        } else if policy.clears(&sample) && self.rung > 0 {
            self.rung -= 1;
            self.steps_up += 1;
            self.last_change_us = now;
            self.transitioned = true;
        }
        self.rung
    }
}

/// Plain-value snapshot of a model's ladder position for metrics and
/// the ops view; serialized under the `"slo"` key on `/v1/metrics`.
#[derive(Clone, Debug)]
pub struct SloStatus {
    pub ladder: Vec<String>,
    /// Current rung index into `ladder`.
    pub rung: usize,
    /// The variant name the ladder currently routes default traffic to.
    pub serving: String,
    pub degraded: bool,
    pub time_degraded_us: u64,
    pub transitions_down: u64,
    pub transitions_up: u64,
}

impl SloStatus {
    pub fn to_json(&self) -> JsonValue {
        let ladder: Vec<JsonValue> =
            self.ladder.iter().map(|r| JsonValue::from(r.as_str())).collect();
        json_obj! {
            "ladder" => ladder,
            "rung" => self.rung,
            "serving" => self.serving.clone(),
            "degraded" => self.degraded,
            "time_degraded_us" => self.time_degraded_us as usize,
            "transitions_down" => self.transitions_down as usize,
            "transitions_up" => self.transitions_up as usize,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder3() -> SloPolicy {
        // depth trigger 4, p99 trigger 1000 µs, dwell 100 µs, margin 0.5
        SloPolicy::new(
            vec!["full".into(), "mid".into(), "cheap".into()],
            4,
            1_000,
            100,
            0.5,
        )
        .unwrap()
    }

    fn calm() -> PressureSample {
        PressureSample { queue_depth: 0, p99_us: 10 }
    }

    fn overload() -> PressureSample {
        PressureSample { queue_depth: 50, p99_us: 20_000 }
    }

    #[test]
    fn json_roundtrip_preserves_policy() {
        let p = ladder3();
        let back = SloPolicy::from_json(&p.to_json_string()).unwrap();
        assert_eq!(back, p, "{}", p.to_json_string());
        // defaults: omitted thresholds are disabled-0, margin 0.5
        let short = r#"{"ladder": ["a", "b"], "max_queue_depth": 3}"#;
        let p = SloPolicy::from_json(short).unwrap();
        assert_eq!(p.max_p99_us(), 0);
        assert_eq!(p.recover_margin(), 0.5);
    }

    #[test]
    fn json_rejects_garbage() {
        for (body, why) in [
            ("{}", "missing ladder"),
            (r#"{"ladder": ["only"], "max_queue_depth": 1}"#, "single rung"),
            (r#"{"ladder": ["a", "a"], "max_queue_depth": 1}"#, "duplicate rung"),
            (r#"{"ladder": ["a", ""], "max_queue_depth": 1}"#, "empty rung"),
            (r#"{"ladder": ["a", "b@c"], "max_queue_depth": 1}"#, "@ in rung"),
            (r#"{"ladder": ["a", 3], "max_queue_depth": 1}"#, "non-string rung"),
            (r#"{"ladder": ["a", "b"]}"#, "no trigger enabled"),
            (
                r#"{"ladder": ["a", "b"], "max_queue_depth": 1, "recover_margin": 0.0}"#,
                "margin 0",
            ),
            (
                r#"{"ladder": ["a", "b"], "max_queue_depth": 1, "recover_margin": 1.5}"#,
                "margin > 1",
            ),
            (
                r#"{"ladder": ["a", "b"], "max_queue_depth": -2}"#,
                "negative threshold",
            ),
        ] {
            assert!(SloPolicy::from_json(body).is_err(), "{why} must not parse: {body}");
        }
    }

    #[test]
    fn breach_and_clear_triggers_respect_disabled_thresholds() {
        // p99-only policy: queue depth can be anything.
        let p = SloPolicy::new(vec!["a".into(), "b".into()], 0, 1_000, 0, 0.5).unwrap();
        assert!(!p.breaches(&PressureSample { queue_depth: 10_000, p99_us: 500 }));
        assert!(p.breaches(&PressureSample { queue_depth: 0, p99_us: 1_001 }));
        assert!(p.clears(&PressureSample { queue_depth: 10_000, p99_us: 500 }));
        assert!(!p.clears(&PressureSample { queue_depth: 0, p99_us: 501 }));
    }

    #[test]
    fn first_breach_after_install_degrades_immediately() {
        let p = ladder3();
        let mut s = LadderState::new();
        // t=0 is well inside the dwell window, but the first transition
        // is exempt: a policy installed mid-overload acts now.
        assert_eq!(s.step(&p, 0, overload()), 1);
        assert_eq!(s.steps_down(), 1);
        assert!(s.degraded());
    }

    #[test]
    fn hysteresis_band_holds_the_rung_both_ways() {
        let p = ladder3();
        let mut s = LadderState::new();
        assert_eq!(s.step(&p, 0, overload()), 1);
        // depth 3 is under the trigger (4) but above margin*trigger (2):
        // neither a breach nor a clear — the rung holds, dwell elapsed
        // or not.
        let band = PressureSample { queue_depth: 3, p99_us: 10 };
        assert!(!p.breaches(&band) && !p.clears(&band));
        for t in [50u64, 150, 1_000, 10_000] {
            assert_eq!(s.step(&p, t, band), 1, "t={t}");
        }
        assert_eq!((s.steps_down(), s.steps_up()), (1, 0));
    }

    #[test]
    fn recovery_requires_clear_sample_and_dwell() {
        let p = ladder3(); // dwell 100 µs
        let mut s = LadderState::new();
        assert_eq!(s.step(&p, 0, overload()), 1);
        // Clear sample but inside dwell: hold.
        assert_eq!(s.step(&p, 50, calm()), 1);
        // Dwell expired: step back up.
        assert_eq!(s.step(&p, 120, calm()), 0);
        assert_eq!((s.steps_down(), s.steps_up()), (1, 1));
        assert!(!s.degraded());
        // Degraded time covers exactly the stretch spent off rung 0.
        assert_eq!(s.time_degraded_us(), 120);
    }

    #[test]
    fn dwell_bounds_flapping_under_an_alternating_signal() {
        let p = ladder3(); // dwell 100 µs
        let mut s = LadderState::new();
        // A pathological signal alternating breach/clear every µs for
        // 1000 µs: without dwell this flaps 1000 times; with dwell 100
        // the transition count is bounded by elapsed/dwell + the exempt
        // first step.
        for t in 0..1_000u64 {
            let sample = if t % 2 == 0 { overload() } else { calm() };
            s.step(&p, t, sample);
        }
        let transitions = s.steps_down() + s.steps_up();
        assert!(
            transitions <= 1_000 / p.dwell_us() + 1,
            "dwell failed to bound flapping: {transitions} transitions"
        );
        assert!(transitions >= 2, "some transitions must still happen");
    }

    #[test]
    fn sustained_overload_descends_one_rung_per_dwell_to_the_bottom() {
        let p = ladder3();
        let mut s = LadderState::new();
        assert_eq!(s.step(&p, 0, overload()), 1);
        assert_eq!(s.step(&p, 50, overload()), 1, "second step gated by dwell");
        assert_eq!(s.step(&p, 110, overload()), 2);
        // Bottom rung: stays put under further overload.
        assert_eq!(s.step(&p, 400, overload()), 2);
        assert_eq!(s.steps_down(), 2);
        // Sustained calm walks it all the way back.
        assert_eq!(s.step(&p, 520, calm()), 1);
        assert_eq!(s.step(&p, 640, calm()), 0);
        assert_eq!(s.steps_up(), 2);
    }

    #[test]
    fn degraded_time_accumulates_only_off_the_default_rung() {
        let p = ladder3();
        let mut s = LadderState::new();
        // 500 µs healthy: no degraded time.
        assert_eq!(s.step(&p, 500, calm()), 0);
        assert_eq!(s.time_degraded_us(), 0);
        s.step(&p, 600, overload()); // degrade at 600
        s.touch(900);
        assert_eq!(s.time_degraded_us(), 300);
        s.step(&p, 1_000, calm()); // recover at 1000
        assert_eq!(s.time_degraded_us(), 400);
        s.touch(5_000); // healthy again: clock stops
        assert_eq!(s.time_degraded_us(), 400);
        // Non-monotonic time is clamped, never underflows.
        s.touch(100);
        assert_eq!(s.time_degraded_us(), 400);
    }
}
