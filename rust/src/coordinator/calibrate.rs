//! Calibration pass (paper §5: "min-max statistics are gathered during a
//! quick preprocessing stage on 2K randomly picked images").
//!
//! Runs the model's calib HLO — f(img) -> (per-layer max, per-layer
//! mean) — over calibration batches and reduces with
//! [`CalibStats`](crate::quant::minmax::CalibStats). The resulting scale
//! vector feeds the sparq HLO and the native engine identically.

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::quant::baselines::{aciq, ScalePolicy};
use crate::quant::minmax::CalibStats;
use crate::runtime::{ArtifactKind, ModelArtifacts, PjrtRuntime, TensorArg};

/// Default number of calibration images (paper: 2K).
pub const CALIB_IMAGES: usize = 2048;

/// Run calibration for one model; returns reduced statistics.
pub fn calibrate(
    rt: &PjrtRuntime,
    model: &ModelArtifacts,
    ds: &Dataset,
    batch: usize,
    images: usize,
) -> Result<CalibStats> {
    let exe = rt.load(&model.hlo_path(ArtifactKind::Calib))?;
    let mut stats = CalibStats::new(model.quant_convs);
    let mut buf = Vec::new();
    let mut seen = 0usize;
    let mut start = 0usize;
    while seen < images {
        ds.batch_f32_into(start, batch, &mut buf);
        let out = exe.run(&[TensorArg::f32(&[batch, ds.h, ds.w, ds.c], buf.clone())])?;
        if out.len() != 2 {
            bail!("calib artifact must return (max, mean), got {} outputs", out.len());
        }
        stats.update(out[0].as_f32(), out[1].as_f32());
        seen += batch;
        start = (start + batch) % ds.n;
    }
    Ok(stats)
}

/// Turn calibration statistics into an activation-scale vector under a
/// given policy (min-max for SPARQ and the naive baselines, analytic
/// clipping for the ACIQ baseline).
pub fn scales_for_policy(stats: &CalibStats, policy: ScalePolicy, act_bits: u8) -> Vec<f32> {
    match policy {
        ScalePolicy::MinMax => stats.scales(),
        ScalePolicy::AciqClip => {
            let clipped = aciq::clipped_maxes(&stats.layer_means(), &stats.maxes, act_bits);
            clipped.iter().map(|&m| m / 255.0).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_scales_differ_when_clipping_bites() {
        let mut stats = CalibStats::new(2);
        // layer 0: heavy tail (max >> mean) -> ACIQ clips hard
        stats.update(&[100.0, 1.0], &[0.5, 0.9]);
        let mm = scales_for_policy(&stats, ScalePolicy::MinMax, 4);
        let ac = scales_for_policy(&stats, ScalePolicy::AciqClip, 4);
        assert!(ac[0] < mm[0] * 0.1, "clipped {} vs minmax {}", ac[0], mm[0]);
        // layer 1: mean close to max -> cap at min-max
        assert!((ac[1] - mm[1]).abs() / mm[1] < 1e-6);
    }
}
