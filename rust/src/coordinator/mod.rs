//! L3 coordinator (DESIGN.md S14) — calibration, evaluation, serving.
//!
//! The paper's contribution lives at the PE/quantizer level, so per the
//! architecture contract L3 is the *driver* tier: it owns process
//! lifecycle, artifact loading, the calibration pass (paper §5's
//! preprocessing stage), the accuracy-evaluation loops behind every
//! table, and the in-process serving stack that shows the SPARQ
//! artifacts serving real request streams.
//!
//! * [`calibrate`] — runs the calib HLO over calibration batches and
//!   reduces min-max / mean statistics into activation scales.
//! * [`eval`]      — top-1 accuracy drivers over the PJRT path and the
//!   native engine (dense + STC).
//! * [`batcher`]   — dynamic batcher: requests queue, a worker forms
//!   batches up to the artifact's lowered batch size or a deadline,
//!   executes, and scatters results (vLLM-style, scaled down).
//! * [`server`]    — single-model inference service facade + metrics.
//! * [`router`]    — sharded multi-engine dispatch over the batcher.
//! * [`registry`]  — versioned per-variant parameter slots: zero-
//!   downtime hot-swap, canary rollout, drain accounting.
//! * [`slo`]       — SLO degradation ladders: validated per-model
//!   [`SloPolicy`] + the pure-compute [`LadderState`] machine behind
//!   load-adaptive precision serving.
//! * [`http`]      — HTTP/1.1 network front door over the router.
//!
//! # Serving architecture
//!
//! The serving stack is four layers, smallest to largest:
//!
//! 1. **Batcher** ([`batcher`]) — one worker thread per shard forming
//!    true-size batches from a **bounded** queue.
//!    [`BatchPolicy::max_queue_depth`] caps waiting requests; on
//!    overload, [`batcher::OverloadPolicy`] either rejects the incoming
//!    request (`RejectNewest`) or sheds the oldest queued one
//!    (`ShedOldest`) — in both cases the losing caller gets a
//!    descriptive error and the event lands in [`batcher::BatcherStats`]
//!    (`rejected` / `shed`, plus the live `queue_depth` gauge and its
//!    high-water mark). [`BatchPolicy::max_queue_wait`] optionally
//!    sheds requests that aged past a deadline at batch-build time
//!    (typed [`batcher::BatchError::Shed`], counted in `expired`).
//!    Burst traffic costs an error, never unbounded memory.
//!    [`Batcher::submit`] returns a [`PendingReply`] whose non-blocking
//!    [`try_wait`](PendingReply::try_wait) is the completion seam the
//!    HTTP event loop polls.
//! 2. **Server** ([`server`]) — one batcher + one executor (a PJRT
//!    executable or a native [`Engine`](crate::model::Engine)), with
//!    e2e/queue latency histograms and the live batcher stats exposed
//!    through [`ServerMetrics`].
//! 3. **Router** ([`router`]) — N named models x V policy variants x M
//!    replica shards per variant in one process. Every *variant* is a
//!    quantization operating point: its own
//!    `Arc<`[`ModelParams`](crate::model::ModelParams)`>` prepared
//!    under a per-layer [`QuantPolicy`](crate::quant::QuantPolicy)
//!    (own TrimLuts + requantized weight tables), over the **same**
//!    `Arc<Graph>`/`Arc<Weights>` as its siblings (enforced at build) —
//!    one shared weight copy serves many operating points at once.
//!    Replicas of a variant additionally share that variant's prepared
//!    tables, so neither replica nor variant count is a memory
//!    multiplier. Dispatch is load-aware within a variant: the shard
//!    with the shallowest live `queue_depth` gauge wins (rotating
//!    tie-break, so idle traffic is exact round-robin and a backed-up
//!    shard stops receiving new work); each shard has its own queue,
//!    worker and scratch, so a poisoned replica fails only its own
//!    callers. [`InferenceRouter::infer`] hits the default (first
//!    registered) variant; [`InferenceRouter::infer_variant`] /
//!    [`submit_variant`](InferenceRouter::submit_variant) address one
//!    by name. Per-variant, per-shard and merged metrics come from
//!    [`router::InferenceRouter::metrics`].
//! 4. **HTTP front door** ([`http`]) — one event-loop thread (epoll /
//!    `poll(2)` via the vendored `minipoll` crate; no tokio in the
//!    offline set) accepts non-blocking keep-alive connections, parses
//!    HTTP/1.1 + depth-capped JSON, `submit`s into the router, and
//!    polls [`PendingReply::try_wait`] to complete responses — no
//!    thread is ever parked per request. Variants are selected with a
//!    `POST /v1/infer/{model}@{variant}` path suffix or a `"variant"`
//!    body field (unknown variant → 404); `GET /v1/models` reports
//!    every variant's resolved per-layer policy, footprint bits and
//!    shared `param_bytes`; `GET /v1/metrics` serves the router
//!    metrics as JSON. Overload maps to 503 with the batcher's
//!    message, malformed input to 400, execution failures to 500, and
//!    a known route hit with the wrong method to 405 with an `Allow`
//!    header.
//!
//! Orthogonal to the four layers, the **versioned registry**
//! ([`registry`]) makes every params-built variant hot-swappable: its
//! executors read a generation-numbered [`VersionSlot`] once per batch,
//! so [`InferenceRouter::reload_variant`] (or
//! `POST /v1/models/{name}/reload` on the front door) can stage new
//! weights or a new policy off-thread, canary 1-in-N batches against
//! the serving generation with measured top-1 agreement, and promote or
//! roll back with zero dropped requests — in-flight batches drain on
//! the old `Arc`. See README "Deployment lifecycle".
//!
//! Also orthogonal: **load-adaptive precision serving** ([`slo`]). A
//! model may carry an [`SloPolicy`] degradation ladder — installed via
//! [`InferenceRouter::set_slo_policy`] or `POST /v1/models/{name}/slo`
//! — naming ever-cheaper variants in `footprint_bits` order. When the
//! serving variant's live pressure (queue depth summed across its
//! shards, sliding-window p99 from the batcher's recent view) crosses
//! the policy's thresholds, unaddressed requests route to the next
//! rung down — degrading quality instead of shedding traffic — and
//! walk back as pressure clears; hysteresis and a minimum dwell keep a
//! noisy signal from flapping the ladder. Pinned (`infer_on`) and
//! variant-addressed traffic bypasses the ladder. See README
//! "Load-adaptive serving".

pub mod batcher;
pub mod calibrate;
pub mod eval;
pub mod http;
pub mod registry;
pub mod router;
pub mod server;
pub mod slo;

/// Lock a mutex, recovering the guard from a poisoned state instead of
/// propagating the panic into the caller (which on the serving path
/// would cascade one worker's panic into every thread touching the
/// shared state). Poisoning only means another thread panicked while
/// holding the guard; the values stored under the coordinator's locks
/// (queue deques, histogram buckets, stats counters) are valid after
/// any partial update, so serving degrades instead of aborting.
pub(crate) fn lock_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

pub use batcher::{
    BatchError, BatchPolicy, Batcher, BatcherSnapshot, BatcherStats, OverloadPolicy, PendingReply,
    Reply,
};
pub use calibrate::{calibrate, scales_for_policy};
pub use eval::{
    evaluate_engine_vs_reference, evaluate_native, evaluate_pjrt, evaluate_policy_native,
    evaluate_policy_vs_reference, evaluate_with_engine, EvalReport, ReferenceTop1,
};
pub use http::{HttpConfig, HttpServer};
pub use registry::{
    ModelVersion, RolloutConfig, RolloutOutcome, RolloutStatus, VersionSlot, VersionTracker,
    FIRST_GENERATION,
};
pub use router::{
    InferenceRouter, ModelMetrics, ReloadSource, ReloadSpec, RouterBuilder, ShardMetrics,
    VariantMetrics, DEFAULT_VARIANT,
};
pub use server::{InferenceServer, LatencyHist, ServerMetrics};
pub use slo::{LadderState, PressureSample, SloPolicy, SloStatus};
