//! L3 coordinator (DESIGN.md S14) — calibration, evaluation, serving.
//!
//! The paper's contribution lives at the PE/quantizer level, so per the
//! architecture contract L3 is the *driver* tier: it owns process
//! lifecycle, artifact loading, the calibration pass (paper §5's
//! preprocessing stage), the accuracy-evaluation loops behind every
//! table, and the in-process serving stack that shows the SPARQ
//! artifacts serving real request streams.
//!
//! * [`calibrate`] — runs the calib HLO over calibration batches and
//!   reduces min-max / mean statistics into activation scales.
//! * [`eval`]      — top-1 accuracy drivers over the PJRT path and the
//!   native engine (dense + STC).
//! * [`batcher`]   — dynamic batcher: requests queue, a worker forms
//!   batches up to the artifact's lowered batch size or a deadline,
//!   executes, and scatters results (vLLM-style, scaled down).
//! * [`server`]    — single-model inference service facade + metrics.
//! * [`router`]    — sharded multi-engine dispatch over the batcher.
//! * [`http`]      — HTTP/1.1 network front door over the router.
//!
//! # Serving architecture
//!
//! The serving stack is four layers, smallest to largest:
//!
//! 1. **Batcher** ([`batcher`]) — one worker thread per shard forming
//!    true-size batches from a **bounded** queue.
//!    [`BatchPolicy::max_queue_depth`] caps waiting requests; on
//!    overload, [`batcher::OverloadPolicy`] either rejects the incoming
//!    request (`RejectNewest`) or sheds the oldest queued one
//!    (`ShedOldest`) — in both cases the losing caller gets a
//!    descriptive error and the event lands in [`batcher::BatcherStats`]
//!    (`rejected` / `shed`, plus the live `queue_depth` gauge and its
//!    high-water mark). [`BatchPolicy::max_queue_wait`] optionally
//!    sheds requests that aged past a deadline at batch-build time
//!    (typed [`batcher::BatchError::Shed`], counted in `expired`).
//!    Burst traffic costs an error, never unbounded memory.
//!    [`Batcher::submit`] returns a [`PendingReply`] whose non-blocking
//!    [`try_wait`](PendingReply::try_wait) is the completion seam the
//!    HTTP event loop polls.
//! 2. **Server** ([`server`]) — one batcher + one executor (a PJRT
//!    executable or a native [`Engine`](crate::model::Engine)), with
//!    e2e/queue latency histograms and the live batcher stats exposed
//!    through [`ServerMetrics`].
//! 3. **Router** ([`router`]) — N named models x M replica shards per
//!    model in one process. All replicas of a model execute over one
//!    shared `Arc<`[`ModelParams`](crate::model::ModelParams)`>`:
//!    graph, weights and prepared weight tables are built once and
//!    Arc-shared, so replica count is a throughput knob, not a memory
//!    multiplier. Dispatch is load-aware: the shard with the
//!    shallowest live `queue_depth` gauge wins (rotating tie-break, so
//!    idle traffic is exact round-robin and a backed-up shard stops
//!    receiving new work); each shard has its own queue, worker and
//!    scratch, so a poisoned replica fails only its own callers.
//!    Per-shard and merged aggregate metrics come from
//!    [`router::InferenceRouter::metrics`].
//! 4. **HTTP front door** ([`http`]) — one event-loop thread (epoll /
//!    `poll(2)` via the vendored `minipoll` crate; no tokio in the
//!    offline set) accepts non-blocking keep-alive connections, parses
//!    HTTP/1.1 + depth-capped JSON, `submit`s into the router, and
//!    polls [`PendingReply::try_wait`] to complete responses — no
//!    thread is ever parked per request. Overload maps to 503 with the
//!    batcher's message, malformed input to 400, execution failures to
//!    500; `GET /v1/metrics` serves the router metrics as JSON.

pub mod batcher;
pub mod calibrate;
pub mod eval;
pub mod http;
pub mod router;
pub mod server;

pub use batcher::{
    BatchError, BatchPolicy, Batcher, BatcherSnapshot, BatcherStats, OverloadPolicy, PendingReply,
    Reply,
};
pub use calibrate::{calibrate, scales_for_policy};
pub use eval::{evaluate_native, evaluate_pjrt, evaluate_with_engine, EvalReport};
pub use http::{HttpConfig, HttpServer};
pub use router::{InferenceRouter, ModelMetrics, RouterBuilder, ShardMetrics};
pub use server::{InferenceServer, LatencyHist, ServerMetrics};
