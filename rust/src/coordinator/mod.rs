//! L3 coordinator (DESIGN.md S14) — calibration, evaluation, serving.
//!
//! The paper's contribution lives at the PE/quantizer level, so per the
//! architecture contract L3 is the *driver* tier: it owns process
//! lifecycle, artifact loading, the calibration pass (paper §5's
//! preprocessing stage), the accuracy-evaluation loops behind every
//! table, and the in-process serving stack that shows the SPARQ
//! artifacts serving real request streams.
//!
//! * [`calibrate`] — runs the calib HLO over calibration batches and
//!   reduces min-max / mean statistics into activation scales.
//! * [`eval`]      — top-1 accuracy drivers over the PJRT path and the
//!   native engine (dense + STC).
//! * [`batcher`]   — dynamic batcher: requests queue, a worker forms
//!   batches up to the artifact's lowered batch size or a deadline,
//!   executes, and scatters results (vLLM-style, scaled down).
//! * [`server`]    — single-model inference service facade + metrics.
//! * [`router`]    — sharded multi-engine front door over the batcher.
//!
//! # Serving architecture
//!
//! The serving stack is three layers, smallest to largest:
//!
//! 1. **Batcher** ([`batcher`]) — one worker thread per shard forming
//!    true-size batches from a **bounded** queue.
//!    [`BatchPolicy::max_queue_depth`] caps waiting requests; on
//!    overload, [`batcher::OverloadPolicy`] either rejects the incoming
//!    request (`RejectNewest`) or sheds the oldest queued one
//!    (`ShedOldest`) — in both cases the losing caller gets a
//!    descriptive error and the event lands in [`batcher::BatcherStats`]
//!    (`rejected` / `shed`, plus the live `queue_depth` gauge and its
//!    high-water mark). Burst traffic costs an error, never unbounded
//!    memory.
//! 2. **Server** ([`server`]) — one batcher + one executor (a PJRT
//!    executable or a native [`Engine`](crate::model::Engine)), with
//!    e2e/queue latency histograms and the live batcher stats exposed
//!    through [`ServerMetrics`].
//! 3. **Router** ([`router`]) — N named models x M replica shards per
//!    model in one process. All replicas of a model execute over one
//!    shared `Arc<`[`ModelParams`](crate::model::ModelParams)`>`:
//!    graph, weights and prepared weight tables are built once and
//!    Arc-shared, so replica count is a throughput knob, not a memory
//!    multiplier. Requests round-robin across shards (atomic cursor);
//!    each shard has its own queue, worker and scratch, so a poisoned
//!    replica fails only its own callers. Per-shard and merged
//!    aggregate metrics come from [`router::InferenceRouter::metrics`].

pub mod batcher;
pub mod calibrate;
pub mod eval;
pub mod router;
pub mod server;

pub use batcher::{
    BatchPolicy, Batcher, BatcherSnapshot, BatcherStats, OverloadPolicy, PendingReply, Reply,
};
pub use calibrate::{calibrate, scales_for_policy};
pub use eval::{evaluate_native, evaluate_pjrt, evaluate_with_engine, EvalReport};
pub use router::{InferenceRouter, ModelMetrics, RouterBuilder, ShardMetrics};
pub use server::{InferenceServer, LatencyHist, ServerMetrics};
