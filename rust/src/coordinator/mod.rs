//! L3 coordinator (DESIGN.md S14) — calibration, evaluation, serving.
//!
//! The paper's contribution lives at the PE/quantizer level, so per the
//! architecture contract L3 is the *driver* tier: it owns process
//! lifecycle, artifact loading, the calibration pass (paper §5's
//! preprocessing stage), the accuracy-evaluation loops behind every
//! table, and a dynamically batched inference service that shows the
//! SPARQ artifacts serving real request streams.
//!
//! * [`calibrate`] — runs the calib HLO over calibration batches and
//!   reduces min-max / mean statistics into activation scales.
//! * [`eval`]      — top-1 accuracy drivers over the PJRT path and the
//!   native engine (dense + STC).
//! * [`batcher`]   — dynamic batcher: requests queue, a worker forms
//!   batches up to the artifact's lowered batch size or a deadline,
//!   executes, and scatters results (vLLM-style, scaled down).
//! * [`server`]    — in-process inference service facade + metrics.

pub mod batcher;
pub mod calibrate;
pub mod eval;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use calibrate::{calibrate, scales_for_policy};
pub use eval::{evaluate_native, evaluate_pjrt, EvalReport};
pub use server::{InferenceServer, ServerMetrics};
