//! Versioned model registry — the zero-downtime hot-swap primitive.
//!
//! Each serving variant owns one [`VersionSlot`]: a generation-numbered
//! [`ModelVersion`] behind a `Mutex<Arc<_>>`. Executors `load()` the
//! slot once per batch (an `Arc` clone under a microsecond lock — the
//! safe equivalent of an `ArcSwap`, with no `unsafe` for Miri to
//! reason about), run the whole batch on that version, and drop the
//! clone when the batch completes. A swap publishes the next version
//! atomically: batches already in flight finish on the old `Arc`
//! (drain-on-old-Arc), new batches pick up the new one, and no request
//! is ever dropped or torn across versions.
//!
//! The companion [`VersionTracker`] runs the rollout protocol on top of
//! the raw swap:
//!
//! * **staged load** — [`VersionTracker::begin_rollout`] validates the
//!   incoming [`ModelParams`] against the live graph
//!   ([`validate_staged`]) before anything is published;
//! * **canary** — with `canary_share = N`, 1 in N batches routes to the
//!   incoming generation while the serving generation shadow-computes
//!   the same batch; per-row top-1 agreement accumulates until
//!   `min_requests` rows have been compared, then the candidate
//!   auto-promotes (agreement ≥ threshold) or auto-rolls-back;
//! * **drain accounting** — superseded (and rolled-back) versions park
//!   in a retired list until their `Arc::strong_count` falls to 1,
//!   i.e. no executor or in-flight batch holds them; the sweep then
//!   frees the prepared tables and records the generation as drained.
//!
//! Lock order: `VersionTracker` inner before `VersionSlot` (the tracker
//! swaps the slot while holding its own lock; nothing takes them in the
//! other order). Both locks guard single assignments/clones — no I/O,
//! no waiting, no executor work ever runs under them.
//!
//! **Composition with SLO degradation** ([`super::slo`]): the ladder
//! re-routes *which variant* a request reaches, while the registry
//! versions *what parameters* each variant executes — the two are
//! orthogonal by construction. Traffic degraded onto a cheaper rung
//! flows through that variant's own slot and tracker, so a canary in
//! flight on the cheap variant keeps measuring agreement (now over
//! more rows), a hot-swap of the degraded-to variant still drains on
//! the old `Arc`, and stepping the ladder back up needs no registry
//! coordination at all.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::json::JsonValue;
use crate::json_obj;
use crate::model::ModelParams;

use super::lock_recover;

/// Generation number assigned to the parameters a variant was built
/// with. Reloads count up from here, per variant.
pub const FIRST_GENERATION: u64 = 1;

/// How many drained generation numbers to keep for reporting.
const DRAINED_KEEP: usize = 32;

/// Where a version's parameters came from — hand-written config vs
/// policy auto-search ([`crate::search`]). Carried on the
/// [`ModelVersion`] so `/v1/models` can answer "who chose this
/// operating point, and what did it measure at the time?".
#[derive(Clone, Debug, PartialEq)]
pub struct VersionProvenance {
    /// `"search"` for auto-searched policies; free-form otherwise
    /// (e.g. `"reload"` for operator-driven swaps).
    pub origin: String,
    /// Top-1 agreement vs the A8W8 reference measured when the policy
    /// was chosen (`None` when the origin didn't measure one).
    pub agreement: Option<f64>,
    /// Content hash of the [`crate::search::SearchReport`] that
    /// produced the policy (empty when not search-generated).
    pub report_sha: String,
}

impl VersionProvenance {
    pub fn to_json(&self) -> JsonValue {
        json_obj! {
            "origin" => self.origin.clone(),
            "agreement" => match self.agreement {
                Some(a) => JsonValue::Number(a),
                None => JsonValue::Null,
            },
            "report_sha" => self.report_sha.clone(),
        }
    }
}

/// One immutable published version of a variant's parameters. The
/// registry wrapper (rather than a bare `Arc<ModelParams>`) makes drain
/// accounting exact: the only strong references to a `ModelVersion` are
/// the slot, the tracker's retired list, and in-flight batches — so
/// `Arc::strong_count == 1` on a retired version means every batch that
/// ever saw it has completed.
pub struct ModelVersion {
    pub generation: u64,
    /// Content hash of the weight store ([`crate::model::Weights::content_sha`]).
    pub weights_sha: String,
    pub params: Arc<ModelParams>,
    /// How this version's parameters were chosen (`None` for
    /// build-time parameters and untagged reloads).
    pub provenance: Option<VersionProvenance>,
}

impl ModelVersion {
    fn build(
        generation: u64,
        params: Arc<ModelParams>,
        provenance: Option<VersionProvenance>,
    ) -> Arc<Self> {
        let weights_sha = params.weights.content_sha();
        Arc::new(Self { generation, weights_sha, params, provenance })
    }
}

/// The swap cell: current version behind a mutex, cloned per batch.
pub struct VersionSlot {
    current: Mutex<Arc<ModelVersion>>,
}

impl VersionSlot {
    /// Wrap build-time parameters as [`FIRST_GENERATION`].
    pub fn new(params: Arc<ModelParams>) -> Self {
        Self { current: Mutex::new(ModelVersion::build(FIRST_GENERATION, params, None)) }
    }

    /// The version new work should run on — an `Arc` clone; the caller
    /// keeps the whole batch on this one version.
    pub fn load(&self) -> Arc<ModelVersion> {
        Arc::clone(&lock_recover(&self.current))
    }

    /// Publish `next`, returning the superseded version for the
    /// caller's retired list.
    fn swap(&self, next: Arc<ModelVersion>) -> Arc<ModelVersion> {
        std::mem::replace(&mut *lock_recover(&self.current), next)
    }
}

/// Rollout knobs for one reload.
#[derive(Clone, Copy, Debug)]
pub struct RolloutConfig {
    /// Route 1 in `canary_share` batches to the incoming generation.
    /// `0` disables the canary: the swap happens immediately.
    pub canary_share: u64,
    /// Promote when measured top-1 agreement ≥ this, else roll back.
    pub promote_threshold: f64,
    /// Rows to shadow-compare before the promote/rollback verdict.
    pub min_requests: u64,
}

impl Default for RolloutConfig {
    fn default() -> Self {
        Self { canary_share: 8, promote_threshold: 0.99, min_requests: 256 }
    }
}

/// Where a batch should execute, per [`VersionTracker::dispatch`].
pub enum Dispatch {
    /// Run on the serving generation.
    Serving(Arc<ModelVersion>),
    /// Canary batch: run on `incoming`, shadow-compare against
    /// `serving`, report rows via [`VersionTracker::record_canary`].
    Canary { incoming: Arc<ModelVersion>, serving: Arc<ModelVersion> },
}

/// Terminal record of one rollout.
#[derive(Clone, Debug)]
pub struct RolloutOutcome {
    pub generation: u64,
    pub promoted: bool,
    /// Measured agreement (`None` for an immediate, uncanaried swap or
    /// an executor-failure rollback).
    pub agreement: Option<f64>,
}

/// Live canary state, as reported by [`VersionTracker::status`].
#[derive(Clone, Debug)]
pub struct CanaryStatus {
    pub generation: u64,
    pub weights_sha: String,
    pub share: u64,
    pub agree: u64,
    pub total: u64,
    pub min_requests: u64,
    pub promote_threshold: f64,
}

/// A retired version still held by in-flight work.
#[derive(Clone, Debug)]
pub struct DrainingVersion {
    pub generation: u64,
    /// Strong holders beyond the registry's own reference.
    pub holders: usize,
}

/// Snapshot of a variant's rollout state for `/v1/models` and
/// `/v1/metrics`.
#[derive(Clone, Debug)]
pub struct RolloutStatus {
    pub canary: Option<CanaryStatus>,
    pub draining: Vec<DrainingVersion>,
    /// Recently fully-drained generations (newest last, bounded).
    pub drained: Vec<u64>,
    /// Rows served per generation, over the variant's lifetime.
    pub served: BTreeMap<u64, u64>,
    pub last_outcome: Option<RolloutOutcome>,
    pub last_error: Option<String>,
}

impl RolloutStatus {
    /// The variant's lifecycle label: `canary` while a candidate takes
    /// traffic, `draining` while a superseded version is still held by
    /// in-flight work, `serving` otherwise.
    pub fn state(&self) -> &'static str {
        if self.canary.is_some() {
            "canary"
        } else if self.draining.is_empty() {
            "serving"
        } else {
            "draining"
        }
    }
}

struct Canary {
    incoming: Arc<ModelVersion>,
    share: u64,
    /// Batch counter for the 1-in-`share` routing pattern.
    tick: u64,
    agree: u64,
    total: u64,
    threshold: f64,
    min_requests: u64,
}

struct TrackerInner {
    next_generation: u64,
    canary: Option<Canary>,
    retired: Vec<Arc<ModelVersion>>,
    drained: Vec<u64>,
    served: BTreeMap<u64, u64>,
    last_outcome: Option<RolloutOutcome>,
    last_error: Option<String>,
}

/// Per-variant rollout state machine: allocates generations, routes
/// canary traffic, applies the promote/rollback verdict, and accounts
/// for draining versions. Shared by every replica executor of the
/// variant.
pub struct VersionTracker {
    inner: Mutex<TrackerInner>,
}

impl Default for VersionTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl VersionTracker {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(TrackerInner {
                next_generation: FIRST_GENERATION + 1,
                canary: None,
                retired: Vec::new(),
                drained: Vec::new(),
                served: BTreeMap::new(),
                last_outcome: None,
                last_error: None,
            }),
        }
    }

    /// Stage `params` as the next generation. Validates against the
    /// live version first; with `canary_share == 0` the swap is
    /// immediate, otherwise a canary is installed and the verdict comes
    /// from measured agreement. Returns the incoming generation number.
    /// At most one rollout per variant may be in flight.
    pub fn begin_rollout(
        &self,
        slot: &VersionSlot,
        params: Arc<ModelParams>,
        cfg: RolloutConfig,
    ) -> Result<u64> {
        self.begin_rollout_tagged(slot, params, cfg, None)
    }

    /// [`Self::begin_rollout`] with a provenance tag attached to the
    /// incoming version — the install path for search-generated
    /// policies, which carry their measured agreement and report hash.
    pub fn begin_rollout_tagged(
        &self,
        slot: &VersionSlot,
        params: Arc<ModelParams>,
        cfg: RolloutConfig,
        provenance: Option<VersionProvenance>,
    ) -> Result<u64> {
        if !(0.0..=1.0).contains(&cfg.promote_threshold) {
            bail!("promote_threshold {} not in [0, 1]", cfg.promote_threshold);
        }
        let mut inner = lock_recover(&self.inner);
        if let Some(c) = &inner.canary {
            bail!("rollout of generation {} already in progress", c.incoming.generation);
        }
        validate_staged(&slot.load().params, &params)?;
        let generation = inner.next_generation;
        inner.next_generation += 1;
        let incoming = ModelVersion::build(generation, params, provenance);
        if cfg.canary_share == 0 {
            let old = slot.swap(incoming);
            inner.retired.push(old);
            inner.last_outcome =
                Some(RolloutOutcome { generation, promoted: true, agreement: None });
        } else {
            inner.canary = Some(Canary {
                incoming,
                share: cfg.canary_share,
                tick: 0,
                agree: 0,
                total: 0,
                threshold: cfg.promote_threshold,
                min_requests: cfg.min_requests.max(1),
            });
        }
        inner.last_error = None;
        Ok(generation)
    }

    /// Route one batch: every `share`-th batch goes to the canary (when
    /// one is active), the rest to the serving generation.
    pub fn dispatch(&self, slot: &VersionSlot) -> Dispatch {
        let mut inner = lock_recover(&self.inner);
        if let Some(c) = &mut inner.canary {
            c.tick += 1;
            if c.tick % c.share == 0 {
                return Dispatch::Canary {
                    incoming: Arc::clone(&c.incoming),
                    serving: slot.load(),
                };
            }
        }
        drop(inner);
        Dispatch::Serving(slot.load())
    }

    /// Record `agree` agreeing rows out of `total` shadow-compared rows
    /// for canary `generation`. Once `min_requests` rows are in, the
    /// verdict is applied: promote (swap + retire old) or roll back
    /// (retire the candidate). Stale generations (a verdict already
    /// landed on another replica) are ignored, so the call is
    /// idempotent across concurrent executors.
    pub fn record_canary(
        &self,
        slot: &VersionSlot,
        generation: u64,
        agree: u64,
        total: u64,
    ) -> Option<RolloutOutcome> {
        let mut inner = lock_recover(&self.inner);
        let c = match &mut inner.canary {
            Some(c) if c.incoming.generation == generation => c,
            _ => return None,
        };
        c.agree += agree;
        c.total += total;
        if c.total < c.min_requests {
            return None;
        }
        let agreement = c.agree as f64 / c.total as f64;
        let promoted = agreement >= c.threshold;
        let incoming = Arc::clone(&c.incoming);
        inner.canary = None;
        if promoted {
            let old = slot.swap(incoming);
            inner.retired.push(old);
        } else {
            inner.retired.push(incoming);
        }
        let outcome = RolloutOutcome { generation, promoted, agreement: Some(agreement) };
        inner.last_outcome = Some(outcome.clone());
        Some(outcome)
    }

    /// Roll back canary `generation` because its executor failed (the
    /// serving generation keeps answering). Returns false if that
    /// canary is no longer active.
    pub fn fail_canary(&self, generation: u64, err: &str) -> bool {
        let mut inner = lock_recover(&self.inner);
        let matches = matches!(&inner.canary, Some(c) if c.incoming.generation == generation);
        if !matches {
            return false;
        }
        if let Some(c) = inner.canary.take() {
            inner.retired.push(c.incoming);
        }
        inner.last_outcome = Some(RolloutOutcome { generation, promoted: false, agreement: None });
        inner.last_error = Some(format!("canary generation {generation} failed: {err}"));
        true
    }

    /// Count `rows` answered by `generation` and sweep the retired list.
    pub fn note_served(&self, generation: u64, rows: u64) {
        let mut inner = lock_recover(&self.inner);
        *inner.served.entry(generation).or_insert(0) += rows;
        sweep(&mut inner);
    }

    /// Record a staging failure (reload thread) for `/v1/models`.
    pub fn set_error(&self, msg: String) {
        lock_recover(&self.inner).last_error = Some(msg);
    }

    /// Rollout snapshot for introspection endpoints. Sweeps first, so a
    /// version with no remaining holders reports as drained, not
    /// draining.
    pub fn status(&self) -> RolloutStatus {
        let mut inner = lock_recover(&self.inner);
        sweep(&mut inner);
        RolloutStatus {
            canary: inner.canary.as_ref().map(|c| CanaryStatus {
                generation: c.incoming.generation,
                weights_sha: c.incoming.weights_sha.clone(),
                share: c.share,
                agree: c.agree,
                total: c.total,
                min_requests: c.min_requests,
                promote_threshold: c.threshold,
            }),
            draining: inner
                .retired
                .iter()
                .map(|v| DrainingVersion {
                    generation: v.generation,
                    holders: Arc::strong_count(v).saturating_sub(1),
                })
                .collect(),
            drained: inner.drained.clone(),
            served: inner.served.clone(),
            last_outcome: inner.last_outcome.clone(),
            last_error: inner.last_error.clone(),
        }
    }
}

/// Drop retired versions whose only remaining holder is the retired
/// list itself. Nothing ever clones out of the list, so once the count
/// reaches 1 it can only stay there — the check is race-free despite
/// `strong_count` being advisory in general.
fn sweep(inner: &mut TrackerInner) {
    let mut i = 0;
    while i < inner.retired.len() {
        if Arc::strong_count(&inner.retired[i]) == 1 {
            let v = inner.retired.swap_remove(i);
            inner.drained.push(v.generation);
            if inner.drained.len() > DRAINED_KEEP {
                inner.drained.remove(0);
            }
        } else {
            i += 1;
        }
    }
}

/// Staged-reload validation: the incoming parameter block must drop
/// into the live variant's request shapes — same input tensor, same
/// class count, shape-identical weight store. Values (and policy) are
/// free to differ.
pub fn validate_staged(live: &ModelParams, incoming: &ModelParams) -> Result<()> {
    if live.graph.input_hwc != incoming.graph.input_hwc {
        bail!(
            "input shape {:?} vs incoming {:?}",
            live.graph.input_hwc,
            incoming.graph.input_hwc
        );
    }
    if live.graph.num_classes != incoming.graph.num_classes {
        bail!(
            "class count {} vs incoming {}",
            live.graph.num_classes,
            incoming.graph.num_classes
        );
    }
    live.weights
        .same_shapes(&incoming.weights)
        .context("incoming weights incompatible with live graph")
}

/// Rows on which two logit matrices pick the same top-1 class — the
/// canary's agreement measure (same machinery as the eval harness's
/// accuracy loop).
pub(crate) fn top1_agreement(a: &[f32], b: &[f32], classes: usize) -> u64 {
    super::eval::top1(a, classes)
        .into_iter()
        .zip(super::eval::top1(b, classes))
        .filter(|(x, y)| x == y)
        .count() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::QuantConv;
    use crate::model::{EngineMode, Graph, ModelParams, Node, Op, Weights};
    use crate::quant::{QuantPolicy, SparqConfig};
    use std::collections::HashMap;

    /// Minimal 4x4x1 single-quant-conv model; `seed` shifts the weight
    /// bytes so distinct seeds are distinct versions with equal shapes.
    fn tiny_params(seed: i8) -> Arc<ModelParams> {
        tiny_params_classes(seed, 2)
    }

    fn tiny_params_classes(seed: i8, classes: usize) -> Arc<ModelParams> {
        let graph = Graph {
            arch: "tiny".into(),
            variant: "registry-test".into(),
            num_classes: classes,
            input_hwc: [4, 4, 1],
            eval_batch: 4,
            quant_convs: vec!["q1".into()],
            nodes: vec![
                Node { name: "img".into(), op: Op::Input, inputs: vec![] },
                Node {
                    name: "q1".into(),
                    op: Op::Conv { k: 3, stride: 1, out_ch: 4, relu: true, quant: true },
                    inputs: vec!["img".into()],
                },
                Node { name: "g".into(), op: Op::Gap, inputs: vec!["q1".into()] },
                Node {
                    name: "fc".into(),
                    op: Op::Fc { out: classes },
                    inputs: vec!["g".into()],
                },
            ],
        };
        let mut quant = HashMap::new();
        quant.insert(
            "q1".to_string(),
            QuantConv {
                wq: (0..9 * 4).map(|i| (i as i8).wrapping_mul(7).wrapping_add(seed)).collect(),
                k: 9,
                o: 4,
                scale: vec![0.01; 4],
                bias: vec![0.0; 4],
            },
        );
        let weights = Weights {
            quant,
            float: HashMap::new(),
            fc_w: (0..4 * classes).map(|i| i as f32 / 8.0).collect(),
            fc_in: 4,
            fc_out: classes,
            fc_b: vec![0.0; classes],
        };
        Arc::new(
            ModelParams::with_policy(
                Arc::new(graph),
                Arc::new(weights),
                QuantPolicy::uniform(SparqConfig::A8W8),
                &[0.02],
                EngineMode::Dense,
            )
            .unwrap(),
        )
    }

    #[test]
    fn slot_serves_first_generation_and_swap_publishes_atomically() {
        let slot = VersionSlot::new(tiny_params(0));
        let v1 = slot.load();
        assert_eq!(v1.generation, FIRST_GENERATION);
        assert_eq!(v1.weights_sha.len(), 16);

        let tracker = VersionTracker::new();
        let cfg = RolloutConfig { canary_share: 0, ..RolloutConfig::default() };
        let gen2 = tracker.begin_rollout(&slot, tiny_params(1), cfg).unwrap();
        assert_eq!(gen2, FIRST_GENERATION + 1);
        let v2 = slot.load();
        assert_eq!(v2.generation, gen2);
        assert_ne!(v1.weights_sha, v2.weights_sha, "distinct seeds hash differently");
        // the pre-swap handle still works and still names generation 1 —
        // in-flight batches drain on the old Arc
        assert_eq!(v1.generation, FIRST_GENERATION);
    }

    #[test]
    fn retired_generation_drains_once_all_holders_drop() {
        let slot = VersionSlot::new(tiny_params(0));
        let tracker = VersionTracker::new();
        let inflight = slot.load(); // simulated in-flight batch
        let cfg = RolloutConfig { canary_share: 0, ..RolloutConfig::default() };
        tracker.begin_rollout(&slot, tiny_params(1), cfg).unwrap();

        let st = tracker.status();
        assert_eq!(st.state(), "draining");
        assert_eq!(st.draining.len(), 1);
        assert_eq!(st.draining[0].generation, FIRST_GENERATION);
        assert_eq!(st.draining[0].holders, 1, "only the simulated batch holds it");

        drop(inflight);
        let st = tracker.status();
        assert_eq!(st.state(), "serving");
        assert!(st.draining.is_empty(), "no holders left: fully drained");
        assert_eq!(st.drained, vec![FIRST_GENERATION]);
    }

    #[test]
    fn canary_routes_one_in_n_and_promotes_on_agreement() {
        let slot = VersionSlot::new(tiny_params(0));
        let tracker = VersionTracker::new();
        let cfg =
            RolloutConfig { canary_share: 3, promote_threshold: 0.9, min_requests: 6 };
        let gen2 = tracker.begin_rollout(&slot, tiny_params(1), cfg).unwrap();
        assert_eq!(tracker.status().state(), "canary");

        let mut canary_batches = 0;
        for i in 1..=9 {
            match tracker.dispatch(&slot) {
                Dispatch::Canary { incoming, serving } => {
                    canary_batches += 1;
                    assert_eq!(i % 3, 0, "canary fires on exactly every 3rd batch");
                    assert_eq!(incoming.generation, gen2);
                    assert_eq!(serving.generation, FIRST_GENERATION);
                    tracker.note_served(incoming.generation, 2);
                    tracker.record_canary(&slot, gen2, 2, 2);
                }
                Dispatch::Serving(v) => {
                    assert_eq!(v.generation, FIRST_GENERATION);
                    tracker.note_served(v.generation, 2);
                }
            }
        }
        assert_eq!(canary_batches, 3);
        // 3 canary batches x 2 rows = 6 rows ≥ min_requests → verdict
        let st = tracker.status();
        let outcome = st.last_outcome.expect("verdict landed");
        assert!(outcome.promoted);
        assert_eq!(outcome.agreement, Some(1.0));
        assert_eq!(slot.load().generation, gen2);
        assert_eq!(st.served.get(&FIRST_GENERATION), Some(&12));
        assert_eq!(st.served.get(&gen2), Some(&6));
        // the superseded generation has no holders → already drained
        assert_eq!(st.drained, vec![FIRST_GENERATION]);
    }

    #[test]
    fn canary_rolls_back_below_threshold_and_candidate_drains() {
        let slot = VersionSlot::new(tiny_params(0));
        let tracker = VersionTracker::new();
        let cfg =
            RolloutConfig { canary_share: 1, promote_threshold: 0.9, min_requests: 4 };
        let gen2 = tracker.begin_rollout(&slot, tiny_params(1), cfg).unwrap();
        // every batch is a canary at share 1; report 50% agreement
        let outcome = tracker.record_canary(&slot, gen2, 2, 4).expect("verdict");
        assert!(!outcome.promoted);
        assert_eq!(outcome.agreement, Some(0.5));
        assert_eq!(slot.load().generation, FIRST_GENERATION, "serving version untouched");
        let st = tracker.status();
        assert_eq!(st.state(), "serving");
        assert_eq!(st.drained, vec![gen2], "rejected candidate freed immediately");
        // a late replica reporting the dead canary is a no-op
        assert!(tracker.record_canary(&slot, gen2, 4, 4).is_none());
    }

    #[test]
    fn overlapping_rollouts_are_rejected_but_sequential_ones_number_up() {
        let slot = VersionSlot::new(tiny_params(0));
        let tracker = VersionTracker::new();
        let cfg = RolloutConfig { canary_share: 4, ..RolloutConfig::default() };
        let gen2 = tracker.begin_rollout(&slot, tiny_params(1), cfg).unwrap();
        let err = tracker
            .begin_rollout(&slot, tiny_params(2), cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("already in progress"), "{err}");
        // failure-rollback clears the canary; the next rollout proceeds
        assert!(tracker.fail_canary(gen2, "executor died"));
        assert!(!tracker.fail_canary(gen2, "stale"), "second report is a no-op");
        let st = tracker.status();
        assert!(st.last_error.as_deref().is_some_and(|e| e.contains("executor died")));
        let gen3 = tracker
            .begin_rollout(
                &slot,
                tiny_params(2),
                RolloutConfig { canary_share: 0, ..cfg },
            )
            .unwrap();
        assert_eq!(gen3, gen2 + 1);
        assert_eq!(slot.load().generation, gen3);
    }

    #[test]
    fn staging_validation_rejects_shape_changes() {
        let slot = VersionSlot::new(tiny_params(0));
        let tracker = VersionTracker::new();
        let err = tracker
            .begin_rollout(
                &slot,
                tiny_params_classes(1, 3),
                RolloutConfig::default(),
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("class count"), "{err}");
        assert_eq!(slot.load().generation, FIRST_GENERATION);
    }

    #[test]
    fn provenance_rides_the_rollout_and_serializes() {
        let slot = VersionSlot::new(tiny_params(0));
        assert!(slot.load().provenance.is_none(), "build-time version is untagged");
        let tracker = VersionTracker::new();
        let tag = VersionProvenance {
            origin: "search".into(),
            agreement: Some(0.993),
            report_sha: "cbf29ce484222325".into(),
        };
        let cfg = RolloutConfig { canary_share: 0, ..RolloutConfig::default() };
        tracker
            .begin_rollout_tagged(&slot, tiny_params(1), cfg, Some(tag.clone()))
            .unwrap();
        let v = slot.load();
        assert_eq!(v.provenance, Some(tag.clone()));
        let j = tag.to_json();
        assert_eq!(j.get("origin").and_then(JsonValue::as_str), Some("search"));
        assert_eq!(j.get("agreement").and_then(JsonValue::as_f64), Some(0.993));
        // untagged rollouts keep the None path
        let gen3 = tracker.begin_rollout(&slot, tiny_params(2), cfg).unwrap();
        assert_eq!(slot.load().generation, gen3);
        assert!(slot.load().provenance.is_none());
    }

    #[test]
    fn top1_agreement_counts_matching_rows() {
        let a = [0.1f32, 0.9, 0.8, 0.2, 0.3, 0.7];
        let b = [0.2f32, 0.8, 0.1, 0.9, 0.1, 0.6];
        // rows: argmax a = [1, 0, 1], argmax b = [1, 1, 1]
        assert_eq!(top1_agreement(&a, &b, 2), 2);
    }
}
