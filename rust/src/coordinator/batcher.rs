//! Dynamic batcher — forms execution batches from an asynchronous
//! request stream (the vLLM-router pattern scaled to this repo).
//!
//! The batcher collects up to `max_batch` requests, or whatever arrived
//! when the oldest request hits its latency deadline, then executes the
//! batch **at its true size**: the executor receives the packed images
//! for exactly `bsz` requests plus `bsz` itself. Executors with a fixed
//! lowered batch dimension (the PJRT path) pad internally at the last
//! possible layer; the native engine executes short batches without any
//! padded compute. Per-request results are scattered back, and executor
//! failures are carried to every waiting `infer` caller with the real
//! underlying message. Threads + channels, no async runtime — tokio is
//! not in this image's vendored set, and one worker thread per model is
//! the right shape for a single-device backend anyway.
//!
//! # Backpressure
//!
//! The queue is **bounded**: [`BatchPolicy::max_queue_depth`] caps the
//! number of requests waiting for a batch slot (requests already being
//! executed don't count). When a submit would exceed the cap, the
//! [`OverloadPolicy`] decides who loses:
//!
//! * [`OverloadPolicy::RejectNewest`] — the submitting caller gets an
//!   immediate, descriptive overload error; everyone already queued
//!   keeps their slot. Predictable for upstream retry loops.
//! * [`OverloadPolicy::ShedOldest`] — the oldest *queued* request is
//!   shed (its waiting caller receives the overload error) and the new
//!   request takes the tail slot. Favors fresh traffic when stale
//!   results are worthless.
//!
//! Either way memory is bounded under burst traffic, the event is
//! counted ([`BatcherStats::rejected`] / [`BatcherStats::shed`]) and
//! the live depth is observable ([`BatcherStats::queue_depth`],
//! [`BatcherStats::peak_queue_depth`]) — overload is an error plus a
//! metric, never silent unbounded growth.
//!
//! # Deadlines and non-blocking completion
//!
//! Admission under the depth bound is not a promise of freshness: a
//! waiter can sit behind a slow executor indefinitely. The optional
//! [`BatchPolicy::max_queue_wait`] deadline sheds over-age requests at
//! batch-build time with a typed [`BatchError::Shed`] (counted in
//! [`BatcherStats::expired`]), so compute is never spent on replies the
//! caller has given up on.
//!
//! [`Batcher::submit`] is non-blocking and returns a [`PendingReply`];
//! [`PendingReply::try_wait`] polls completion without blocking and
//! reports the typed outcome. That pair is the seam the HTTP front door
//! ([`super::http`]) builds on: one event-loop thread carries every
//! in-flight request instead of pinning a blocked thread per request.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::observability::{LatencyHist, WindowedHist};

/// Span of the sliding-window latency view every batcher keeps
/// alongside its cumulative counters ([`Batcher::recent_hist`]). One
/// second is long enough to hold a stable p99 at serving rates and
/// short enough that the SLO ladder (`coordinator::slo`) reacts to the
/// current overload, not to history.
pub const RECENT_WINDOW_US: u64 = 1_000_000;

/// Ring granularity of the sliding window: samples expire in
/// `RECENT_WINDOW_US / RECENT_SLICES` steps (100 ms).
pub const RECENT_SLICES: usize = 10;

/// What to do with a submit that would push the queue past
/// [`BatchPolicy::max_queue_depth`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Fail the incoming request immediately with an overload error.
    #[default]
    RejectNewest,
    /// Shed the oldest queued request (its caller gets the overload
    /// error) and admit the incoming one.
    ShedOldest,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum formed batch (for PJRT executors: the HLO's lowered batch
    /// dimension).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before a (possibly
    /// short) batch is launched.
    pub max_wait: Duration,
    /// Max requests waiting for a batch slot before the overload policy
    /// kicks in (the in-flight batch does not count).
    pub max_queue_depth: usize,
    /// Who loses when the queue is full.
    pub overload: OverloadPolicy,
    /// Optional deadline on queue time: a request that has already
    /// waited longer than this when a batch is being built is shed
    /// (typed [`BatchError::Shed`], counted in
    /// [`BatcherStats::expired`]) instead of executed. Bounds how stale
    /// a reply can be when a slow executor backs the queue up; `None`
    /// disables the check. Queue age includes the deliberate
    /// [`BatchPolicy::max_wait`] batch-fill window, so this must be
    /// **strictly greater than `max_wait`** — otherwise even an idle
    /// server would shed every request (validated at spawn/build).
    pub max_queue_wait: Option<Duration>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_wait: Duration::from_millis(5),
            max_queue_depth: 1024,
            overload: OverloadPolicy::RejectNewest,
            max_queue_wait: None,
        }
    }
}

/// Why a request failed, as carried over the reply channel. Public and
/// typed so non-blocking front ends ([`PendingReply::try_wait`]) can
/// map outcomes to transport status codes without sniffing message
/// strings, and so overload sheds (the request never ran) don't
/// masquerade as execution failures to the caller.
#[derive(Clone, Debug)]
pub enum BatchError {
    /// The batch executed and failed (executor error, malformed output).
    Exec(String),
    /// The request was shed without executing: the queue head lost under
    /// [`OverloadPolicy::ShedOldest`], or it aged past
    /// [`BatchPolicy::max_queue_wait`] before a batch picked it up.
    Shed(String),
    /// The worker dropped the request without replying (shutdown or
    /// worker death).
    Dropped,
}

impl std::fmt::Display for BatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Exec(msg) => write!(f, "batch execution failed: {msg}"),
            Self::Shed(msg) => f.write_str(msg),
            Self::Dropped => f.write_str("batcher worker dropped the request"),
        }
    }
}

/// One queued inference request.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Result<Reply, BatchError>>,
}

/// Per-request result: logits row + timing.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub queue_time: Duration,
    pub batch_size: usize,
}

/// The batch executor supplied by the server: receives the packed image
/// buffer for the *actual* batch (`bsz * image_len` floats) and `bsz`,
/// and returns at least `bsz` row-major logits rows. `FnMut` so an
/// executor can own reusable state (engine scratch, padding buffers).
pub type ExecuteFn = dyn FnMut(&[f32], usize) -> Result<Vec<f32>> + Send;

/// Statistics the worker and the submit path expose. All fields are
/// atomics so the hot paths never contend on a stats lock and readers
/// (metrics endpoints, the router aggregator) can sample without
/// stopping the world; take a coherent copy with
/// [`BatcherStats::snapshot`].
#[derive(Default, Debug)]
pub struct BatcherStats {
    pub batches: AtomicU64,
    pub requests: AtomicU64,
    pub full_batches: AtomicU64,
    /// Batches whose execution failed — executor errors and malformed
    /// (too-short) logits alike, each surfaced to all of that batch's
    /// callers.
    pub exec_errors: AtomicU64,
    /// Live gauge: requests currently waiting for a batch slot.
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth` (never exceeds the policy's
    /// `max_queue_depth` — the bounded-queue invariant).
    pub peak_queue_depth: AtomicU64,
    /// Requests dropped from the queue head by [`OverloadPolicy::ShedOldest`].
    pub shed: AtomicU64,
    /// Submissions refused by [`OverloadPolicy::RejectNewest`].
    pub rejected: AtomicU64,
    /// Requests shed at batch-build time because they aged past
    /// [`BatchPolicy::max_queue_wait`].
    pub expired: AtomicU64,
}

/// Plain-value copy of [`BatcherStats`] at one instant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatcherSnapshot {
    pub batches: u64,
    pub requests: u64,
    pub full_batches: u64,
    pub exec_errors: u64,
    pub queue_depth: u64,
    pub peak_queue_depth: u64,
    pub shed: u64,
    pub rejected: u64,
    pub expired: u64,
}

impl BatcherStats {
    pub fn snapshot(&self) -> BatcherSnapshot {
        BatcherSnapshot {
            batches: self.batches.load(Relaxed),
            requests: self.requests.load(Relaxed),
            full_batches: self.full_batches.load(Relaxed),
            exec_errors: self.exec_errors.load(Relaxed),
            queue_depth: self.queue_depth.load(Relaxed),
            peak_queue_depth: self.peak_queue_depth.load(Relaxed),
            shed: self.shed.load(Relaxed),
            rejected: self.rejected.load(Relaxed),
            expired: self.expired.load(Relaxed),
        }
    }
}

impl BatcherSnapshot {
    /// The wire form served under `GET /v1/metrics` (every counter,
    /// stable key order) and embedded in bench-report queue sections.
    pub fn to_json(&self) -> crate::json::JsonValue {
        crate::json_obj! {
            "batches" => self.batches as usize,
            "requests" => self.requests as usize,
            "full_batches" => self.full_batches as usize,
            "exec_errors" => self.exec_errors as usize,
            "queue_depth" => self.queue_depth as usize,
            "peak_queue_depth" => self.peak_queue_depth as usize,
            "shed" => self.shed as usize,
            "rejected" => self.rejected as usize,
            "expired" => self.expired as usize,
        }
    }

    /// Accumulate another shard's snapshot into this one (the router's
    /// aggregate view). Counters and the live depth gauge sum;
    /// `peak_queue_depth` takes the per-shard maximum.
    pub fn merge(&mut self, other: &BatcherSnapshot) {
        self.batches += other.batches;
        self.requests += other.requests;
        self.full_batches += other.full_batches;
        self.exec_errors += other.exec_errors;
        self.queue_depth += other.queue_depth;
        self.peak_queue_depth = self.peak_queue_depth.max(other.peak_queue_depth);
        self.shed += other.shed;
        self.rejected += other.rejected;
        self.expired += other.expired;
    }
}

/// Queue shared between submit handles and the worker.
struct QueueState {
    deque: VecDeque<Request>,
    /// False once every [`Batcher`] handle has dropped; the worker
    /// drains what is left and exits.
    open: bool,
    /// True once the worker thread has exited (normally or by panic);
    /// further submits fail fast instead of feeding a dead queue.
    dead: bool,
}

struct Shared {
    q: Mutex<QueueState>,
    avail: Condvar,
    stats: Arc<BatcherStats>,
    policy: BatchPolicy,
    /// Sliding-window view of completed-request latency (queue + exec),
    /// recorded by the worker after each successful batch. The SLO
    /// dispatch seam reads its merged p99 as a pressure signal; the
    /// cumulative per-shard histogram the router keeps is too stale for
    /// control.
    recent: Mutex<WindowedHist>,
    /// Wall-clock origin for the window's microsecond time base.
    epoch: Instant,
}

/// Closes the queue when the last `Batcher` handle drops, so the worker
/// thread shuts down instead of leaking.
struct HandleGuard(Arc<Shared>);

impl Drop for HandleGuard {
    fn drop(&mut self) {
        // Poison recovery: shutdown must proceed even if a submitter
        // panicked while holding the queue lock.
        self.0.q.lock().unwrap_or_else(PoisonError::into_inner).open = false;
        self.0.avail.notify_all();
    }
}

/// Runs when the worker thread exits for any reason — including a
/// panic that escaped [`worker_loop`]'s per-batch containment. Marks
/// the queue dead (submits fail fast with a shutdown error) and drops
/// everything still queued, which drops those requests' reply senders
/// so their waiting callers unblock with "worker dropped the request"
/// instead of hanging forever.
struct WorkerGuard(Arc<Shared>);

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        // This guard exists to run on worker *panic* — the lock may
        // well be poisoned by the same panic; recover the guard, the
        // queue state is still structurally valid.
        let mut q = self.0.q.lock().unwrap_or_else(PoisonError::into_inner);
        q.dead = true;
        q.deque.clear();
        self.0.stats.queue_depth.store(0, Relaxed);
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Batcher {
    shared: Arc<Shared>,
    image_len: usize,
    _guard: Arc<HandleGuard>,
}

/// An in-flight request. Block for the outcome with
/// [`PendingReply::wait`], or poll it without blocking via
/// [`PendingReply::try_wait`] — the seam that lets one event-loop
/// thread carry thousands of in-flight requests instead of pinning a
/// blocked thread per request.
pub struct PendingReply {
    rx: Receiver<Result<Reply, BatchError>>,
    /// True once `try_wait` has yielded the terminal outcome; the
    /// channel then reads Disconnected, which must not be re-reported
    /// as a worker death.
    done: bool,
}

impl PendingReply {
    /// Block until the batch containing this request has executed (or
    /// the request was shed). Executor failures and overload sheds
    /// surface here with the underlying message.
    pub fn wait(self) -> Result<Reply> {
        match self.rx.recv() {
            Ok(Ok(reply)) => Ok(reply),
            Ok(Err(e)) => Err(anyhow::anyhow!("{e}")),
            Err(_) => Err(anyhow::anyhow!("{}", BatchError::Dropped)),
        }
    }

    /// Non-blocking completion poll: `None` while the request is still
    /// queued or executing, `Some` exactly once when the outcome is
    /// ready. A `PendingReply` is spent after yielding `Some`; polling
    /// it again reports [`BatchError::Dropped`] (the reply was already
    /// taken), so callers should drop it once resolved.
    pub fn try_wait(&mut self) -> Option<Result<Reply, BatchError>> {
        if self.done {
            return Some(Err(BatchError::Dropped));
        }
        match self.rx.try_recv() {
            Ok(outcome) => {
                self.done = true;
                Some(outcome)
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                self.done = true;
                Some(Err(BatchError::Dropped))
            }
        }
    }
}

impl Batcher {
    /// Spawn the worker thread. `image_len` is the per-request input
    /// length; `classes` the logits row width.
    pub fn spawn(
        policy: BatchPolicy,
        image_len: usize,
        classes: usize,
        execute: Box<ExecuteFn>,
        stats: Arc<BatcherStats>,
    ) -> Self {
        assert!(policy.max_batch >= 1, "max_batch must be >= 1");
        assert!(policy.max_queue_depth >= 1, "max_queue_depth must be >= 1");
        if let Some(limit) = policy.max_queue_wait {
            assert!(
                limit > policy.max_wait,
                "max_queue_wait ({limit:?}) must exceed max_wait ({:?}): queue age includes \
                 the deliberate batch-fill window, so a smaller deadline sheds all traffic",
                policy.max_wait
            );
        }
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState { deque: VecDeque::new(), open: true, dead: false }),
            avail: Condvar::new(),
            stats,
            policy,
            recent: Mutex::new(WindowedHist::new(RECENT_WINDOW_US, RECENT_SLICES)),
            epoch: Instant::now(),
        });
        let worker_shared = shared.clone();
        std::thread::spawn(move || {
            let _on_exit = WorkerGuard(worker_shared.clone());
            worker_loop(worker_shared, image_len, classes, execute);
        });
        Self { shared: shared.clone(), image_len, _guard: Arc::new(HandleGuard(shared)) }
    }

    /// Enqueue one image without blocking for the result. Returns the
    /// overload error immediately when the bounded queue is full under
    /// [`OverloadPolicy::RejectNewest`].
    pub fn submit(&self, image: Vec<f32>) -> Result<PendingReply> {
        anyhow::ensure!(
            image.len() == self.image_len,
            "image length {} != {}",
            image.len(),
            self.image_len
        );
        let (reply_tx, reply_rx) = channel();
        let req = Request { image, enqueued: Instant::now(), reply: reply_tx };
        let policy = &self.shared.policy;
        let stats = &self.shared.stats;
        let mut shed_victim = None;
        {
            let mut q = self.shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            if q.dead {
                anyhow::bail!("batcher worker has shut down");
            }
            if q.deque.len() >= policy.max_queue_depth {
                match policy.overload {
                    OverloadPolicy::RejectNewest => {
                        stats.rejected.fetch_add(1, Relaxed);
                        anyhow::bail!(
                            "batcher overloaded: queue depth {} is at the limit {} \
                             (reject-newest); retry later or raise max_queue_depth",
                            q.deque.len(),
                            policy.max_queue_depth
                        );
                    }
                    OverloadPolicy::ShedOldest => {
                        if let Some(oldest) = q.deque.pop_front() {
                            stats.shed.fetch_add(1, Relaxed);
                            shed_victim = Some(oldest);
                        }
                    }
                }
            }
            q.deque.push_back(req);
            let depth = q.deque.len() as u64;
            stats.queue_depth.store(depth, Relaxed);
            stats.peak_queue_depth.fetch_max(depth, Relaxed);
        }
        self.shared.avail.notify_one();
        // The shed caller is answered after the queue lock is released:
        // waking another thread's channel receiver is not work to do
        // under the hot submit lock.
        if let Some(oldest) = shed_victim {
            let _ = oldest.reply.send(Err(BatchError::Shed(format!(
                "batcher overloaded: request shed from the queue head after \
                 {:?} waiting (shed-oldest, depth limit {})",
                oldest.enqueued.elapsed(),
                policy.max_queue_depth
            ))));
        }
        Ok(PendingReply { rx: reply_rx, done: false })
    }

    /// Submit one image; blocks until the reply arrives. Executor
    /// failures and overload errors surface here with the underlying
    /// message.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply> {
        self.submit(image)?.wait()
    }

    /// Live stats handle (shared with the worker).
    pub fn stats(&self) -> Arc<BatcherStats> {
        self.shared.stats.clone()
    }

    /// Merged view of the sliding latency window right now: roughly the
    /// last [`RECENT_WINDOW_US`] of completed-request latencies
    /// (queue + exec). Reading advances the ring, so an idle shard's
    /// window drains to empty — recent p99 recovers as pressure clears,
    /// which is what makes it usable as an SLO control signal.
    pub fn recent_hist(&self) -> LatencyHist {
        let now_us = self.shared.epoch.elapsed().as_micros() as u64;
        self.shared
            .recent
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .merged_at(now_us)
    }
}

/// Pop everything currently queued (up to `max_batch` total in
/// `pending`) and refresh the depth gauge. Call with the lock held.
fn drain_into(
    q: &mut QueueState,
    pending: &mut Vec<Request>,
    max_batch: usize,
    stats: &BatcherStats,
) {
    while pending.len() < max_batch {
        match q.deque.pop_front() {
            Some(r) => pending.push(r),
            None => break,
        }
    }
    stats.queue_depth.store(q.deque.len() as u64, Relaxed);
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_loop(shared: Arc<Shared>, image_len: usize, classes: usize, mut execute: Box<ExecuteFn>) {
    let policy = shared.policy;
    let mut pending: Vec<Request> = Vec::with_capacity(policy.max_batch);
    // Hoisted: one packing buffer for the worker's lifetime.
    let mut buf: Vec<f32> = Vec::with_capacity(policy.max_batch * image_len);
    loop {
        // Block for the first request of a batch (or shutdown: queue
        // closed and fully drained).
        {
            // Poison recovery throughout the worker: a panicking
            // submitter must degrade that one request, not wedge the
            // whole shard's worker thread.
            let mut q = shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if !q.deque.is_empty() {
                    break;
                }
                if !q.open {
                    return;
                }
                q = shared.avail.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
            drain_into(&mut q, &mut pending, policy.max_batch, &shared.stats);
        }
        // Admit until full or the oldest request's deadline.
        while pending.len() < policy.max_batch {
            let elapsed = pending[0].enqueued.elapsed();
            let Some(budget) = policy.max_wait.checked_sub(elapsed) else { break };
            let mut q = shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            if q.deque.is_empty() {
                if !q.open {
                    break;
                }
                let (guard, timeout) =
                    shared.avail.wait_timeout(q, budget).unwrap_or_else(PoisonError::into_inner);
                q = guard;
                if q.deque.is_empty() && timeout.timed_out() {
                    break;
                }
            }
            drain_into(&mut q, &mut pending, policy.max_batch, &shared.stats);
        }
        let mut batch = std::mem::take(&mut pending);
        // Deadline shed at batch-build time: requests that aged past
        // max_queue_wait behind a slow executor are answered with a
        // typed shed error instead of burning compute on a reply the
        // caller has likely abandoned.
        if let Some(limit) = policy.max_queue_wait {
            let before = batch.len();
            batch.retain(|r| {
                let waited = r.enqueued.elapsed();
                if waited <= limit {
                    return true;
                }
                let _ = r.reply.send(Err(BatchError::Shed(format!(
                    "request expired after {waited:?} queued (max_queue_wait {limit:?}); \
                     shed before execution"
                ))));
                false
            });
            let expired = (before - batch.len()) as u64;
            if expired > 0 {
                shared.stats.expired.fetch_add(expired, Relaxed);
            }
            if batch.is_empty() {
                continue;
            }
        }
        let bsz = batch.len();
        buf.clear();
        for r in &batch {
            buf.extend_from_slice(&r.image);
        }
        // True-size execution: no padded rows, no padded compute. A
        // panicking executor is contained to this batch (its callers
        // get the panic message as an error) so the worker — and every
        // request queued behind the bad batch — survives.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            execute(&buf, bsz)
        }));
        let outcome: Result<Vec<f32>, String> = match caught {
            Ok(Ok(logits)) if logits.len() >= bsz * classes => Ok(logits),
            Ok(Ok(logits)) => Err(format!(
                "executor returned {} logits for a batch of {bsz} (need {})",
                logits.len(),
                bsz * classes
            )),
            Ok(Err(e)) => Err(e.to_string()),
            Err(payload) => Err(format!("executor panicked: {}", panic_message(&payload))),
        };
        shared.stats.batches.fetch_add(1, Relaxed);
        shared.stats.requests.fetch_add(bsz as u64, Relaxed);
        if bsz == policy.max_batch {
            shared.stats.full_batches.fetch_add(1, Relaxed);
        }
        if outcome.is_err() {
            shared.stats.exec_errors.fetch_add(1, Relaxed);
        }
        match outcome {
            Ok(logits) => {
                // Feed the sliding-window latency view in one scoped
                // lock; the guard must be gone before the reply sends
                // below (channel sends block).
                {
                    let now_us = shared.epoch.elapsed().as_micros() as u64;
                    let mut recent =
                        shared.recent.lock().unwrap_or_else(PoisonError::into_inner);
                    for r in &batch {
                        recent.record_at(now_us, r.enqueued.elapsed());
                    }
                }
                for (i, r) in batch.into_iter().enumerate() {
                    let row = logits[i * classes..(i + 1) * classes].to_vec();
                    let _ = r.reply.send(Ok(Reply {
                        logits: row,
                        queue_time: r.enqueued.elapsed(),
                        batch_size: bsz,
                    }));
                }
            }
            Err(msg) => {
                // Carry the real failure to every caller of this
                // batch instead of dropping the reply channels.
                for r in batch {
                    let _ = r.reply.send(Err(BatchError::Exec(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_echo(policy: BatchPolicy) -> (Batcher, Arc<BatcherStats>) {
        let stats = Arc::new(BatcherStats::default());
        // "model": logits = [sum(image), batch_marker]
        let b = Batcher::spawn(
            policy,
            4,
            2,
            Box::new(|buf, batch| {
                assert_eq!(buf.len(), batch * 4, "executor must see the true batch size");
                let mut out = Vec::new();
                for i in 0..batch {
                    let s: f32 = buf[i * 4..(i + 1) * 4].iter().sum();
                    out.push(s);
                    out.push(batch as f32);
                }
                Ok(out)
            }),
            stats.clone(),
        );
        (b, stats)
    }

    /// A batcher whose executor blocks until a token arrives on `gate`,
    /// signalling `entered` first — lets tests park the worker mid-batch
    /// and fill the queue deterministically.
    fn spawn_gated(policy: BatchPolicy) -> (Batcher, Arc<BatcherStats>, Sender<()>, Receiver<()>) {
        let stats = Arc::new(BatcherStats::default());
        let (gate_tx, gate_rx) = channel::<()>();
        let (entered_tx, entered_rx) = channel::<()>();
        let b = Batcher::spawn(
            policy,
            1,
            1,
            Box::new(move |buf, bsz| {
                entered_tx.send(()).ok();
                gate_rx.recv().ok();
                Ok(buf[..bsz].to_vec())
            }),
            stats.clone(),
        );
        (b, stats, gate_tx, entered_rx)
    }

    #[test]
    fn single_request_flushes_on_deadline() {
        let (b, stats) = spawn_echo(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            ..BatchPolicy::default()
        });
        let r = b.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(r.logits[0], 10.0);
        assert_eq!(r.batch_size, 1);
        // true-size execution: the executor's batch marker equals 1, not
        // the padded hardware batch
        assert_eq!(r.logits[1], 1.0);
        assert_eq!(stats.batches.load(Relaxed), 1);
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let (b, stats) = spawn_echo(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            ..BatchPolicy::default()
        });
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let b = b.clone();
                std::thread::spawn(move || b.infer(vec![i as f32; 4]).unwrap())
            })
            .collect();
        let replies: Vec<Reply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (i, r) in replies.iter().enumerate() {
            assert_eq!(r.logits[0], 4.0 * i as f32);
        }
        let s = stats.snapshot();
        assert_eq!(s.requests, 8);
        assert!(s.batches <= 4, "8 requests should pack into few batches, got {}", s.batches);
        assert_eq!(s.queue_depth, 0, "queue must drain back to empty");
    }

    #[test]
    fn rejects_wrong_image_len() {
        let (b, _) = spawn_echo(BatchPolicy::default());
        assert!(b.infer(vec![0.0; 3]).is_err());
    }

    #[test]
    fn executor_error_reaches_every_caller_with_message() {
        let stats = Arc::new(BatcherStats::default());
        let b = Batcher::spawn(
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(20),
                ..BatchPolicy::default()
            },
            2,
            1,
            Box::new(|_buf, _batch| Err(anyhow::anyhow!("kernel exploded at layer 3"))),
            stats.clone(),
        );
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let b = b.clone();
                std::thread::spawn(move || b.infer(vec![i as f32, 0.0]).unwrap_err().to_string())
            })
            .collect();
        for h in handles {
            let msg = h.join().unwrap();
            assert!(
                msg.contains("kernel exploded at layer 3"),
                "root cause missing from `{msg}`"
            );
        }
        assert!(stats.exec_errors.load(Relaxed) >= 1);
    }

    #[test]
    fn short_logits_vector_is_an_error_not_a_panic() {
        let stats = Arc::new(BatcherStats::default());
        let b = Batcher::spawn(
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(2),
                ..BatchPolicy::default()
            },
            1,
            3,
            Box::new(|_buf, _batch| Ok(vec![0.0])), // too short
            stats.clone(),
        );
        let msg = b.infer(vec![1.0]).unwrap_err().to_string();
        assert!(msg.contains("need 3"), "{msg}");
        // malformed output counts as an execution error in the stats
        assert_eq!(stats.exec_errors.load(Relaxed), 1);
    }

    #[test]
    fn stateful_executor_reuses_buffers() {
        // FnMut executor owning scratch: counts calls without realloc.
        let stats = Arc::new(BatcherStats::default());
        let mut calls = 0u32;
        let mut scratch: Vec<f32> = Vec::new();
        let b = Batcher::spawn(
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            1,
            1,
            Box::new(move |buf, batch| {
                calls += 1;
                scratch.clear();
                scratch.extend_from_slice(buf);
                Ok(scratch.iter().take(batch).map(|v| v + calls as f32).collect())
            }),
            stats,
        );
        let r1 = b.infer(vec![10.0]).unwrap();
        let r2 = b.infer(vec![10.0]).unwrap();
        assert_eq!(r1.logits[0], 11.0);
        assert_eq!(r2.logits[0], 12.0);
    }

    #[test]
    fn reject_newest_returns_descriptive_overload_error() {
        let (b, stats, gate, entered) = spawn_gated(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            max_queue_depth: 2,
            overload: OverloadPolicy::RejectNewest,
            ..BatchPolicy::default()
        });
        // Park the worker inside execute() so the queue state is ours.
        let a = b.submit(vec![1.0]).unwrap();
        entered.recv().unwrap();
        let c = b.submit(vec![2.0]).unwrap(); // depth 1
        let d = b.submit(vec![3.0]).unwrap(); // depth 2 == limit
        let err = b.submit(vec![4.0]).unwrap_err().to_string();
        assert!(err.contains("overloaded"), "not a descriptive overload error: {err}");
        assert!(err.contains("limit 2"), "limit missing from error: {err}");
        let s = stats.snapshot();
        assert_eq!((s.rejected, s.shed, s.queue_depth), (1, 0, 2));
        // Everyone admitted still completes, in order, once released.
        for _ in 0..3 {
            gate.send(()).unwrap();
        }
        assert_eq!(a.wait().unwrap().logits[0], 1.0);
        assert_eq!(c.wait().unwrap().logits[0], 2.0);
        assert_eq!(d.wait().unwrap().logits[0], 3.0);
        assert_eq!(stats.snapshot().peak_queue_depth, 2);
    }

    #[test]
    fn shed_oldest_errors_the_oldest_waiter_and_admits_the_newest() {
        let (b, stats, gate, entered) = spawn_gated(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            max_queue_depth: 2,
            overload: OverloadPolicy::ShedOldest,
            ..BatchPolicy::default()
        });
        let a = b.submit(vec![1.0]).unwrap();
        entered.recv().unwrap();
        let c = b.submit(vec![2.0]).unwrap(); // depth 1 — oldest queued
        let d = b.submit(vec![3.0]).unwrap(); // depth 2 == limit
        let e = b.submit(vec![4.0]).unwrap(); // sheds c, takes its place
        let s = stats.snapshot();
        assert_eq!((s.rejected, s.shed, s.queue_depth), (0, 1, 2));
        // The shed victim gets the overload error without waiting for
        // any execution; the in-flight request and the survivors finish.
        let msg = c.wait().unwrap_err().to_string();
        assert!(msg.contains("shed"), "shed victim got wrong error: {msg}");
        for _ in 0..3 {
            gate.send(()).unwrap();
        }
        assert_eq!(a.wait().unwrap().logits[0], 1.0);
        assert_eq!(d.wait().unwrap().logits[0], 3.0);
        assert_eq!(e.wait().unwrap().logits[0], 4.0);
    }

    #[test]
    fn executor_panic_becomes_an_error_and_the_worker_survives() {
        // A panic inside execute() must not kill the worker: the
        // panicking batch's caller gets the panic message as an error,
        // and the batcher keeps serving subsequent requests.
        let stats = Arc::new(BatcherStats::default());
        let mut first = true;
        let b = Batcher::spawn(
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            1,
            1,
            Box::new(move |buf, bsz| {
                if std::mem::take(&mut first) {
                    panic!("executor blew up at layer 7");
                }
                Ok(buf[..bsz].to_vec())
            }),
            stats.clone(),
        );
        let msg = b.infer(vec![1.0]).unwrap_err().to_string();
        assert!(msg.contains("executor blew up at layer 7"), "{msg}");
        assert_eq!(stats.exec_errors.load(Relaxed), 1);
        // the worker survived and the queue is not dead
        assert_eq!(b.infer(vec![2.0]).unwrap().logits[0], 2.0);
    }

    #[test]
    fn burst_traffic_is_bounded_and_fully_accounted() {
        // 16 client threads x 16 requests against a slow executor and a
        // tiny queue: every request either completes or fails with the
        // overload error, the depth never exceeds the bound (no OOM
        // growth), and the books balance exactly.
        let stats = Arc::new(BatcherStats::default());
        let depth = 4u64;
        let b = Batcher::spawn(
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_micros(100),
                max_queue_depth: depth as usize,
                overload: OverloadPolicy::RejectNewest,
                ..BatchPolicy::default()
            },
            1,
            1,
            Box::new(|buf, bsz| {
                std::thread::sleep(Duration::from_micros(300));
                Ok(buf[..bsz].to_vec())
            }),
            stats.clone(),
        );
        let (clients, per) = (16usize, 16usize);
        let handles: Vec<_> = (0..clients)
            .map(|i| {
                let b = b.clone();
                std::thread::spawn(move || {
                    let mut ok = 0u64;
                    for j in 0..per {
                        match b.infer(vec![(i * per + j) as f32]) {
                            Ok(r) => {
                                assert_eq!(r.logits[0], (i * per + j) as f32);
                                ok += 1;
                            }
                            Err(e) => {
                                let msg = e.to_string();
                                assert!(
                                    msg.contains("overloaded"),
                                    "burst failure was not an overload error: {msg}"
                                );
                            }
                        }
                    }
                    ok
                })
            })
            .collect();
        let completed: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let s = stats.snapshot();
        assert!(s.peak_queue_depth <= depth, "queue grew past the bound: {s:?}");
        assert_eq!(s.requests, completed, "executed requests vs successful replies");
        assert_eq!(
            s.requests + s.rejected,
            (clients * per) as u64,
            "every request must be either executed or rejected: {s:?}"
        );
        assert_eq!(s.shed, 0);
    }

    #[test]
    fn recent_hist_tracks_completed_requests() {
        let (b, _stats) = spawn_echo(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_micros(200),
            ..BatchPolicy::default()
        });
        assert_eq!(b.recent_hist().count(), 0, "idle batcher has an empty window");
        for i in 0..3 {
            b.infer(vec![i as f32; 4]).unwrap();
        }
        let h = b.recent_hist();
        assert_eq!(h.count(), 3, "every completed request lands in the window");
        // e2e latency includes the deliberate batch-fill wait, so the
        // recorded values are nonzero µs.
        assert!(h.max_us() > 0);
        assert!(h.quantile_us(0.99) >= h.quantile_us(0.5));
    }

    /// Poll a pending reply until it resolves, failing after a deadline
    /// so a wedged worker can't hang the test suite.
    fn poll_until_ready(p: &mut PendingReply) -> Result<Reply, BatchError> {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if let Some(outcome) = p.try_wait() {
                return outcome;
            }
            assert!(Instant::now() < deadline, "try_wait never became ready");
            std::thread::sleep(Duration::from_micros(50));
        }
    }

    #[test]
    fn try_wait_is_pending_then_ready_exactly_once() {
        let (b, _stats, gate, entered) = spawn_gated(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        });
        let mut p = b.submit(vec![7.0]).unwrap();
        entered.recv().unwrap(); // worker parked inside execute()
        assert!(p.try_wait().is_none(), "ready before the executor finished");
        assert!(p.try_wait().is_none(), "pending poll must be repeatable");
        gate.send(()).unwrap();
        let reply = poll_until_ready(&mut p).expect("gated echo should succeed");
        assert_eq!(reply.logits[0], 7.0);
        // Spent: the outcome was taken once; polling again is a typed
        // Dropped, not a hang, a panic, or a phantom second reply.
        assert!(matches!(p.try_wait(), Some(Err(BatchError::Dropped))));
    }

    #[test]
    fn try_wait_surfaces_typed_exec_and_shed_errors() {
        // Execution failure: typed Exec with the real message.
        let stats = Arc::new(BatcherStats::default());
        let b = Batcher::spawn(
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                ..BatchPolicy::default()
            },
            1,
            1,
            Box::new(|_buf, _bsz| Err(anyhow::anyhow!("device fell over"))),
            stats,
        );
        let mut p = b.submit(vec![1.0]).unwrap();
        match poll_until_ready(&mut p) {
            Err(BatchError::Exec(msg)) => assert!(msg.contains("device fell over"), "{msg}"),
            other => panic!("expected typed Exec error, got {other:?}"),
        }

        // Overload shed: typed Shed on the victim, no execution.
        let (b, stats, gate, entered) = spawn_gated(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            max_queue_depth: 1,
            overload: OverloadPolicy::ShedOldest,
            ..BatchPolicy::default()
        });
        let a = b.submit(vec![1.0]).unwrap();
        entered.recv().unwrap();
        let mut victim = b.submit(vec![2.0]).unwrap(); // queued, depth 1 == limit
        let survivor = b.submit(vec![3.0]).unwrap(); // sheds `victim`
        match victim.try_wait() {
            Some(Err(BatchError::Shed(msg))) => assert!(msg.contains("shed"), "{msg}"),
            other => panic!("expected typed Shed error, got {other:?}"),
        }
        gate.send(()).unwrap();
        gate.send(()).unwrap();
        assert_eq!(a.wait().unwrap().logits[0], 1.0);
        assert_eq!(survivor.wait().unwrap().logits[0], 3.0);
        assert_eq!(stats.snapshot().shed, 1);
    }

    #[test]
    fn max_queue_wait_sheds_stale_requests_at_batch_build() {
        let limit = Duration::from_millis(30);
        let (b, stats, gate, entered) = spawn_gated(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_queue_wait: Some(limit),
            ..BatchPolicy::default()
        });
        // `a` enters execution immediately (fresh — not shed); `stale`
        // then ages in the queue behind the parked executor.
        let a = b.submit(vec![1.0]).unwrap();
        entered.recv().unwrap();
        let stale = b.submit(vec![2.0]).unwrap();
        std::thread::sleep(limit + Duration::from_millis(40));
        gate.send(()).unwrap(); // release `a`
        assert_eq!(a.wait().unwrap().logits[0], 1.0);
        // The next batch build finds `stale` over-age and sheds it with
        // a descriptive typed error instead of executing it.
        let msg = stale.wait().unwrap_err().to_string();
        assert!(msg.contains("expired"), "not a deadline shed error: {msg}");
        assert!(msg.contains("max_queue_wait"), "limit missing from error: {msg}");
        // Fresh traffic afterwards is unaffected.
        let fresh = b.submit(vec![3.0]).unwrap();
        entered.recv().unwrap();
        gate.send(()).unwrap();
        assert_eq!(fresh.wait().unwrap().logits[0], 3.0);
        let s = stats.snapshot();
        assert_eq!(s.expired, 1, "deadline shed must land in the expired counter: {s:?}");
        assert_eq!(s.shed, 0, "deadline sheds must not count as overload sheds");
        assert_eq!(s.requests, 2, "only executed requests count: {s:?}");
    }
}
