//! Dynamic batcher — forms execution batches from an asynchronous
//! request stream (the vLLM-router pattern scaled to this repo).
//!
//! The lowered HLO has a fixed batch dimension B, so the batcher's job
//! is: collect up to B requests, or whatever arrived when the oldest
//! request hits its latency deadline; pad the tail of a short batch by
//! repeating the last image (padded outputs are discarded); execute;
//! scatter per-request results. Threads + channels, no async runtime —
//! tokio is not in this image's vendored set, and one worker thread per
//! model is the right shape for a single-device PJRT client anyway.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Hardware batch (the HLO's lowered batch dimension).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before a (possibly
    /// short) batch is launched.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 64, max_wait: Duration::from_millis(5) }
    }
}

/// One queued inference request.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Reply>,
}

/// Per-request result: logits row + timing.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub queue_time: Duration,
    pub batch_size: usize,
}

/// The batch executor supplied by the server: takes a padded image
/// buffer `[max_batch, ...]` and returns row-major logits.
pub type ExecuteFn = dyn Fn(&[f32], usize) -> Result<Vec<f32>> + Send;

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Batcher {
    tx: Sender<Request>,
    image_len: usize,
}

/// Statistics the worker exposes.
#[derive(Default, Debug)]
pub struct BatcherStats {
    pub batches: u64,
    pub requests: u64,
    pub full_batches: u64,
}

impl Batcher {
    /// Spawn the worker thread. `image_len` is the per-request input
    /// length; `classes` the logits row width.
    pub fn spawn(
        policy: BatchPolicy,
        image_len: usize,
        classes: usize,
        execute: Box<ExecuteFn>,
        stats: Arc<Mutex<BatcherStats>>,
    ) -> Self {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        std::thread::spawn(move || {
            let mut pending: Vec<Request> = Vec::with_capacity(policy.max_batch);
            loop {
                // Block for the first request of a batch.
                if pending.is_empty() {
                    match rx.recv() {
                        Ok(r) => pending.push(r),
                        Err(_) => return, // all senders dropped: shut down
                    }
                }
                // Admit until full or the oldest request's deadline.
                while pending.len() < policy.max_batch {
                    let elapsed = pending[0].enqueued.elapsed();
                    let Some(budget) = policy.max_wait.checked_sub(elapsed) else { break };
                    match rx.recv_timeout(budget) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                let batch = std::mem::take(&mut pending);
                let bsz = batch.len();
                // Pad to max_batch by repeating the last image.
                let mut buf = Vec::with_capacity(policy.max_batch * image_len);
                for r in &batch {
                    buf.extend_from_slice(&r.image);
                }
                for _ in bsz..policy.max_batch {
                    let last = buf[(bsz - 1) * image_len..bsz * image_len].to_vec();
                    buf.extend_from_slice(&last);
                }
                let result = execute(&buf, policy.max_batch);
                {
                    let mut s = stats.lock().unwrap();
                    s.batches += 1;
                    s.requests += bsz as u64;
                    if bsz == policy.max_batch {
                        s.full_batches += 1;
                    }
                }
                match result {
                    Ok(logits) => {
                        for (i, r) in batch.into_iter().enumerate() {
                            let row = logits[i * classes..(i + 1) * classes].to_vec();
                            let _ = r.reply.send(Reply {
                                logits: row,
                                queue_time: r.enqueued.elapsed(),
                                batch_size: bsz,
                            });
                        }
                    }
                    Err(_) => {
                        // Drop the replies; senders observe a closed
                        // channel and surface an error upstream.
                        drop(batch);
                    }
                }
            }
        });
        Self { tx, image_len }
    }

    /// Submit one image; blocks until the reply arrives.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply> {
        anyhow::ensure!(
            image.len() == self.image_len,
            "image length {} != {}",
            image.len(),
            self.image_len
        );
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request { image, enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("batcher worker has shut down"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("batch execution failed"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_echo(policy: BatchPolicy) -> (Batcher, Arc<Mutex<BatcherStats>>) {
        let stats = Arc::new(Mutex::new(BatcherStats::default()));
        // "model": logits = [sum(image), batch_marker]
        let b = Batcher::spawn(
            policy,
            4,
            2,
            Box::new(|buf, batch| {
                let mut out = Vec::new();
                for i in 0..batch {
                    let s: f32 = buf[i * 4..(i + 1) * 4].iter().sum();
                    out.push(s);
                    out.push(batch as f32);
                }
                Ok(out)
            }),
            stats.clone(),
        );
        (b, stats)
    }

    #[test]
    fn single_request_flushes_on_deadline() {
        let (b, stats) = spawn_echo(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        });
        let r = b.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(r.logits[0], 10.0);
        assert_eq!(r.batch_size, 1);
        assert_eq!(stats.lock().unwrap().batches, 1);
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let (b, stats) = spawn_echo(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        });
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let b = b.clone();
                std::thread::spawn(move || b.infer(vec![i as f32; 4]).unwrap())
            })
            .collect();
        let replies: Vec<Reply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (i, r) in replies.iter().enumerate() {
            assert_eq!(r.logits[0], 4.0 * i as f32);
        }
        let s = stats.lock().unwrap();
        assert_eq!(s.requests, 8);
        assert!(s.batches <= 4, "8 requests should pack into few batches, got {}", s.batches);
    }

    #[test]
    fn rejects_wrong_image_len() {
        let (b, _) = spawn_echo(BatchPolicy::default());
        assert!(b.infer(vec![0.0; 3]).is_err());
    }
}
