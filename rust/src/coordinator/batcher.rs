//! Dynamic batcher — forms execution batches from an asynchronous
//! request stream (the vLLM-router pattern scaled to this repo).
//!
//! The batcher collects up to `max_batch` requests, or whatever arrived
//! when the oldest request hits its latency deadline, then executes the
//! batch **at its true size**: the executor receives the packed images
//! for exactly `bsz` requests plus `bsz` itself. Executors with a fixed
//! lowered batch dimension (the PJRT path) pad internally at the last
//! possible layer; the native engine executes short batches without any
//! padded compute. Per-request results are scattered back, and executor
//! failures are carried to every waiting `infer` caller with the real
//! underlying message. Threads + channels, no async runtime — tokio is
//! not in this image's vendored set, and one worker thread per model is
//! the right shape for a single-device backend anyway.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum formed batch (for PJRT executors: the HLO's lowered batch
    /// dimension).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before a (possibly
    /// short) batch is launched.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 64, max_wait: Duration::from_millis(5) }
    }
}

/// One queued inference request.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Result<Reply, String>>,
}

/// Per-request result: logits row + timing.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub queue_time: Duration,
    pub batch_size: usize,
}

/// The batch executor supplied by the server: receives the packed image
/// buffer for the *actual* batch (`bsz * image_len` floats) and `bsz`,
/// and returns at least `bsz` row-major logits rows. `FnMut` so an
/// executor can own reusable state (engine scratch, padding buffers).
pub type ExecuteFn = dyn FnMut(&[f32], usize) -> Result<Vec<f32>> + Send;

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Batcher {
    tx: Sender<Request>,
    image_len: usize,
}

/// Statistics the worker exposes.
#[derive(Default, Debug)]
pub struct BatcherStats {
    pub batches: u64,
    pub requests: u64,
    pub full_batches: u64,
    /// Batches whose execution failed — executor errors and malformed
    /// (too-short) logits alike, each surfaced to all of that batch's
    /// callers.
    pub exec_errors: u64,
}

impl Batcher {
    /// Spawn the worker thread. `image_len` is the per-request input
    /// length; `classes` the logits row width.
    pub fn spawn(
        policy: BatchPolicy,
        image_len: usize,
        classes: usize,
        mut execute: Box<ExecuteFn>,
        stats: Arc<Mutex<BatcherStats>>,
    ) -> Self {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        std::thread::spawn(move || {
            let mut pending: Vec<Request> = Vec::with_capacity(policy.max_batch);
            // Hoisted: one packing buffer for the worker's lifetime.
            let mut buf: Vec<f32> = Vec::with_capacity(policy.max_batch * image_len);
            loop {
                // Block for the first request of a batch.
                if pending.is_empty() {
                    match rx.recv() {
                        Ok(r) => pending.push(r),
                        Err(_) => return, // all senders dropped: shut down
                    }
                }
                // Admit until full or the oldest request's deadline.
                while pending.len() < policy.max_batch {
                    let elapsed = pending[0].enqueued.elapsed();
                    let Some(budget) = policy.max_wait.checked_sub(elapsed) else { break };
                    match rx.recv_timeout(budget) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                let batch = std::mem::take(&mut pending);
                let bsz = batch.len();
                buf.clear();
                for r in &batch {
                    buf.extend_from_slice(&r.image);
                }
                // True-size execution: no padded rows, no padded compute.
                let outcome: Result<Vec<f32>, String> = match execute(&buf, bsz) {
                    Ok(logits) if logits.len() >= bsz * classes => Ok(logits),
                    Ok(logits) => Err(format!(
                        "executor returned {} logits for a batch of {bsz} (need {})",
                        logits.len(),
                        bsz * classes
                    )),
                    Err(e) => Err(e.to_string()),
                };
                {
                    let mut s = stats.lock().unwrap();
                    s.batches += 1;
                    s.requests += bsz as u64;
                    if bsz == policy.max_batch {
                        s.full_batches += 1;
                    }
                    if outcome.is_err() {
                        s.exec_errors += 1;
                    }
                }
                match outcome {
                    Ok(logits) => {
                        for (i, r) in batch.into_iter().enumerate() {
                            let row = logits[i * classes..(i + 1) * classes].to_vec();
                            let _ = r.reply.send(Ok(Reply {
                                logits: row,
                                queue_time: r.enqueued.elapsed(),
                                batch_size: bsz,
                            }));
                        }
                    }
                    Err(msg) => {
                        // Carry the real failure to every caller of this
                        // batch instead of dropping the reply channels.
                        for r in batch {
                            let _ = r.reply.send(Err(msg.clone()));
                        }
                    }
                }
            }
        });
        Self { tx, image_len }
    }

    /// Submit one image; blocks until the reply arrives. Executor
    /// failures surface here with the underlying message.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply> {
        anyhow::ensure!(
            image.len() == self.image_len,
            "image length {} != {}",
            image.len(),
            self.image_len
        );
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request { image, enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("batcher worker has shut down"))?;
        match reply_rx.recv() {
            Ok(Ok(reply)) => Ok(reply),
            Ok(Err(msg)) => Err(anyhow::anyhow!("batch execution failed: {msg}")),
            Err(_) => Err(anyhow::anyhow!("batcher worker dropped the request")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_echo(policy: BatchPolicy) -> (Batcher, Arc<Mutex<BatcherStats>>) {
        let stats = Arc::new(Mutex::new(BatcherStats::default()));
        // "model": logits = [sum(image), batch_marker]
        let b = Batcher::spawn(
            policy,
            4,
            2,
            Box::new(|buf, batch| {
                assert_eq!(buf.len(), batch * 4, "executor must see the true batch size");
                let mut out = Vec::new();
                for i in 0..batch {
                    let s: f32 = buf[i * 4..(i + 1) * 4].iter().sum();
                    out.push(s);
                    out.push(batch as f32);
                }
                Ok(out)
            }),
            stats.clone(),
        );
        (b, stats)
    }

    #[test]
    fn single_request_flushes_on_deadline() {
        let (b, stats) = spawn_echo(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        });
        let r = b.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(r.logits[0], 10.0);
        assert_eq!(r.batch_size, 1);
        // true-size execution: the executor's batch marker equals 1, not
        // the padded hardware batch
        assert_eq!(r.logits[1], 1.0);
        assert_eq!(stats.lock().unwrap().batches, 1);
    }

    #[test]
    fn concurrent_requests_share_batches() {
        let (b, stats) = spawn_echo(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        });
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let b = b.clone();
                std::thread::spawn(move || b.infer(vec![i as f32; 4]).unwrap())
            })
            .collect();
        let replies: Vec<Reply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (i, r) in replies.iter().enumerate() {
            assert_eq!(r.logits[0], 4.0 * i as f32);
        }
        let s = stats.lock().unwrap();
        assert_eq!(s.requests, 8);
        assert!(s.batches <= 4, "8 requests should pack into few batches, got {}", s.batches);
    }

    #[test]
    fn rejects_wrong_image_len() {
        let (b, _) = spawn_echo(BatchPolicy::default());
        assert!(b.infer(vec![0.0; 3]).is_err());
    }

    #[test]
    fn executor_error_reaches_every_caller_with_message() {
        let stats = Arc::new(Mutex::new(BatcherStats::default()));
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(20) },
            2,
            1,
            Box::new(|_buf, _batch| Err(anyhow::anyhow!("kernel exploded at layer 3"))),
            stats.clone(),
        );
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let b = b.clone();
                std::thread::spawn(move || b.infer(vec![i as f32, 0.0]).unwrap_err().to_string())
            })
            .collect();
        for h in handles {
            let msg = h.join().unwrap();
            assert!(
                msg.contains("kernel exploded at layer 3"),
                "root cause missing from `{msg}`"
            );
        }
        assert!(stats.lock().unwrap().exec_errors >= 1);
    }

    #[test]
    fn short_logits_vector_is_an_error_not_a_panic() {
        let stats = Arc::new(Mutex::new(BatcherStats::default()));
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) },
            1,
            3,
            Box::new(|_buf, _batch| Ok(vec![0.0])), // too short
            stats.clone(),
        );
        let msg = b.infer(vec![1.0]).unwrap_err().to_string();
        assert!(msg.contains("need 3"), "{msg}");
        // malformed output counts as an execution error in the stats
        assert_eq!(stats.lock().unwrap().exec_errors, 1);
    }

    #[test]
    fn stateful_executor_reuses_buffers() {
        // FnMut executor owning scratch: counts calls without realloc.
        let stats = Arc::new(Mutex::new(BatcherStats::default()));
        let mut calls = 0u32;
        let mut scratch: Vec<f32> = Vec::new();
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
            1,
            1,
            Box::new(move |buf, batch| {
                calls += 1;
                scratch.clear();
                scratch.extend_from_slice(buf);
                Ok(scratch.iter().take(batch).map(|v| v + calls as f32).collect())
            }),
            stats,
        );
        let r1 = b.infer(vec![10.0]).unwrap();
        let r2 = b.infer(vec![10.0]).unwrap();
        assert_eq!(r1.logits[0], 11.0);
        assert_eq!(r2.logits[0], 12.0);
    }
}
