//! HTTP/1.1 front door on the inference router — the network edge of
//! the serving stack.
//!
//! One event-loop thread (no thread-per-connection, no async runtime —
//! the offline set has no tokio) accepts non-blocking TCP connections
//! through the vendored [`minipoll`] readiness loop, parses HTTP/1.1
//! with keep-alive, decodes JSON bodies with the depth-capped
//! [`crate::json`] parser, `submit`s into the
//! [`InferenceRouter`](super::router::InferenceRouter), and completes
//! responses by polling [`PendingReply::try_wait`] — so thousands of
//! in-flight requests cost zero parked threads.
//!
//! # Routes
//!
//! * `POST /v1/infer/{model}` — body `{"image": [f32; image_len]}` for
//!   one row or `{"images": [[…], …]}` for a micro-batch. Replies with
//!   the logits row(s), the executed batch size, queue time, and the
//!   variant that served the request. A **policy variant** is selected
//!   with a path suffix (`POST /v1/infer/{model}@{variant}`) or a
//!   `"variant"` field in the JSON body; without either the model's
//!   default variant serves — unless an SLO degradation ladder
//!   (`POST /v1/models/{model}/slo`) has degraded the model under
//!   load, in which case the ladder's current rung serves and the
//!   response's `"variant"` echo names it.
//! * `GET /v1/models` — the introspection surface: every model with
//!   its input shape, shared `param_bytes`, and per-variant resolved
//!   policy (full JSON encoding + display string + per-layer configs +
//!   policy-weighted footprint bits per activation), plus the variant's
//!   **version metadata**: serving `generation`, `weights_sha`,
//!   lifecycle `state` (`serving` / `canary` / `draining`) and the full
//!   rollout snapshot (canary progress, draining versions,
//!   per-generation served counters, last outcome/error).
//! * `POST /v1/models/{model}/reload` (or `{model}@{variant}`) — stage
//!   and roll out a new generation for one variant. The body names a
//!   `"source"` (`"policy"` with a policy JSON/preset, `"weights_npz"`
//!   with a path, or `"perturb"` with `seed`/`amplitude` for rollout
//!   drills) plus optional rollout knobs (`canary_share`,
//!   `promote_threshold`, `min_requests`). Validation is synchronous
//!   (unknown model/variant → 404 listing what exists, malformed body →
//!   400, rollout already in flight → 409, executor-backed variant →
//!   400); the expensive staging + rollout itself runs on a detached
//!   thread and the route answers **202** immediately — poll
//!   `GET /v1/models` to watch the canary promote or roll back.
//! * `POST /v1/models/{model}/slo` — install (body =
//!   [`SloPolicy`](super::slo::SloPolicy) JSON) or clear (empty body /
//!   `null` / `{"clear": true}`) the model's SLO degradation ladder.
//!   Installation is synchronous: **200** on success, 400 for policy
//!   or registry validation failures, 404 for unknown models.
//! * `POST /v1/models/{model}/autosearch` — launch a
//!   calibration-driven policy auto-search ([`crate::search`]) against
//!   the model's default variant. Optional body knobs: `floor`
//!   (agreement floor, default 0.99), `budget` (sweep eval budget,
//!   0 = unlimited), `ranked` (ACIQ-ordered visit, default true),
//!   `rows` (calibration rows, default 256) and `install` (default
//!   false; when true the winning policy is staged as a new generation
//!   through the reload path, its version tagged with `"search"`
//!   provenance). Answers **202**; the search runs on a detached
//!   thread and its phase/eval progress plus terminal outcome appear
//!   under the model's `"autosearch"` key on `GET /v1/metrics`.
//!   Calibration images are synthesized against the live weights — a
//!   stand-in until a real calibration set is wired to the server.
//! * `GET /v1/metrics` — per-variant, per-shard and aggregate
//!   [`RouterMetrics`](super::router::ModelMetrics) for every model,
//!   plus the router-wide aggregate, as JSON — including each model's
//!   `"slo"` ladder position (rung, serving variant,
//!   `time_degraded_us`, transition counters) and each variant's
//!   sliding-window `"recent_p99_us"`.
//! * `GET /healthz` — liveness plus the served model names.
//!
//! # Error mapping
//!
//! Backpressure is a status code, not a dropped connection: overload
//! from `RejectNewest`/`ShedOldest`/`max_queue_wait` maps to **503**
//! with the batcher's descriptive message; malformed requests (bad
//! framing, invalid or too-deep JSON, wrong image length) map to
//! **400** without killing the connection loop; unknown models *and
//! unknown variants* are **404**; execution failures are **500**; a
//! known route hit with the wrong method is **405 with an `Allow`
//! header** (not a 404 fall-through). A framing error the parser
//! cannot recover from closes that one connection after the error
//! response — never the accept loop.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context as _, Result};
use minipoll::{Event, Interest, Poller};

use crate::json::JsonValue;
use crate::json_obj;

use super::batcher::{BatchError, PendingReply, Reply};
use super::registry::{RolloutConfig, RolloutStatus, VersionProvenance};
use super::router::{InferenceRouter, ReloadSource, ReloadSpec};
use super::slo::SloPolicy;
use crate::quant::QuantPolicy;

/// Front-door limits. Defaults are sized for the native demo models;
/// raise `max_body_bytes` for large input tensors.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Concurrent connections; accepts past this are answered 503.
    pub max_connections: usize,
    /// Cap on the request line + headers.
    pub max_header_bytes: usize,
    /// Cap on `Content-Length` (bodies above it are answered 413).
    pub max_body_bytes: usize,
    /// Cap on rows per `images` micro-batch.
    pub max_batch_images: usize,
    /// Force the portable `poll(2)` backend instead of epoll.
    pub use_poll_fallback: bool,
}

impl Default for HttpConfig {
    fn default() -> Self {
        Self {
            max_connections: 1024,
            max_header_bytes: 16 * 1024,
            max_body_bytes: 4 * 1024 * 1024,
            max_batch_images: 64,
            use_poll_fallback: false,
        }
    }
}

/// Handle to a running front door. Dropping it (or calling
/// [`HttpServer::shutdown`]) stops the event loop and closes every
/// connection; the shared router keeps serving in-process callers.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// spawn the event-loop thread over `router`.
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        router: Arc<InferenceRouter>,
        cfg: HttpConfig,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding http server to {addr:?}"))?;
        listener.set_nonblocking(true).context("non-blocking listener")?;
        let local = listener.local_addr().context("listener local_addr")?;
        let mut poller = if cfg.use_poll_fallback {
            Poller::with_poll_backend()
        } else {
            Poller::new().context("creating readiness poller")?
        };
        poller
            .register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READABLE)
            .context("registering listener")?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let event_loop = EventLoop {
            listener,
            router,
            cfg,
            poller,
            conns: HashMap::new(),
            next_token: LISTENER_TOKEN + 1,
        };
        let join = std::thread::Builder::new()
            .name("sparq-http".into())
            .spawn(move || {
                if let Err(e) = event_loop.run(&flag) {
                    eprintln!("sparq-http event loop exited: {e}");
                }
            })
            .context("spawning http event loop thread")?;
        Ok(Self { addr: local, stop, join: Some(join) })
    }

    /// The bound address (resolves `:0` to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the event loop and wait for it to exit.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Relaxed);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

const LISTENER_TOKEN: u64 = 0;

/// One keep-alive connection's state machine. At any instant it is
/// reading a request, polling an in-flight inference, or draining a
/// response — the event loop drives all three without blocking.
struct Conn {
    stream: TcpStream,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    written: usize,
    /// Submitted inference whose replies are still being polled.
    inflight: Option<Inflight>,
    /// Keep-alive decision of the request currently being answered.
    keep_alive: bool,
    /// Close once the write buffer drains (Connection: close, or a
    /// framing error that poisoned the byte stream).
    close_after_write: bool,
    /// Whether the poller registration currently includes writable.
    want_write: bool,
    /// The peer half-closed its write side (read EOF). Requests already
    /// buffered or in flight are still answered — one-shot clients that
    /// `shutdown(Write)` after sending must get their response — and
    /// the connection is reaped once nothing is left to answer.
    peer_closed: bool,
    /// Fatal IO error or fully drained after `peer_closed`: reap.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Self {
            stream,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            written: 0,
            inflight: None,
            keep_alive: true,
            close_after_write: false,
            want_write: false,
            peer_closed: false,
            dead: false,
        }
    }

    /// Drain the socket into the read buffer (level-triggered: stop at
    /// WouldBlock). EOF marks the peer's write side closed; hard IO
    /// errors mark the connection dead.
    fn fill_read_buf(&mut self, cap: usize) {
        let mut chunk = [0u8; 4096];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.peer_closed = true;
                    return;
                }
                Ok(n) => {
                    self.read_buf.extend_from_slice(&chunk[..n]);
                    if self.read_buf.len() > cap {
                        // A legitimate request always fits under the
                        // configured caps; stop pulling more until the
                        // parser consumes (or 4xx-rejects) this one.
                        return;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    fn has_pending_write(&self) -> bool {
        self.written < self.write_buf.len()
    }

    /// Push queued response bytes out until WouldBlock or drained.
    fn flush_write_buf(&mut self) {
        while self.has_pending_write() {
            match self.stream.write(&self.write_buf[self.written..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => self.written += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        self.write_buf.clear();
        self.written = 0;
        if self.close_after_write {
            self.dead = true;
        }
    }

    fn queue_response(&mut self, status: u16, body: &JsonValue, keep_alive: bool) {
        self.queue_response_with(status, body, keep_alive, None);
    }

    /// `allow`: the `Allow` header value for 405 responses (RFC 9110
    /// requires it on Method Not Allowed).
    fn queue_response_with(
        &mut self,
        status: u16,
        body: &JsonValue,
        keep_alive: bool,
        allow: Option<&str>,
    ) {
        debug_assert!(!self.has_pending_write(), "response queued over an undrained one");
        self.close_after_write = !keep_alive;
        let payload = body.to_string();
        let allow_line = allow.map_or_else(String::new, |a| format!("Allow: {a}\r\n"));
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\
             {}Connection: {}\r\n\r\n",
            status,
            status_text(status),
            payload.len(),
            allow_line,
            if keep_alive { "keep-alive" } else { "close" },
        );
        self.write_buf.extend_from_slice(head.as_bytes());
        self.write_buf.extend_from_slice(payload.as_bytes());
    }
}

/// A submitted inference request: one pending reply per image row.
struct Inflight {
    model: String,
    /// The policy variant that served the request (the model's default
    /// when none was selected) — echoed in the response.
    variant: String,
    /// `{"image": …}` requests answer with a flat object; `{"images":
    /// …}` answer with a `results` array.
    single: bool,
    slots: Vec<Slot>,
}

struct Slot {
    pending: Option<PendingReply>,
    outcome: Option<std::result::Result<Reply, BatchError>>,
}

impl Inflight {
    /// Poll every unresolved slot once; true when all are resolved.
    fn poll(&mut self) -> bool {
        let mut all_done = true;
        for slot in &mut self.slots {
            if slot.outcome.is_some() {
                continue;
            }
            let Some(pending) = slot.pending.as_mut() else {
                // An unresolved slot with no reply handle has lost its
                // worker; resolve it as dropped so the connection gets
                // a 500 instead of the event loop aborting.
                slot.outcome = Some(Err(BatchError::Dropped));
                continue;
            };
            match pending.try_wait() {
                Some(outcome) => {
                    slot.outcome = Some(outcome);
                    slot.pending = None;
                }
                None => all_done = false,
            }
        }
        all_done
    }

    /// Build the terminal response. Any shed reply turns the whole
    /// request into 503 (the caller should retry); any execution
    /// failure into 500 — both with the real underlying message.
    fn response(self) -> (u16, JsonValue) {
        let mut rows: Vec<JsonValue> = Vec::with_capacity(self.slots.len());
        for slot in self.slots {
            // A slot that somehow reaches response-building unresolved
            // is answered as a dropped request, not a panic.
            match slot.outcome.unwrap_or(Err(BatchError::Dropped)) {
                Ok(reply) => rows.push(reply_json(&reply)),
                Err(e) => {
                    let status = match &e {
                        BatchError::Shed(_) => 503,
                        BatchError::Exec(_) | BatchError::Dropped => 500,
                    };
                    return (status, error_body(status, &e.to_string()));
                }
            }
        }
        if self.single {
            if let Some(JsonValue::Object(mut obj)) = rows.into_iter().next() {
                obj.insert("model".to_string(), JsonValue::from(self.model));
                obj.insert("variant".to_string(), JsonValue::from(self.variant));
                return (200, JsonValue::Object(obj));
            }
            // reply_json always builds one object row per slot, so an
            // empty or non-object row means the inflight was built
            // empty — degrade to a 500 for this connection only.
            let status = 500;
            (status, error_body(status, &BatchError::Dropped.to_string()))
        } else {
            (
                200,
                json_obj! {
                    "model" => self.model,
                    "variant" => self.variant,
                    "results" => rows,
                },
            )
        }
    }
}

fn reply_json(r: &Reply) -> JsonValue {
    json_obj! {
        "logits" => r.logits.iter().map(|&v| f64::from(v)).collect::<Vec<f64>>(),
        "batch_size" => r.batch_size,
        "queue_us" => r.queue_time.as_micros() as usize,
    }
}

fn error_body(status: u16, msg: &str) -> JsonValue {
    json_obj! { "status" => status as usize, "error" => msg }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        409 => "Conflict",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

/// One fully framed request, decoded enough to route.
struct ParsedRequest {
    method: String,
    path: String,
    keep_alive: bool,
    body: Vec<u8>,
}

enum ParseStatus {
    /// Not enough bytes yet.
    Incomplete,
    /// A complete request and how many buffer bytes it consumed.
    Complete(Box<ParsedRequest>, usize),
    /// Framing is broken: answer with this status and close (the byte
    /// stream can no longer be trusted for a next request).
    Malformed(u16, String),
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Try to frame one HTTP/1.1 request at the front of `buf`.
fn parse_request(buf: &[u8], cfg: &HttpConfig) -> ParseStatus {
    let Some(head_end) = find_subsequence(buf, b"\r\n\r\n") else {
        return if buf.len() > cfg.max_header_bytes {
            ParseStatus::Malformed(
                431,
                format!("header section exceeds {} bytes", cfg.max_header_bytes),
            )
        } else {
            ParseStatus::Incomplete
        };
    };
    if head_end > cfg.max_header_bytes {
        return ParseStatus::Malformed(
            431,
            format!("header section exceeds {} bytes", cfg.max_header_bytes),
        );
    }
    let Ok(head) = std::str::from_utf8(&buf[..head_end]) else {
        return ParseStatus::Malformed(400, "header section is not UTF-8".to_string());
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (Some(method), Some(path), Some(version), None) =
        (parts.next(), parts.next(), parts.next(), parts.next())
    else {
        return ParseStatus::Malformed(400, format!("malformed request line `{request_line}`"));
    };
    if method.is_empty() || path.is_empty() {
        return ParseStatus::Malformed(400, format!("malformed request line `{request_line}`"));
    }
    if !version.starts_with("HTTP/1.") {
        return ParseStatus::Malformed(505, format!("unsupported protocol version `{version}`"));
    }
    let mut keep_alive = version == "HTTP/1.1";
    let mut content_length = 0usize;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return ParseStatus::Malformed(400, format!("malformed header line `{line}`"));
        };
        let value = value.trim();
        match name.trim().to_ascii_lowercase().as_str() {
            "content-length" => match value.parse::<usize>() {
                Ok(n) => content_length = n,
                Err(_) => {
                    return ParseStatus::Malformed(400, format!("bad Content-Length `{value}`"));
                }
            },
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.split(',').any(|t| t.trim() == "close") {
                    keep_alive = false;
                } else if v.split(',').any(|t| t.trim() == "keep-alive") {
                    keep_alive = true;
                }
            }
            "transfer-encoding" => {
                return ParseStatus::Malformed(
                    501,
                    "transfer-encoded bodies are not supported; send Content-Length".to_string(),
                );
            }
            _ => {}
        }
    }
    if content_length > cfg.max_body_bytes {
        return ParseStatus::Malformed(
            413,
            format!("body of {content_length} bytes exceeds the {} limit", cfg.max_body_bytes),
        );
    }
    let total = head_end + 4 + content_length;
    if buf.len() < total {
        return ParseStatus::Incomplete;
    }
    let req = ParsedRequest {
        method: method.to_string(),
        path: path.to_string(),
        keep_alive,
        body: buf[head_end + 4..total].to_vec(),
    };
    ParseStatus::Complete(Box::new(req), total)
}

/// Routing outcome: either a response that can be written now (with an
/// optional `Allow` header value — 405s carry one per RFC 9110), or an
/// inference whose replies the event loop polls to completion.
enum Routed {
    Immediate(u16, JsonValue, Option<&'static str>),
    Infer(Inflight),
}

/// Immediate response with no extra headers.
fn imm(status: u16, body: JsonValue) -> Routed {
    Routed::Immediate(status, body, None)
}

fn route(router: &Arc<InferenceRouter>, cfg: &HttpConfig, req: &ParsedRequest) -> Routed {
    const INFER_PREFIX: &str = "/v1/infer/";
    const MODELS_PREFIX: &str = "/v1/models/";
    // Route on the path only — clients (and load-balancer probes)
    // append query strings that must not change resolution.
    let path = req.path.split_once('?').map_or(req.path.as_str(), |(p, _)| p);
    if let Some(target) = path.strip_prefix(INFER_PREFIX) {
        return if req.method == "POST" {
            route_infer(router, cfg, target, &req.body)
        } else {
            // Known route, wrong method: 405 + Allow, not a 404.
            Routed::Immediate(405, error_body(405, "inference requires POST"), Some("POST"))
        };
    }
    if let Some(target) = path.strip_prefix(MODELS_PREFIX).and_then(|r| r.strip_suffix("/reload"))
    {
        return if req.method == "POST" {
            route_reload(router, target, &req.body)
        } else {
            Routed::Immediate(405, error_body(405, "reload requires POST"), Some("POST"))
        };
    }
    if let Some(target) = path.strip_prefix(MODELS_PREFIX).and_then(|r| r.strip_suffix("/slo")) {
        return if req.method == "POST" {
            route_slo(router, target, &req.body)
        } else {
            Routed::Immediate(405, error_body(405, "SLO policy updates require POST"), Some("POST"))
        };
    }
    if let Some(target) =
        path.strip_prefix(MODELS_PREFIX).and_then(|r| r.strip_suffix("/autosearch"))
    {
        return if req.method == "POST" {
            route_autosearch(router, target, &req.body)
        } else {
            Routed::Immediate(405, error_body(405, "auto-search requires POST"), Some("POST"))
        };
    }
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => imm(200, health_json(router)),
        ("GET", "/v1/metrics") => imm(200, metrics_json(router)),
        ("GET", "/v1/models") => imm(200, models_json(router)),
        (_, "/healthz") | (_, "/v1/metrics") | (_, "/v1/models") => Routed::Immediate(
            405,
            error_body(405, &format!("{path} only supports GET")),
            Some("GET"),
        ),
        _ => imm(404, error_body(404, &format!("no route for `{}`", req.path))),
    }
}

/// `POST /v1/models/{model}/reload` (target may carry an `@{variant}`
/// suffix; without one the default variant reloads). Everything cheap —
/// target resolution, body decoding, reload-in-flight detection — is
/// answered synchronously; the staging work (weight loading, LUT/table
/// preparation) runs on a detached thread so the event loop never
/// blocks, and the route answers 202. Rollout progress and any staging
/// failure are visible in `GET /v1/models`.
fn route_reload(router: &Arc<InferenceRouter>, target: &str, body: &[u8]) -> Routed {
    let (model, variant) = match target.split_once('@') {
        Some((m, v)) => (m, v.to_string()),
        None => match router.default_variant(target) {
            Ok(v) => (target, v.to_string()),
            Err(_) => {
                // Unknown model: 404 naming what does exist.
                let known = router.model_names().join("`, `");
                return imm(
                    404,
                    error_body(
                        404,
                        &format!("no model named `{target}` (available: `{known}`)"),
                    ),
                );
            }
        },
    };
    // An explicit `@variant` also needs the 404-with-listing treatment.
    let version = match router.variant_version(model, &variant) {
        Ok(v) => v,
        Err(e) => return imm(404, error_body(404, &e.to_string())),
    };
    let Some(version) = version else {
        return imm(
            400,
            error_body(
                400,
                &format!(
                    "model `{model}` variant `{variant}` is executor-backed and cannot be \
                     hot-reloaded"
                ),
            ),
        );
    };
    let spec = match parse_reload_spec(body) {
        Ok(s) => s,
        Err(msg) => return imm(400, error_body(400, &msg)),
    };
    // Best-effort early conflict answer; the authoritative check is in
    // `begin_rollout`, whose rejection lands in the variant's
    // `last_error` for pollers.
    if let Ok(Some(st)) = router.variant_rollout(model, &variant) {
        if let Some(c) = &st.canary {
            return imm(
                409,
                error_body(
                    409,
                    &format!("rollout of generation {} is already in progress", c.generation),
                ),
            );
        }
    }
    let accepted = json_obj! {
        "status" => "accepted",
        "model" => model,
        "variant" => variant.clone(),
        "serving_generation" => version.generation as usize,
        "canary_share" => spec.rollout.canary_share as usize,
    };
    let router = Arc::clone(router);
    let model = model.to_string();
    let spawned = std::thread::Builder::new().name("sparq-reload".into()).spawn(move || {
        // Errors are recorded on the variant's tracker by
        // `reload_variant` itself; nothing to do with them here.
        let _ = router.reload_variant(&model, &variant, spec);
    });
    match spawned {
        Ok(_) => Routed::Immediate(202, accepted, None),
        Err(e) => imm(500, error_body(500, &format!("spawning reload thread: {e}"))),
    }
}

/// Decode a reload request body into a [`ReloadSpec`].
fn parse_reload_spec(body: &[u8]) -> std::result::Result<ReloadSpec, String> {
    let Ok(text) = std::str::from_utf8(body) else {
        return Err("body is not UTF-8".to_string());
    };
    let v = JsonValue::parse(text).map_err(|e| format!("invalid JSON body: {e}"))?;
    let u64_field = |key: &str, default: u64| -> std::result::Result<u64, String> {
        match v.get(key) {
            None => Ok(default),
            Some(x) => x
                .as_usize()
                .map(|n| n as u64)
                .ok_or_else(|| format!("`{key}` must be a non-negative integer")),
        }
    };
    let defaults = RolloutConfig::default();
    let rollout = RolloutConfig {
        canary_share: u64_field("canary_share", defaults.canary_share)?,
        promote_threshold: match v.get("promote_threshold") {
            None => defaults.promote_threshold,
            Some(x) => x.as_f64().ok_or("`promote_threshold` must be a number")?,
        },
        min_requests: u64_field("min_requests", defaults.min_requests)?,
    };
    let source = match v.get("source").and_then(JsonValue::as_str) {
        Some("policy") => {
            let p = v.get("policy").ok_or("`policy` source requires a `policy` field")?;
            let policy = QuantPolicy::from_json_value(p)
                .map_err(|e| format!("invalid `policy`: {e:#}"))?;
            ReloadSource::Policy(policy)
        }
        Some("weights_npz") => {
            let path = v
                .get("path")
                .and_then(JsonValue::as_str)
                .ok_or("`weights_npz` source requires a string `path`")?;
            ReloadSource::WeightsNpz(std::path::PathBuf::from(path))
        }
        Some("perturb") => {
            let amplitude = v
                .get("amplitude")
                .and_then(JsonValue::as_usize)
                .ok_or("`perturb` source requires a non-negative integer `amplitude`")?;
            let amplitude = i8::try_from(amplitude)
                .map_err(|_| format!("`amplitude` {amplitude} exceeds {}", i8::MAX))?;
            ReloadSource::Perturb { seed: u64_field("seed", 0)?, amplitude }
        }
        Some(other) => {
            return Err(format!(
                "unknown `source` `{other}` (expected `policy`, `weights_npz` or `perturb`)"
            ));
        }
        None => return Err("body must name a `source` string".to_string()),
    };
    Ok(ReloadSpec { source, rollout, provenance: None })
}

/// `POST /v1/models/{model}/autosearch` — launch a policy auto-search
/// ([`crate::search`]) for the model's default variant on a detached
/// thread, answering 202. Per-model like `/slo` (a `@variant` target is
/// a 400): the search measures operating points for the model, not for
/// one rung of it. Progress and the terminal outcome surface under the
/// model's `"autosearch"` key on `GET /v1/metrics`; with
/// `"install": true` the winning policy additionally stages as a new
/// generation of the default variant, its version tagged with
/// [`VersionProvenance`] `origin: "search"`.
fn route_autosearch(router: &Arc<InferenceRouter>, target: &str, body: &[u8]) -> Routed {
    if target.contains('@') {
        return imm(
            400,
            error_body(
                400,
                &format!("auto-search is per-model; `{target}` must not name a variant"),
            ),
        );
    }
    let variant = match router.default_variant(target) {
        Ok(v) => v.to_string(),
        Err(_) => {
            let known = router.model_names().join("`, `");
            return imm(
                404,
                error_body(404, &format!("no model named `{target}` (available: `{known}`)")),
            );
        }
    };
    // The search needs the live graph/weights/scales, so an
    // executor-backed default variant cannot be searched.
    let version = match router.variant_version(target, &variant) {
        Ok(Some(v)) => v,
        Ok(None) => {
            return imm(
                400,
                error_body(
                    400,
                    &format!(
                        "model `{target}` default variant `{variant}` is executor-backed; \
                         auto-search requires a params-built variant"
                    ),
                ),
            );
        }
        Err(e) => return imm(404, error_body(404, &e.to_string())),
    };
    let (cfg, rows, install) = match parse_autosearch_spec(body) {
        Ok(t) => t,
        Err(msg) => return imm(400, error_body(400, &msg)),
    };
    let progress = match router.begin_autosearch(target) {
        Ok(p) => p,
        Err(e) => return imm(409, error_body(409, &e.to_string())),
    };
    let accepted = json_obj! {
        "status" => "accepted",
        "model" => target,
        "variant" => variant.clone(),
        "agreement_floor" => cfg.agreement_floor,
        "eval_budget" => cfg.eval_budget,
        "rows" => rows,
        "install" => install,
    };
    let router = Arc::clone(router);
    let model = target.to_string();
    let worker_progress = Arc::clone(&progress);
    let spawned = std::thread::Builder::new().name("sparq-autosearch".into()).spawn(move || {
        // Terminal state (Done/Failed + outcome) lands in the progress
        // cell; an install failure is additionally recorded on the
        // variant's tracker by `reload_variant` itself.
        let params = Arc::clone(&version.params);
        let scales = params.act_scales();
        let ds = crate::model::demo::synth_dataset(&params.graph, &params.weights, &scales, rows);
        let cfg = crate::search::SearchConfig { mode: params.mode(), ..cfg };
        let outcome = crate::search::run_with_progress(
            &params.graph,
            &params.weights,
            &ds,
            &scales,
            &cfg,
            Some(&worker_progress),
        );
        if let (Ok(out), true) = (outcome, install) {
            let _ = router.reload_variant(
                &model,
                &variant,
                ReloadSpec {
                    source: ReloadSource::Policy(out.policy),
                    // The search already measured agreement against the
                    // A8W8 reference; an immediate swap keeps install
                    // deterministic (operators wanting a live canary
                    // can reload the reported policy themselves).
                    rollout: RolloutConfig { canary_share: 0, ..RolloutConfig::default() },
                    provenance: Some(VersionProvenance {
                        origin: "search".to_string(),
                        agreement: Some(out.agreement),
                        report_sha: out.report_sha,
                    }),
                },
            );
        }
    });
    match spawned {
        Ok(_) => Routed::Immediate(202, accepted, None),
        Err(e) => {
            // Release the claim: a cell stuck Idle would block every
            // future search of this model.
            progress.finish(
                crate::search::SearchPhase::Failed,
                json_obj! { "error" => format!("spawning auto-search thread: {e}") },
            );
            imm(500, error_body(500, &format!("spawning auto-search thread: {e}")))
        }
    }
}

/// Decode an autosearch request body: search knobs plus the row count
/// for the synthesized calibration set and the `install` flag. An empty
/// body runs an all-defaults search.
fn parse_autosearch_spec(
    body: &[u8],
) -> std::result::Result<(crate::search::SearchConfig, usize, bool), String> {
    let mut cfg = crate::search::SearchConfig::default();
    let mut rows = 256usize;
    let mut install = false;
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    if !text.trim().is_empty() {
        let v = JsonValue::parse(text).map_err(|e| format!("invalid JSON body: {e}"))?;
        if let Some(x) = v.get("floor") {
            cfg.agreement_floor =
                x.as_f64().ok_or_else(|| "`floor` must be a number".to_string())?;
        }
        if let Some(x) = v.get("budget") {
            cfg.eval_budget =
                x.as_usize().ok_or_else(|| "`budget` must be a non-negative integer".to_string())?;
        }
        if let Some(x) = v.get("ranked") {
            cfg.ranked = x.as_bool().ok_or_else(|| "`ranked` must be a boolean".to_string())?;
        }
        if let Some(x) = v.get("rows") {
            rows = x.as_usize().ok_or_else(|| "`rows` must be a positive integer".to_string())?;
        }
        if let Some(x) = v.get("install") {
            install = x.as_bool().ok_or_else(|| "`install` must be a boolean".to_string())?;
        }
    }
    if !(0.0 < cfg.agreement_floor && cfg.agreement_floor <= 1.0) {
        return Err(format!("`floor` {} not in (0, 1]", cfg.agreement_floor));
    }
    // Bound the synthesized calibration set: each row costs a forward
    // pass per measured policy.
    if rows == 0 || rows > 65_536 {
        return Err(format!("`rows` {rows} not in [1, 65536]"));
    }
    Ok((cfg, rows, install))
}

/// `POST /v1/models/{model}/slo` — install or clear the model's SLO
/// degradation ladder. The body is exactly the
/// [`SloPolicy`] wire encoding (`{ladder, max_queue_depth, max_p99_us,
/// dwell_us, recover_margin}`); an empty body, a JSON `null`, or
/// `{"clear": true}` removes any installed policy. Unlike reload
/// there is no staging work, so installation is synchronous: 200 on
/// success, 400 for anything the policy or registry validation
/// rejects (bad JSON, unknown rung, rung 0 not the default,
/// footprint_bits increasing along the ladder), 404 for unknown
/// models. Ladders are per-model, so a `{model}@{variant}` target is
/// a 400, not a different resource.
fn route_slo(router: &InferenceRouter, target: &str, body: &[u8]) -> Routed {
    if target.contains('@') {
        return imm(
            400,
            error_body(
                400,
                &format!("SLO policies are per-model; `{target}` must not name a variant"),
            ),
        );
    }
    if router.default_variant(target).is_err() {
        let known = router.model_names().join("`, `");
        return imm(
            404,
            error_body(404, &format!("no model named `{target}` (available: `{known}`)")),
        );
    }
    let Ok(text) = std::str::from_utf8(body) else {
        return imm(400, error_body(400, "body is not UTF-8"));
    };
    let trimmed = text.trim();
    let cleared = || {
        json_obj! {
            "status" => "cleared",
            "model" => target,
        }
    };
    if trimmed.is_empty() || trimmed == "null" {
        return match router.set_slo_policy(target, None) {
            Ok(()) => imm(200, cleared()),
            Err(e) => imm(404, error_body(404, &e.to_string())),
        };
    }
    let parsed = match JsonValue::parse(trimmed) {
        Ok(v) => v,
        Err(e) => return imm(400, error_body(400, &format!("invalid JSON body: {e}"))),
    };
    if parsed.get("clear").and_then(JsonValue::as_bool) == Some(true) {
        return match router.set_slo_policy(target, None) {
            Ok(()) => imm(200, cleared()),
            Err(e) => imm(404, error_body(404, &e.to_string())),
        };
    }
    let policy = match SloPolicy::from_json_value(&parsed) {
        Ok(p) => p,
        Err(e) => return imm(400, error_body(400, &format!("invalid SLO policy: {e:#}"))),
    };
    let ladder: Vec<JsonValue> =
        policy.ladder().iter().map(|r| JsonValue::from(r.as_str())).collect();
    match router.set_slo_policy(target, Some(policy)) {
        Ok(()) => imm(
            200,
            json_obj! {
                "status" => "installed",
                "model" => target,
                "ladder" => ladder,
            },
        ),
        Err(e) => imm(400, error_body(400, &format!("{e:#}"))),
    }
}

/// `target` is `{model}` or `{model}@{variant}`; the body may also name
/// a `"variant"`. Path and body selections must agree if both present.
fn route_infer(router: &InferenceRouter, cfg: &HttpConfig, target: &str, body: &[u8]) -> Routed {
    let (model, path_variant) = match target.split_once('@') {
        Some((m, v)) => (m, Some(v)),
        None => (target, None),
    };
    let (image_len, _classes) = match router.shape(model) {
        Ok(shape) => shape,
        Err(e) => return imm(404, error_body(404, &e.to_string())),
    };
    let Ok(text) = std::str::from_utf8(body) else {
        return imm(400, error_body(400, "body is not UTF-8"));
    };
    let parsed = match JsonValue::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return imm(400, error_body(400, &format!("invalid JSON body: {e}")));
        }
    };
    let body_variant = match parsed.get("variant") {
        None => None,
        Some(v) => match v.as_str() {
            Some(s) => Some(s),
            None => return imm(400, error_body(400, "`variant` must be a string")),
        },
    };
    let variant = match (path_variant, body_variant) {
        (Some(p), Some(b)) if p != b => {
            return imm(
                400,
                error_body(400, &format!("path selects variant `{p}` but body says `{b}`")),
            );
        }
        (p, b) => p.or(b),
    };
    // Unknown variants are 404 — checked before submit so the error is
    // typed as routing, not queue pressure. The common no-variant path
    // stays allocation-free apart from the served-name copy.
    let served = match variant {
        Some(v) => {
            let known = router.variant_names(model).unwrap_or_default();
            if !known.contains(&v) {
                return imm(
                    404,
                    error_body(
                        404,
                        &format!("model `{model}` has no variant `{v}` (available: {known:?})"),
                    ),
                );
            }
            v.to_string()
        }
        // Unaddressed requests resolve through the SLO dispatch seam:
        // with no ladder installed this is the default variant; with
        // one, the rung the ladder picks for this request. Resolving
        // once here and then pinning every row to `served` keeps a
        // micro-batch on one variant and lets the response echo what
        // actually served it.
        None => router.serving_variant(model).unwrap_or("default").to_string(),
    };
    let (images, single) = match extract_images(&parsed, image_len, cfg) {
        Ok(x) => x,
        Err(msg) => return imm(400, error_body(400, &msg)),
    };
    let mut slots = Vec::with_capacity(images.len());
    for image in images {
        match router.submit_variant(model, &served, image) {
            Ok(pending) => slots.push(Slot { pending: Some(pending), outcome: None }),
            // Name, variant and shape were validated above, so a submit
            // failure is queue pressure (overload or worker shutdown):
            // 503 with the batcher's descriptive message. Earlier rows
            // of this micro-batch may still execute; their replies are
            // dropped.
            Err(e) => return imm(503, error_body(503, &e.to_string())),
        }
    }
    Routed::Infer(Inflight { model: model.to_string(), variant: served, single, slots })
}

/// Pull `image` (single row) or `images` (micro-batch) out of a
/// request body, validating width and element types.
#[allow(clippy::type_complexity)]
fn extract_images(
    v: &JsonValue,
    image_len: usize,
    cfg: &HttpConfig,
) -> std::result::Result<(Vec<Vec<f32>>, bool), String> {
    fn image_row(
        v: &JsonValue,
        image_len: usize,
        which: usize,
    ) -> std::result::Result<Vec<f32>, String> {
        let arr = v
            .as_array()
            .ok_or_else(|| format!("image {which}: expected an array of numbers"))?;
        if arr.len() != image_len {
            return Err(format!(
                "image {which}: expected {image_len} values, got {}",
                arr.len()
            ));
        }
        arr.iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| format!("image {which}: non-numeric element"))
            })
            .collect()
    }
    if let Some(img) = v.get("image") {
        Ok((vec![image_row(img, image_len, 0)?], true))
    } else if let Some(list) = v.get("images") {
        let arr = list
            .as_array()
            .ok_or_else(|| "images: expected an array of image rows".to_string())?;
        if arr.is_empty() {
            return Err("images: micro-batch is empty".to_string());
        }
        if arr.len() > cfg.max_batch_images {
            return Err(format!(
                "images: micro-batch of {} rows exceeds the limit {}",
                arr.len(),
                cfg.max_batch_images
            ));
        }
        let rows = arr
            .iter()
            .enumerate()
            .map(|(i, x)| image_row(x, image_len, i))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        Ok((rows, false))
    } else {
        Err("body must contain `image` (one row) or `images` (micro-batch)".to_string())
    }
}

fn health_json(router: &InferenceRouter) -> JsonValue {
    let models: Vec<String> = router.model_names().iter().map(|s| s.to_string()).collect();
    json_obj! { "status" => "ok", "models" => models }
}

fn shard_json(s: &super::router::ShardMetrics) -> JsonValue {
    json_obj! {
        "shard" => s.shard,
        "completed" => s.completed as usize,
        "mean_latency_us" => s.mean_latency_us,
        "p50_latency_us" => s.p50_latency_us as usize,
        "p99_latency_us" => s.p99_latency_us as usize,
        // full bucketed distribution, not just the two quantiles —
        // the ops dashboard's sparkline reads this
        "hist" => s.hist.to_json(),
        "batcher" => s.batcher.to_json(),
    }
}

/// A variant's rollout snapshot as JSON — shared by `/v1/models`
/// (discovery) and `/v1/metrics` (the per-generation counters the ops
/// view reads).
fn rollout_json(st: &RolloutStatus) -> JsonValue {
    let served: Vec<JsonValue> = st
        .served
        .iter()
        .map(|(generation, rows)| {
            json_obj! {
                "generation" => *generation as usize,
                "rows" => *rows as usize,
            }
        })
        .collect();
    let draining: Vec<JsonValue> = st
        .draining
        .iter()
        .map(|d| {
            json_obj! { "generation" => d.generation as usize, "holders" => d.holders }
        })
        .collect();
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("state".to_string(), JsonValue::from(st.state()));
    obj.insert(
        "canary".to_string(),
        st.canary.as_ref().map_or(JsonValue::Null, |c| {
            json_obj! {
                "generation" => c.generation as usize,
                "weights_sha" => c.weights_sha.clone(),
                "share" => c.share as usize,
                "agree" => c.agree as usize,
                "total" => c.total as usize,
                "min_requests" => c.min_requests as usize,
                "promote_threshold" => c.promote_threshold,
            }
        }),
    );
    obj.insert("draining".to_string(), JsonValue::from(draining));
    obj.insert(
        "drained".to_string(),
        JsonValue::from(st.drained.iter().map(|&g| g as f64).collect::<Vec<f64>>()),
    );
    obj.insert("served_rows_by_generation".to_string(), JsonValue::from(served));
    obj.insert(
        "last_outcome".to_string(),
        st.last_outcome.as_ref().map_or(JsonValue::Null, |o| {
            json_obj! {
                "generation" => o.generation as usize,
                "promoted" => o.promoted,
                "agreement" => o.agreement.map_or(JsonValue::Null, JsonValue::from),
            }
        }),
    );
    obj.insert(
        "last_error".to_string(),
        st.last_error.as_deref().map_or(JsonValue::Null, JsonValue::from),
    );
    JsonValue::Object(obj)
}

fn metrics_json(router: &InferenceRouter) -> JsonValue {
    let mut models = std::collections::BTreeMap::new();
    for name in router.model_names() {
        let Ok(m) = router.metrics(name) else { continue };
        let shards: Vec<JsonValue> = m.shards.iter().map(shard_json).collect();
        let variants: Vec<JsonValue> = m
            .variants
            .iter()
            .map(|v| {
                json_obj! {
                    "variant" => v.variant.clone(),
                    "replicas" => v.replicas,
                    "policy" => v.policy.clone(),
                    "footprint_bits_per_act" => v.footprint_bits,
                    "generation" => v.generation as usize,
                    "weights_sha" => v.weights_sha.clone(),
                    "state" => v.state.clone(),
                    "provenance" => v
                        .provenance
                        .as_ref()
                        .map_or(JsonValue::Null, VersionProvenance::to_json),
                    "rollout" => v.rollout.as_ref().map_or(JsonValue::Null, rollout_json),
                    "recent_p99_us" => v.recent_p99_us as usize,
                    "shards" => v.shards.iter().map(shard_json).collect::<Vec<JsonValue>>(),
                    "total" => v.total.to_json(),
                }
            })
            .collect();
        models.insert(
            name.to_string(),
            json_obj! {
                "replicas" => m.replicas,
                "param_bytes" => m.param_bytes,
                // Ladder position when an SLO policy is installed:
                // current rung, serving variant, time-in-degraded-mode,
                // transition counters (null otherwise).
                "slo" => m.slo.as_ref().map_or(JsonValue::Null, super::slo::SloStatus::to_json),
                // Latest auto-search launched against this model:
                // phase, eval progress, terminal outcome (null until
                // the first POST /v1/models/{name}/autosearch).
                "autosearch" => router
                    .autosearch_progress(name)
                    .ok()
                    .flatten()
                    .unwrap_or(JsonValue::Null),
                "variants" => variants,
                "shards" => shards,
                "total" => m.total.to_json(),
            },
        );
    }
    let mut top = std::collections::BTreeMap::new();
    top.insert("models".to_string(), JsonValue::Object(models));
    top.insert("aggregate".to_string(), router.aggregate().to_json());
    JsonValue::Object(top)
}

/// `GET /v1/models` — the policy introspection surface: every model
/// with shape, shared parameter footprint, default variant, and each
/// variant's resolved per-layer policy (wire-format JSON + display
/// string + per-layer config list + footprint bits per activation)
/// plus its version metadata (serving generation, weights hash,
/// lifecycle state, rollout snapshot). Built from the router's cheap
/// accessors only — no stats snapshots, no latency-histogram locks
/// (the version slot/tracker mutexes are microsecond assignments), so
/// polling this discovery endpoint never contends with the serving
/// hot path.
fn models_json(router: &InferenceRouter) -> JsonValue {
    let mut models = std::collections::BTreeMap::new();
    for name in router.model_names() {
        let Ok((image_len, classes)) = router.shape(name) else { continue };
        let Ok(variant_replicas) = router.variant_replicas(name) else { continue };
        let mut total_replicas = 0usize;
        let mut variants = std::collections::BTreeMap::new();
        for (vname, replicas) in variant_replicas {
            total_replicas += replicas;
            // The serving ModelVersion pins generation + weights_sha +
            // params to one consistent snapshot even mid-hot-swap.
            let base = match router.variant_version(name, vname) {
                Ok(Some(version)) => {
                    let params = &version.params;
                    let layers: Vec<JsonValue> = params
                        .layer_cfgs()
                        .iter()
                        .map(|(lname, cfg)| {
                            json_obj! {
                                "layer" => lname.clone(),
                                "config" => cfg.to_string(),
                            }
                        })
                        .collect();
                    let status = router.variant_rollout(name, vname).ok().flatten();
                    let state = status.as_ref().map_or("serving", RolloutStatus::state);
                    let rollout = status.as_ref().map_or(JsonValue::Null, rollout_json);
                    json_obj! {
                        "replicas" => replicas,
                        "policy" => params.policy().to_json(),
                        "policy_display" => params.policy().to_string(),
                        "layers" => layers,
                        "distinct_configs" => params.distinct_configs(),
                        "footprint_bits_per_act" => params.footprint_bits(1),
                        "generation" => version.generation as usize,
                        "weights_sha" => version.weights_sha.clone(),
                        "state" => state,
                        // Who chose this operating point: null for
                        // hand-written/build-time parameters; for
                        // searched variants, the origin, the agreement
                        // measured at search time, and the report hash
                        // tying the version to its SearchReport.
                        "provenance" => version
                            .provenance
                            .as_ref()
                            .map_or(JsonValue::Null, VersionProvenance::to_json),
                        "rollout" => rollout,
                    }
                }
                // Executor-backed variants (PJRT shards, test doubles)
                // have no introspectable policy or version.
                _ => json_obj! { "replicas" => replicas, "policy" => JsonValue::Null },
            };
            variants.insert(vname.to_string(), base);
        }
        models.insert(
            name.to_string(),
            json_obj! {
                "image_len" => image_len,
                "classes" => classes,
                "param_bytes" => router.param_bytes(name).unwrap_or(0),
                "replicas" => total_replicas,
                "default_variant" => router
                    .default_variant(name)
                    .unwrap_or("default")
                    .to_string(),
                "variants" => JsonValue::Object(variants),
            },
        );
    }
    json_obj! { "models" => JsonValue::Object(models) }
}

/// The single-threaded reactor: accept, read, parse, submit, poll
/// replies, write — every step non-blocking.
struct EventLoop {
    listener: TcpListener,
    router: Arc<InferenceRouter>,
    cfg: HttpConfig,
    poller: Poller,
    conns: HashMap<u64, Conn>,
    next_token: u64,
}

impl EventLoop {
    fn run(mut self, stop: &AtomicBool) -> io::Result<()> {
        let mut events: Vec<Event> = Vec::new();
        loop {
            if stop.load(Relaxed) {
                return Ok(());
            }
            // Short tick while replies are in flight (completion is
            // polled, not pushed); longer when purely idle so shutdown
            // and new connections are still noticed promptly.
            let waiting = self.conns.values().any(|c| c.inflight.is_some());
            let timeout =
                if waiting { Duration::from_millis(1) } else { Duration::from_millis(20) };
            self.poller.wait(&mut events, Some(timeout))?;
            let read_cap = self.cfg.max_header_bytes + self.cfg.max_body_bytes + 4;
            for ev in &events {
                if ev.token == LISTENER_TOKEN {
                    self.accept_new();
                } else if let Some(conn) = self.conns.get_mut(&ev.token) {
                    // A half-closed socket stays readable (EOF) forever
                    // under level triggering; read it only once.
                    if ev.readable && !conn.peer_closed {
                        conn.fill_read_buf(read_cap);
                    }
                    if ev.writable {
                        conn.flush_write_buf();
                    }
                }
            }
            self.progress_all();
            self.reap_dead();
        }
    }

    fn accept_new(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((mut stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    if self.conns.len() >= self.cfg.max_connections {
                        // Best-effort 503 straight into the fresh
                        // socket buffer, then drop.
                        let body = error_body(503, "connection limit reached");
                        let mut throwaway = Conn::new(stream);
                        throwaway.queue_response(503, &body, false);
                        throwaway.flush_write_buf();
                        continue;
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    if self
                        .poller
                        .register(stream.as_raw_fd(), token, Interest::READABLE)
                        .is_err()
                    {
                        continue;
                    }
                    self.conns.insert(token, Conn::new(stream));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return,
            }
        }
    }

    /// Advance every connection's state machine: resolve in-flight
    /// replies, flush writes, parse the next request when idle.
    fn progress_all(&mut self) {
        for (&token, conn) in self.conns.iter_mut() {
            if conn.dead {
                continue;
            }
            let resolved = conn.inflight.as_mut().is_some_and(Inflight::poll);
            if resolved {
                if let Some(inflight) = conn.inflight.take() {
                    let (status, body) = inflight.response();
                    let keep = conn.keep_alive;
                    conn.queue_response(status, &body, keep);
                }
            }
            if conn.has_pending_write() {
                conn.flush_write_buf();
            }
            // Parse as many buffered requests as can be answered now
            // (pipelining degrades gracefully: one in-flight inference
            // per connection at a time).
            while !conn.dead
                && conn.inflight.is_none()
                && !conn.has_pending_write()
                && !conn.read_buf.is_empty()
            {
                match parse_request(&conn.read_buf, &self.cfg) {
                    ParseStatus::Incomplete => break,
                    ParseStatus::Malformed(status, msg) => {
                        conn.read_buf.clear();
                        conn.queue_response(status, &error_body(status, &msg), false);
                        conn.flush_write_buf();
                        break;
                    }
                    ParseStatus::Complete(req, consumed) => {
                        conn.read_buf.drain(..consumed);
                        conn.keep_alive = req.keep_alive;
                        match route(&self.router, &self.cfg, &req) {
                            Routed::Immediate(status, body, allow) => {
                                conn.queue_response_with(status, &body, req.keep_alive, allow);
                                conn.flush_write_buf();
                            }
                            Routed::Infer(inflight) => {
                                conn.inflight = Some(inflight);
                            }
                        }
                    }
                }
            }
            // A half-closed peer gets every already-submitted answer;
            // once nothing is in flight or buffered for write, no new
            // request can ever arrive (any leftover bytes are a forever
            // incomplete frame) — reap.
            if conn.peer_closed && conn.inflight.is_none() && !conn.has_pending_write() {
                conn.dead = true;
            }
            // Ask for writable readiness only while a response is
            // actually stuck in the buffer.
            let want_write = conn.has_pending_write();
            if want_write != conn.want_write && !conn.dead {
                let interest = if want_write { Interest::BOTH } else { Interest::READABLE };
                if self
                    .poller
                    .modify(conn.stream.as_raw_fd(), token, interest)
                    .is_err()
                {
                    conn.dead = true;
                } else {
                    conn.want_write = want_write;
                }
            }
        }
    }

    fn reap_dead(&mut self) {
        let dead: Vec<u64> = self.conns.iter().filter(|(_, c)| c.dead).map(|(&t, _)| t).collect();
        for token in dead {
            if let Some(conn) = self.conns.remove(&token) {
                let _ = self.poller.deregister(conn.stream.as_raw_fd());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HttpConfig {
        HttpConfig::default()
    }

    fn parse_ok(raw: &str) -> (ParsedRequest, usize) {
        match parse_request(raw.as_bytes(), &cfg()) {
            ParseStatus::Complete(req, n) => (*req, n),
            other => panic!(
                "expected complete parse, got {}",
                match other {
                    ParseStatus::Incomplete => "Incomplete".to_string(),
                    ParseStatus::Malformed(s, m) => format!("Malformed({s}, {m})"),
                    ParseStatus::Complete(..) => unreachable!(),
                }
            ),
        }
    }

    #[test]
    fn parses_request_with_body_and_keepalive_rules() {
        let raw = "POST /v1/infer/m HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd";
        let (req, consumed) = parse_ok(raw);
        assert_eq!(consumed, raw.len());
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/infer/m");
        assert_eq!(req.body, b"abcd");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");

        let (req, _) = parse_ok("GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(!req.keep_alive);
        let (req, _) = parse_ok("GET /healthz HTTP/1.0\r\n\r\n");
        assert!(!req.keep_alive, "HTTP/1.0 defaults to close");
        let (req, _) = parse_ok("GET /healthz HTTP/1.0\r\nConnection: keep-alive\r\n\r\n");
        assert!(req.keep_alive);
    }

    #[test]
    fn incomplete_frames_wait_for_more_bytes() {
        for partial in [
            "POST /v1/infer/m HT",
            "POST /v1/infer/m HTTP/1.1\r\nContent-Length: 10\r\n\r\n",
            "POST /v1/infer/m HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc",
        ] {
            assert!(
                matches!(parse_request(partial.as_bytes(), &cfg()), ParseStatus::Incomplete),
                "should be incomplete: {partial:?}"
            );
        }
    }

    #[test]
    fn malformed_frames_are_typed_errors() {
        let cases: Vec<(&str, u16)> = vec![
            ("GARBAGE\r\n\r\n", 400),
            ("GET /x\r\n\r\n", 400),
            ("GET /x SPDY/3\r\n\r\n", 505),
            ("GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n", 400),
            ("GET /x HTTP/1.1\r\nContent-Length: banana\r\n\r\n", 400),
            ("POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 501),
        ];
        for (raw, want) in cases {
            match parse_request(raw.as_bytes(), &cfg()) {
                ParseStatus::Malformed(status, _) => {
                    assert_eq!(status, want, "wrong status for {raw:?}");
                }
                _ => panic!("expected malformed: {raw:?}"),
            }
        }
        // Oversized declared body is 413 before any body byte arrives.
        let raw = format!(
            "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            cfg().max_body_bytes + 1
        );
        assert!(matches!(
            parse_request(raw.as_bytes(), &cfg()),
            ParseStatus::Malformed(413, _)
        ));
        // Unbounded header section is cut off at the cap.
        let raw = format!("GET /x HTTP/1.1\r\nX-Pad: {}", "a".repeat(cfg().max_header_bytes));
        assert!(matches!(
            parse_request(raw.as_bytes(), &cfg()),
            ParseStatus::Malformed(431, _)
        ));
    }

    #[test]
    fn allow_header_is_emitted_on_405_responses() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut conn = Conn::new(stream);
        conn.queue_response_with(405, &error_body(405, "nope"), true, Some("GET"));
        let raw = String::from_utf8(conn.write_buf.clone()).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"), "{raw}");
        assert!(raw.contains("Allow: GET\r\n"), "{raw}");
        // non-405 responses carry no Allow header
        let stream2 = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut conn2 = Conn::new(stream2);
        conn2.queue_response(200, &error_body(200, "ok"), true);
        let raw2 = String::from_utf8(conn2.write_buf.clone()).unwrap();
        assert!(!raw2.contains("Allow:"), "{raw2}");
    }

    #[test]
    fn response_encoding_has_exact_content_length() {
        // Build a throwaway Conn around a loopback socket to exercise
        // queue_response framing.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut conn = Conn::new(stream);
        let body = json_obj! { "k" => "v" };
        conn.queue_response(200, &body, true);
        let raw = String::from_utf8(conn.write_buf.clone()).unwrap();
        let payload = body.to_string();
        assert!(raw.starts_with("HTTP/1.1 200 OK\r\n"), "{raw}");
        assert!(raw.contains(&format!("Content-Length: {}\r\n", payload.len())), "{raw}");
        assert!(raw.contains("Connection: keep-alive\r\n"), "{raw}");
        assert!(raw.ends_with(&payload), "{raw}");
    }
}
