//! In-process inference service: an executor (PJRT executable or the
//! native engine) behind the dynamic batcher, plus latency/throughput
//! metrics. `examples/serve_bench.rs` drives it with concurrent
//! synthetic clients.
//!
//! Batches execute at their true size. The PJRT executor is the one
//! place that still pads — its HLO has a fixed lowered batch dimension —
//! and it does so internally, slicing the padded rows back off before
//! they reach the batcher. The native executor
//! ([`InferenceServer::start_native`]) runs short batches directly and
//! reuses one [`Scratch`](crate::model::Scratch) across all requests;
//! [`InferenceServer::start_native_shared`] serves replicas off an
//! existing `Arc<ModelParams>` without copying any parameters.
//!
//! [`ServerMetrics`] carries the latency histograms *and* a live handle
//! to the batcher's [`BatcherStats`] — queue depth, peak depth, shed and
//! rejected counts are observable per server, so overload shows up in
//! metrics rather than silently as memory growth.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::model::{Engine, EngineMode, Graph, ModelParams, Scratch, Weights};
use crate::quant::SparqConfig;
use crate::runtime::{ArtifactKind, ModelArtifacts, PjrtRuntime, TensorArg, TensorData};

use super::batcher::{BatchPolicy, Batcher, BatcherStats, Reply};

// The latency histogram moved to the observability subsystem when the
// perf harness made it a reported artifact; re-exported here so the
// serving layer's `coordinator::LatencyHist` name keeps working.
pub use crate::observability::LatencyHist;

/// Aggregated server metrics: latency histograms plus the live batcher
/// stats (queue depth, shed/rejected counts, batch/exec counters). The
/// `batcher` arc is the same one the worker updates, so reads are
/// always current — sample it with `batcher.snapshot()`.
#[derive(Default, Debug)]
pub struct ServerMetrics {
    pub e2e: LatencyHist,
    pub queue: LatencyHist,
    pub batcher: Arc<BatcherStats>,
}

/// A model served through the dynamically batched executor path.
pub struct InferenceServer {
    batcher: Batcher,
    metrics: Arc<Mutex<ServerMetrics>>,
    pub classes: usize,
    pub image_dims: [usize; 3],
}

impl InferenceServer {
    /// Load the model's sparq artifact and start the batching worker on
    /// the PJRT path. The executable's batch dimension is fixed at
    /// `policy.max_batch`; short batches are padded inside this
    /// executor and the padded rows sliced off.
    pub fn start(
        rt: Arc<PjrtRuntime>,
        model: &ModelArtifacts,
        image_dims: [usize; 3],
        classes: usize,
        scales: Vec<f32>,
        cfg: SparqConfig,
        policy: BatchPolicy,
    ) -> Result<Self> {
        let exe = rt.load(&model.hlo_path(ArtifactKind::Sparq))?;
        let metrics = Arc::new(Mutex::new(ServerMetrics::default()));
        let stats = super::lock_recover(&metrics).batcher.clone();
        let [h, w, c] = image_dims;
        let image_len = h * w * c;
        let hw_batch = policy.max_batch;
        let nscales = scales.len();
        let cfg_vec = cfg.to_vec().to_vec();
        let execute = move |buf: &[f32], bsz: usize| -> Result<Vec<f32>> {
            // TensorArg owns its data, so one allocation per batch is
            // inherent to this backend; pad straight into it.
            let mut padded = buf.to_vec();
            padded.resize(hw_batch * image_len, 0.0);
            let out = exe.run(&[
                TensorArg::f32(&[hw_batch, h, w, c], padded),
                TensorArg::f32(&[nscales], scales.clone()),
                TensorArg::i32(&[5], cfg_vec.clone()),
            ])?;
            // Error (don't panic) on malformed executable output: a
            // panic here would kill the batcher worker for good, while
            // an Err is surfaced per-batch and the server keeps serving.
            let first = out
                .first()
                .ok_or_else(|| anyhow::anyhow!("executable returned no outputs"))?;
            let logits = match &first.data {
                TensorData::F32(v) => v,
                TensorData::I32(_) => {
                    anyhow::bail!("executable returned i32 logits, expected f32")
                }
            };
            let need = bsz * classes;
            anyhow::ensure!(
                logits.len() >= need,
                "executable returned {} logits, need {need}",
                logits.len()
            );
            Ok(logits[..need].to_vec())
        };
        let batcher = Batcher::spawn(policy, image_len, classes, Box::new(execute), stats);
        Ok(Self { batcher, metrics, classes, image_dims })
    }

    /// Serve a model through the native integer engine — no PJRT, no
    /// artifacts, true variable-batch execution. Builds the shared
    /// parameter block once and delegates to
    /// [`InferenceServer::start_native_shared`].
    pub fn start_native(
        graph: &Graph,
        weights: &Weights,
        scales: &[f32],
        cfg: SparqConfig,
        mode: EngineMode,
        policy: BatchPolicy,
    ) -> Result<Self> {
        let params = Arc::new(ModelParams::new(
            Arc::new(graph.clone()),
            Arc::new(weights.clone()),
            cfg,
            scales,
            mode,
        )?);
        Self::start_native_shared(params, policy)
    }

    /// Serve a replica off an existing shared parameter block — zero
    /// parameter copies. The worker owns a cheap [`Engine`] handle and
    /// one [`Scratch`], so steady-state requests allocate nothing on
    /// the quantized path.
    pub fn start_native_shared(params: Arc<ModelParams>, policy: BatchPolicy) -> Result<Self> {
        let engine = Engine::from_params(params);
        let [h, w, c] = engine.graph().input_hwc;
        let image_len = h * w * c;
        let classes = engine.graph().num_classes;
        let image_dims = engine.graph().input_hwc;
        let metrics = Arc::new(Mutex::new(ServerMetrics::default()));
        let stats = super::lock_recover(&metrics).batcher.clone();
        let mut scratch = Scratch::default();
        let execute = move |buf: &[f32], bsz: usize| -> Result<Vec<f32>> {
            engine.forward_scratch(buf, bsz, &mut scratch)
        };
        let batcher = Batcher::spawn(policy, image_len, classes, Box::new(execute), stats);
        Ok(Self { batcher, metrics, classes, image_dims })
    }

    /// Blocking single-image inference; returns the logits row.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply> {
        let t0 = std::time::Instant::now();
        let reply = self.batcher.infer(image)?;
        // Recover from metrics-lock poisoning: losing one histogram
        // update is better than failing an inference that succeeded.
        let mut m = super::lock_recover(&self.metrics);
        m.e2e.record(t0.elapsed());
        m.queue.record(reply.queue_time);
        Ok(reply)
    }

    pub fn metrics(&self) -> Arc<Mutex<ServerMetrics>> {
        self.metrics.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::OverloadPolicy;
    use crate::model::{Node, Op};
    use std::collections::HashMap;
    use std::time::Duration;

    // LatencyHist's own tests (quantile ordering + edge cases) live
    // with the type in `observability::histogram`.

    /// Tiny all-native model for serving tests: one quantized conv.
    fn tiny_native_model() -> (Graph, Weights) {
        let graph = Graph {
            arch: "tinyq".into(),
            variant: "serve-test".into(),
            num_classes: 2,
            input_hwc: [4, 4, 1],
            eval_batch: 4,
            quant_convs: vec!["q1".into()],
            nodes: vec![
                Node { name: "img".into(), op: Op::Input, inputs: vec![] },
                Node {
                    name: "q1".into(),
                    op: Op::Conv { k: 3, stride: 1, out_ch: 2, relu: true, quant: true },
                    inputs: vec!["img".into()],
                },
                Node { name: "g".into(), op: Op::Gap, inputs: vec!["q1".into()] },
                Node { name: "fc".into(), op: Op::Fc { out: 2 }, inputs: vec!["g".into()] },
            ],
        };
        let mut quant = HashMap::new();
        quant.insert(
            "q1".to_string(),
            crate::model::weights::QuantConv {
                wq: (0..18).map(|i| (((i * 37) % 255) as i32 - 127) as i8).collect(),
                k: 9,
                o: 2,
                scale: vec![0.015, 0.02],
                bias: vec![0.05, -0.05],
            },
        );
        let weights = Weights {
            quant,
            float: HashMap::new(),
            fc_w: vec![1.0, -0.5, 0.25, 1.0],
            fc_in: 2,
            fc_out: 2,
            fc_b: vec![0.1, 0.2],
        };
        (graph, weights)
    }

    #[test]
    fn native_server_matches_direct_engine_forward() {
        let (graph, weights) = tiny_native_model();
        let cfg = SparqConfig::named("5opt_r").unwrap();
        let scales = [0.02f32];
        let server = Arc::new(
            InferenceServer::start_native(
                &graph,
                &weights,
                &scales,
                cfg,
                EngineMode::Dense,
                BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(5),
                    ..BatchPolicy::default()
                },
            )
            .unwrap(),
        );
        let engine =
            Engine::new(&graph, &weights, cfg, &scales, EngineMode::Dense).unwrap();

        // 6 concurrent clients with distinct images; every reply must
        // equal the direct single-image forward (no cross-wiring, no
        // padded-row contamination).
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let s = server.clone();
                std::thread::spawn(move || {
                    let img: Vec<f32> = (0..16).map(|j| ((i * 16 + j) as f32) / 40.0).collect();
                    (img.clone(), s.infer(img).unwrap())
                })
            })
            .collect();
        for h in handles {
            let (img, reply) = h.join().unwrap();
            let want = engine.forward(&img, 1).unwrap();
            assert_eq!(reply.logits, want, "served logits diverge from direct forward");
            assert!(reply.batch_size >= 1 && reply.batch_size <= 4);
        }
        let metrics = server.metrics();
        let m = metrics.lock().unwrap();
        assert_eq!(m.e2e.count(), 6);
        // the batcher stats are live through ServerMetrics now — not a
        // dead default-zero copy (the pre-fix behaviour)
        let s = m.batcher.snapshot();
        assert_eq!(s.requests, 6, "batcher stats not wired into ServerMetrics");
        assert!(s.batches >= 1);
        assert_eq!(s.queue_depth, 0, "queue depth gauge must drain to zero");
    }

    #[test]
    fn overload_is_observable_through_server_metrics() {
        // A server over a gated executor: queue fills, the overload is
        // returned to callers *and* visible in ServerMetrics.
        let metrics_probe;
        {
            let (graph, weights) = tiny_native_model();
            let server = InferenceServer::start_native(
                &graph,
                &weights,
                &[0.02f32],
                SparqConfig::A8W8,
                EngineMode::Dense,
                BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::from_micros(50),
                    max_queue_depth: 1,
                    overload: OverloadPolicy::RejectNewest,
                    ..BatchPolicy::default()
                },
            )
            .unwrap();
            // Saturate from several threads; with depth 1 and a real
            // engine at least some submissions must hit the bound or
            // complete — both counters land in the same snapshot.
            let server = Arc::new(server);
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let s = server.clone();
                    std::thread::spawn(move || {
                        let img: Vec<f32> = (0..16).map(|j| ((i + j) as f32) / 20.0).collect();
                        s.infer(img).map(|_| ()).map_err(|e| e.to_string())
                    })
                })
                .collect();
            let mut rejected_seen = 0u64;
            for h in handles {
                if let Err(msg) = h.join().unwrap() {
                    assert!(msg.contains("overloaded"), "{msg}");
                    rejected_seen += 1;
                }
            }
            let m = server.metrics();
            let s = m.lock().unwrap().batcher.snapshot();
            assert_eq!(s.rejected, rejected_seen, "metrics disagree with caller errors");
            assert_eq!(s.requests + s.rejected, 8, "unaccounted requests: {s:?}");
            metrics_probe = s;
        }
        assert!(metrics_probe.peak_queue_depth <= 1);
    }
}
