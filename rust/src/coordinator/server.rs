//! In-process inference service: PJRT executable behind the dynamic
//! batcher, plus latency/throughput metrics. `examples/serve_bench.rs`
//! drives it with concurrent synthetic clients.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::quant::SparqConfig;
use crate::runtime::{ArtifactKind, ModelArtifacts, PjrtRuntime, TensorArg};

use super::batcher::{BatchPolicy, Batcher, BatcherStats, Reply};

/// Latency histogram with fixed microsecond buckets (powers of two).
#[derive(Default, Debug, Clone)]
pub struct LatencyHist {
    buckets: [u64; 24],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl LatencyHist {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as u64).min(23) as usize;
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn mean_us(&self) -> f64 {
        self.sum_us as f64 / self.count.max(1) as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Approximate quantile from the histogram (upper bucket bound).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let target = (self.count as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target && c > 0 {
                return 1u64 << i;
            }
        }
        self.max_us
    }
}

/// Aggregated server metrics.
#[derive(Default, Debug)]
pub struct ServerMetrics {
    pub e2e: LatencyHist,
    pub queue: LatencyHist,
    pub batcher: BatcherStats,
}

/// A model served through the batched PJRT path.
pub struct InferenceServer {
    batcher: Batcher,
    metrics: Arc<Mutex<ServerMetrics>>,
    pub classes: usize,
    pub image_dims: [usize; 3],
}

impl InferenceServer {
    /// Load the model's sparq artifact and start the batching worker.
    pub fn start(
        rt: Arc<PjrtRuntime>,
        model: &ModelArtifacts,
        image_dims: [usize; 3],
        classes: usize,
        scales: Vec<f32>,
        cfg: SparqConfig,
        policy: BatchPolicy,
    ) -> Result<Self> {
        let exe = rt.load(&model.hlo_path(ArtifactKind::Sparq))?;
        let metrics = Arc::new(Mutex::new(ServerMetrics::default()));
        let stats = Arc::new(Mutex::new(BatcherStats::default()));
        let [h, w, c] = image_dims;
        let image_len = h * w * c;
        let nscales = scales.len();
        let cfg_vec = cfg.to_vec().to_vec();
        let execute = move |buf: &[f32], batch: usize| -> Result<Vec<f32>> {
            let out = exe.run(&[
                TensorArg::f32(&[batch, h, w, c], buf.to_vec()),
                TensorArg::f32(&[nscales], scales.clone()),
                TensorArg::i32(&[5], cfg_vec.clone()),
            ])?;
            Ok(out[0].as_f32().to_vec())
        };
        let batcher = Batcher::spawn(policy, image_len, classes, Box::new(execute), stats);
        Ok(Self { batcher, metrics, classes, image_dims })
    }

    /// Blocking single-image inference; returns the logits row.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply> {
        let t0 = std::time::Instant::now();
        let reply = self.batcher.infer(image)?;
        let mut m = self.metrics.lock().unwrap();
        m.e2e.record(t0.elapsed());
        m.queue.record(reply.queue_time);
        Ok(reply)
    }

    pub fn metrics(&self) -> Arc<Mutex<ServerMetrics>> {
        self.metrics.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHist::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 100_000);
    }
}
