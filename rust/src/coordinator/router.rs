//! Sharded multi-engine serving router.
//!
//! One process, N engines: an [`InferenceRouter`] hosts any number of
//! **named models**, each served by one or more **replica shards**. A
//! shard is a dynamic [`Batcher`](super::batcher::Batcher) with its own
//! worker thread and its own engine scratch; all shards of a model
//! execute through cheap [`Engine`] handles over one shared
//! `Arc<ModelParams>` — the graph, weights and prepared weight tables
//! exist **once** per model no matter how many replicas serve it.
//! Replica count is a runtime throughput knob, not a memory multiplier
//! (the whole point of SPARQ's memory economy).
//!
//! ```text
//!   infer("resnet10", img)                 infer("resnet18", img)
//!          │                                        │
//!          ▼ shallowest queue wins                  ▼
//!   ┌─────────────────────────────┐        ┌────────────────────┐
//!   │ shard 0   shard 1   shard 2 │        │ shard 0    shard 1 │
//!   │ batcher   batcher   batcher │        │ batcher    batcher │
//!   │ engine────engine────engine  │        │ engine─────engine  │
//!   │     └──── Arc<ModelParams> ─┘        │    └─ Arc<ModelParams>
//!   └─────────────────────────────┘        └────────────────────┘
//! ```
//!
//! * **Sharding** — dispatch is load-aware: [`InferenceRouter::infer`]
//!   (and its non-blocking twin [`InferenceRouter::submit`]) sends each
//!   request to the shard with the shallowest live `queue_depth` gauge,
//!   breaking ties with a rotating cursor — all-idle traffic therefore
//!   degenerates to exact round-robin, and a shard backed up behind a
//!   slow executor stops receiving new work.
//!   [`InferenceRouter::infer_on`] pins a shard (tests, session
//!   affinity).
//! * **Isolation** — each shard has its own queue, worker and executor:
//!   a failing replica errors its *own* callers with the real message
//!   while sibling shards keep serving.
//! * **Backpressure** — every shard queue is bounded by its
//!   [`BatchPolicy`]; overload surfaces as an error to the caller and
//!   as shed/rejected counts in the shard's stats, never as unbounded
//!   memory growth.
//! * **Metrics** — [`InferenceRouter::metrics`] reports per-shard
//!   latency + batcher snapshots and the merged aggregate per model;
//!   [`InferenceRouter::aggregate`] merges across every model.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::model::{Engine, ModelParams, Scratch};

use super::batcher::{
    BatchPolicy, Batcher, BatcherSnapshot, BatcherStats, ExecuteFn, PendingReply, Reply,
};
use super::server::LatencyHist;

/// One replica: a batcher worker plus its metrics.
struct Shard {
    batcher: Batcher,
    stats: Arc<BatcherStats>,
    /// End-to-end latency of successful requests routed to this shard.
    e2e: Mutex<LatencyHist>,
}

/// All shards serving one named model.
struct ModelShards {
    image_len: usize,
    classes: usize,
    shards: Vec<Shard>,
    /// Tie-break cursor for load-aware dispatch; wraps on overflow
    /// (harmless modulo shards).
    cursor: AtomicUsize,
    /// Bytes of the parameter store shared by every shard (0 for
    /// executor-backed entries where the router can't see parameters).
    param_bytes: usize,
}

impl ModelShards {
    /// Load-aware shard pick: the live `queue_depth` gauge decides —
    /// the shallowest queue wins, so a shard backed up behind a slow
    /// executor stops receiving new work while its siblings stay busy.
    /// The scan starts at a rotating cursor so depth ties break fairly;
    /// when every queue is empty (the common sequential case) that
    /// degenerates to exact round-robin, keeping dispatch deterministic
    /// for idle routers.
    fn pick(&self) -> usize {
        let n = self.shards.len();
        let start = self.cursor.fetch_add(1, Relaxed) % n;
        let mut best = start;
        let mut best_depth = u64::MAX;
        for off in 0..n {
            let idx = (start + off) % n;
            let depth = self.shards[idx].stats.queue_depth.load(Relaxed);
            if depth < best_depth {
                best_depth = depth;
                best = idx;
                if depth == 0 {
                    break; // nothing beats an empty queue
                }
            }
        }
        best
    }
}

/// Per-shard metrics view.
#[derive(Clone, Debug, Default)]
pub struct ShardMetrics {
    pub shard: usize,
    /// Successful requests completed through this shard.
    pub completed: u64,
    pub mean_latency_us: f64,
    pub p99_latency_us: u64,
    pub batcher: BatcherSnapshot,
}

/// Per-model metrics: every shard plus the merged aggregate.
#[derive(Clone, Debug, Default)]
pub struct ModelMetrics {
    pub model: String,
    pub replicas: usize,
    /// Parameter bytes held once and shared by all replicas.
    pub param_bytes: usize,
    pub shards: Vec<ShardMetrics>,
    pub total: BatcherSnapshot,
}

enum EntrySource {
    /// Native-engine replicas over one shared parameter block.
    Params { params: Arc<ModelParams>, threads: Option<usize> },
    /// Caller-supplied executors, one per replica (PJRT executables,
    /// test doubles). `executors.len()` is the replica count.
    Executors { image_len: usize, classes: usize, executors: Vec<Box<ExecuteFn>> },
}

struct Entry {
    name: String,
    replicas: usize,
    policy: BatchPolicy,
    source: EntrySource,
}

/// Builder for [`InferenceRouter`]. Add models, then [`RouterBuilder::build`].
#[derive(Default)]
pub struct RouterBuilder {
    entries: Vec<Entry>,
}

impl RouterBuilder {
    /// Serve `replicas` native-engine shards of one model, all sharing
    /// `params`. Each replica uses the engine's default thread count.
    pub fn model(
        self,
        name: &str,
        params: Arc<ModelParams>,
        replicas: usize,
        policy: BatchPolicy,
    ) -> Self {
        self.model_entry(name, params, replicas, policy, None)
    }

    /// Like [`RouterBuilder::model`] but pins every replica engine to
    /// `threads` workers — use `1` when the replicas themselves are the
    /// parallelism (one core per shard) to avoid oversubscription.
    pub fn model_with_threads(
        self,
        name: &str,
        params: Arc<ModelParams>,
        replicas: usize,
        policy: BatchPolicy,
        threads: usize,
    ) -> Self {
        self.model_entry(name, params, replicas, policy, Some(threads))
    }

    fn model_entry(
        mut self,
        name: &str,
        params: Arc<ModelParams>,
        replicas: usize,
        policy: BatchPolicy,
        threads: Option<usize>,
    ) -> Self {
        self.entries.push(Entry {
            name: name.to_string(),
            replicas,
            policy,
            source: EntrySource::Params { params, threads },
        });
        self
    }

    /// Serve a model through caller-supplied batch executors, one per
    /// replica — the escape hatch for PJRT-backed shards and for tests
    /// that need a deliberately failing replica.
    pub fn model_from_executors(
        mut self,
        name: &str,
        image_len: usize,
        classes: usize,
        executors: Vec<Box<ExecuteFn>>,
        policy: BatchPolicy,
    ) -> Self {
        let replicas = executors.len();
        self.entries.push(Entry {
            name: name.to_string(),
            replicas,
            policy,
            source: EntrySource::Executors { image_len, classes, executors },
        });
        self
    }

    /// Spawn every shard worker and produce the router.
    pub fn build(self) -> Result<InferenceRouter> {
        let mut models = HashMap::new();
        for entry in self.entries {
            if entry.replicas == 0 {
                bail!("model `{}`: replica count must be >= 1", entry.name);
            }
            if models.contains_key(&entry.name) {
                bail!("duplicate model name `{}` in router", entry.name);
            }
            // Validate the policy here so a bad config is a build error,
            // not a panic inside Batcher::spawn's asserts.
            if entry.policy.max_batch == 0 {
                bail!("model `{}`: policy.max_batch must be >= 1", entry.name);
            }
            if entry.policy.max_queue_depth == 0 {
                bail!("model `{}`: policy.max_queue_depth must be >= 1", entry.name);
            }
            if let Some(limit) = entry.policy.max_queue_wait {
                if limit <= entry.policy.max_wait {
                    bail!(
                        "model `{}`: policy.max_queue_wait ({:?}) must exceed max_wait ({:?}) \
                         — queue age includes the batch-fill window, so a smaller deadline \
                         would shed every request",
                        entry.name,
                        limit,
                        entry.policy.max_wait
                    );
                }
            }
            let (image_len, classes, param_bytes, executors): (
                usize,
                usize,
                usize,
                Vec<Box<ExecuteFn>>,
            ) = match entry.source {
                EntrySource::Params { params, threads } => {
                    let [h, w, c] = params.graph.input_hwc;
                    let image_len = h * w * c;
                    let classes = params.graph.num_classes;
                    let param_bytes = params.weights.param_bytes();
                    let executors = (0..entry.replicas)
                        .map(|_| {
                            // A cheap handle per shard — Arc bumps, no
                            // parameter copies — plus shard-private scratch.
                            let mut engine = Engine::from_params(params.clone());
                            if let Some(t) = threads {
                                engine.set_threads(t);
                            }
                            let mut scratch = Scratch::default();
                            Box::new(move |buf: &[f32], bsz: usize| {
                                engine.forward_scratch(buf, bsz, &mut scratch)
                            }) as Box<ExecuteFn>
                        })
                        .collect();
                    (image_len, classes, param_bytes, executors)
                }
                EntrySource::Executors { image_len, classes, executors } => {
                    (image_len, classes, 0, executors)
                }
            };
            let shards = executors
                .into_iter()
                .map(|exec| {
                    let stats = Arc::new(BatcherStats::default());
                    let batcher =
                        Batcher::spawn(entry.policy, image_len, classes, exec, stats.clone());
                    Shard { batcher, stats, e2e: Mutex::new(LatencyHist::default()) }
                })
                .collect();
            models.insert(
                entry.name,
                ModelShards {
                    image_len,
                    classes,
                    shards,
                    cursor: AtomicUsize::new(0),
                    param_bytes,
                },
            );
        }
        if models.is_empty() {
            bail!("router has no models; add at least one before build()");
        }
        Ok(InferenceRouter { models })
    }
}

/// Routes inference requests across named models and their replica
/// shards. See the module docs for the architecture.
pub struct InferenceRouter {
    models: HashMap<String, ModelShards>,
}

impl InferenceRouter {
    pub fn builder() -> RouterBuilder {
        RouterBuilder::default()
    }

    /// Registered model names, sorted.
    pub fn model_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.models.keys().map(String::as_str).collect();
        names.sort_unstable();
        names
    }

    pub fn replicas(&self, model: &str) -> Result<usize> {
        Ok(self.shards_of(model)?.shards.len())
    }

    /// (image_len, classes) the named model expects/produces.
    pub fn shape(&self, model: &str) -> Result<(usize, usize)> {
        let ms = self.shards_of(model)?;
        Ok((ms.image_len, ms.classes))
    }

    fn shards_of(&self, model: &str) -> Result<&ModelShards> {
        self.models.get(model).with_context(|| {
            format!("router has no model named `{model}` (available: {:?})", self.model_names())
        })
    }

    /// Dispatch by model name, load-aware across that model's shards
    /// (shallowest live queue wins; ties rotate round-robin). Blocks
    /// until the reply; executor failures and overload errors carry the
    /// shard's real message.
    pub fn infer(&self, model: &str, image: Vec<f32>) -> Result<Reply> {
        let ms = self.shards_of(model)?;
        Self::shard_infer(&ms.shards[ms.pick()], image)
    }

    /// Non-blocking dispatch for event-driven front ends (the HTTP
    /// layer): the same load-aware shard pick as
    /// [`InferenceRouter::infer`], but the caller gets a
    /// [`PendingReply`] to poll via
    /// [`try_wait`](PendingReply::try_wait) instead of parking a
    /// thread. The per-shard latency histograms only track the blocking
    /// path; submit traffic still lands in every batcher counter.
    pub fn submit(&self, model: &str, image: Vec<f32>) -> Result<PendingReply> {
        let ms = self.shards_of(model)?;
        ms.shards[ms.pick()].batcher.submit(image)
    }

    /// Dispatch to one specific shard of a model (session affinity,
    /// deterministic tests).
    pub fn infer_on(&self, model: &str, shard: usize, image: Vec<f32>) -> Result<Reply> {
        let ms = self.shards_of(model)?;
        if shard >= ms.shards.len() {
            bail!(
                "model `{model}` has {} shard(s); no shard {shard}",
                ms.shards.len()
            );
        }
        Self::shard_infer(&ms.shards[shard], image)
    }

    fn shard_infer(shard: &Shard, image: Vec<f32>) -> Result<Reply> {
        let t0 = Instant::now();
        let reply = shard.batcher.infer(image)?;
        // Successful requests only: overload rejections return in
        // microseconds and would drag the latency histogram down.
        shard.e2e.lock().unwrap().record(t0.elapsed());
        Ok(reply)
    }

    /// Per-shard and aggregate metrics for one model.
    pub fn metrics(&self, model: &str) -> Result<ModelMetrics> {
        let ms = self.shards_of(model)?;
        let mut shards = Vec::with_capacity(ms.shards.len());
        let mut total = BatcherSnapshot::default();
        for (i, s) in ms.shards.iter().enumerate() {
            let snap = s.stats.snapshot();
            total.merge(&snap);
            let e2e = s.e2e.lock().unwrap();
            shards.push(ShardMetrics {
                shard: i,
                completed: e2e.count(),
                mean_latency_us: e2e.mean_us(),
                p99_latency_us: e2e.quantile_us(0.99),
                batcher: snap,
            });
        }
        Ok(ModelMetrics {
            model: model.to_string(),
            replicas: ms.shards.len(),
            param_bytes: ms.param_bytes,
            shards,
            total,
        })
    }

    /// Merged batcher snapshot across every model and shard.
    pub fn aggregate(&self) -> BatcherSnapshot {
        let mut total = BatcherSnapshot::default();
        for ms in self.models.values() {
            for s in &ms.shards {
                total.merge(&s.stats.snapshot());
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::OverloadPolicy;
    use crate::model::{EngineMode, Graph, Node, Op, Weights};
    use crate::model::weights::QuantConv;
    use crate::quant::SparqConfig;
    use std::collections::HashMap;
    use std::time::Duration;

    /// Tiny all-native model: one quantized conv, 4x4x1 -> 2 classes.
    fn tiny_params(seed: i8) -> Arc<ModelParams> {
        let graph = Graph {
            arch: "tinyq".into(),
            variant: "router-test".into(),
            num_classes: 2,
            input_hwc: [4, 4, 1],
            eval_batch: 4,
            quant_convs: vec!["q1".into()],
            nodes: vec![
                Node { name: "img".into(), op: Op::Input, inputs: vec![] },
                Node {
                    name: "q1".into(),
                    op: Op::Conv { k: 3, stride: 1, out_ch: 2, relu: true, quant: true },
                    inputs: vec!["img".into()],
                },
                Node { name: "g".into(), op: Op::Gap, inputs: vec!["q1".into()] },
                Node { name: "fc".into(), op: Op::Fc { out: 2 }, inputs: vec!["g".into()] },
            ],
        };
        let mut quant = HashMap::new();
        quant.insert(
            "q1".to_string(),
            QuantConv {
                wq: (0..18)
                    .map(|i| ((((i * 37) % 255) as i32 - 127) as i8).wrapping_add(seed))
                    .collect(),
                k: 9,
                o: 2,
                scale: vec![0.015, 0.02],
                bias: vec![0.05, -0.05],
            },
        );
        let weights = Weights {
            quant,
            float: HashMap::new(),
            fc_w: vec![1.0, -0.5, 0.25, 1.0],
            fc_in: 2,
            fc_out: 2,
            fc_b: vec![0.1, 0.2],
        };
        Arc::new(
            ModelParams::new(
                Arc::new(graph),
                Arc::new(weights),
                SparqConfig::named("5opt_r").unwrap(),
                &[0.02],
                EngineMode::Dense,
            )
            .unwrap(),
        )
    }

    fn img(i: usize) -> Vec<f32> {
        (0..16).map(|j| ((i * 16 + j) as f32) / 40.0).collect()
    }

    fn quick_policy(max_batch: usize) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(200),
            ..BatchPolicy::default()
        }
    }

    #[test]
    fn replicas_share_one_parameter_copy() {
        let params = tiny_params(0);
        let before = Arc::strong_count(&params);
        let router = InferenceRouter::builder()
            .model("m", params.clone(), 3, quick_policy(2))
            .build()
            .unwrap();
        // 3 replica engines = 3 Arc bumps over the builder-held copy —
        // shared storage, not 3 deep clones (the acceptance criterion).
        assert_eq!(Arc::strong_count(&params), before + 3);
        assert_eq!(router.replicas("m").unwrap(), 3);
        let m = router.metrics("m").unwrap();
        assert_eq!(m.param_bytes, params.weights.param_bytes());
        assert!(m.param_bytes > 0);
        // all replicas compute the same function as a direct engine
        let engine = Engine::from_params(params.clone());
        let want = engine.forward(&img(7), 1).unwrap();
        for shard in 0..3 {
            let got = router.infer_on("m", shard, img(7)).unwrap();
            assert_eq!(got.logits, want, "shard {shard} diverged from the shared model");
        }
        // Dropping the router closes every shard queue; the workers
        // (which own the replica engines) exit asynchronously, so poll.
        drop(router);
        let deadline = Instant::now() + Duration::from_secs(10);
        while Arc::strong_count(&params) != before + 1 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(
            Arc::strong_count(&params),
            before + 1,
            "replica engines were not released after router shutdown"
        );
    }

    #[test]
    fn round_robin_sharding_is_deterministic() {
        let router = InferenceRouter::builder()
            .model("m", tiny_params(0), 3, quick_policy(1))
            .build()
            .unwrap();
        // 9 sequential requests over 3 idle shards: every queue gauge
        // reads 0 at dispatch time, so load-aware picking degenerates
        // to its rotating tie-break — exactly 3 per shard, in order
        // 0,1,2,0,1,2,... (deterministic dispatch for idle routers).
        for i in 0..9 {
            router.infer("m", img(i)).unwrap();
        }
        let m = router.metrics("m").unwrap();
        let per_shard: Vec<u64> = m.shards.iter().map(|s| s.batcher.requests).collect();
        assert_eq!(per_shard, vec![3, 3, 3], "round-robin skewed: {per_shard:?}");
        assert_eq!(m.total.requests, 9);
    }

    #[test]
    fn dispatch_by_model_name() {
        // Two different parameterizations under one router: replies must
        // come from the model addressed by name.
        let pa = tiny_params(0);
        let pb = tiny_params(11);
        let router = InferenceRouter::builder()
            .model("alpha", pa.clone(), 2, quick_policy(2))
            .model("beta", pb.clone(), 1, quick_policy(2))
            .build()
            .unwrap();
        assert_eq!(router.model_names(), vec!["alpha", "beta"]);
        let want_a = Engine::from_params(pa).forward(&img(3), 1).unwrap();
        let want_b = Engine::from_params(pb).forward(&img(3), 1).unwrap();
        assert_ne!(want_a, want_b, "test models degenerate: identical outputs");
        assert_eq!(router.infer("alpha", img(3)).unwrap().logits, want_a);
        assert_eq!(router.infer("beta", img(3)).unwrap().logits, want_b);
        // unknown names are a descriptive error, not a panic
        let err = router.infer("gamma", img(0)).unwrap_err().to_string();
        assert!(err.contains("gamma") && err.contains("alpha"), "{err}");
    }

    #[test]
    fn poisoned_replica_errors_its_own_callers_only() {
        // shard 0 echoes; shard 1 always fails. Callers pinned to shard
        // 1 get the real error; shard 0 callers are unaffected — before
        // and after the failures.
        let ok: Box<ExecuteFn> =
            Box::new(|buf: &[f32], bsz: usize| Ok(buf[..bsz].to_vec()));
        let poisoned: Box<ExecuteFn> =
            Box::new(|_buf: &[f32], _bsz: usize| Err(anyhow::anyhow!("replica 1 lost its device")));
        let router = InferenceRouter::builder()
            .model_from_executors("m", 1, 1, vec![ok, poisoned], quick_policy(2))
            .build()
            .unwrap();
        assert_eq!(router.infer_on("m", 0, vec![5.0]).unwrap().logits, vec![5.0]);
        for _ in 0..3 {
            let msg = router.infer_on("m", 1, vec![6.0]).unwrap_err().to_string();
            assert!(msg.contains("replica 1 lost its device"), "{msg}");
        }
        // sibling shard still healthy after repeated failures next door
        assert_eq!(router.infer_on("m", 0, vec![7.0]).unwrap().logits, vec![7.0]);
        let m = router.metrics("m").unwrap();
        assert_eq!(m.shards[0].batcher.exec_errors, 0, "healthy shard counted errors");
        assert!(m.shards[1].batcher.exec_errors >= 3);
        assert!(m.total.exec_errors >= 3);
        // out-of-range shard index is an error, not a panic
        assert!(router.infer_on("m", 2, vec![0.0]).is_err());
    }

    #[test]
    fn aggregate_metrics_are_consistent_under_concurrent_load() {
        let router = Arc::new(
            InferenceRouter::builder()
                .model("m", tiny_params(0), 3, quick_policy(4))
                .build()
                .unwrap(),
        );
        let engine = Engine::from_params(tiny_params(0));
        let (threads, per) = (8usize, 12usize);
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let r = router.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        let idx = t * per + i;
                        let reply = r.infer("m", img(idx)).unwrap();
                        assert_eq!(reply.logits.len(), 2);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // spot-check correctness of a routed answer after the storm
        assert_eq!(
            router.infer("m", img(1)).unwrap().logits,
            engine.forward(&img(1), 1).unwrap()
        );
        let total_sent = (threads * per) as u64 + 1;
        let m = router.metrics("m").unwrap();
        assert_eq!(m.total.requests, total_sent, "aggregate lost requests");
        let per_shard_sum: u64 = m.shards.iter().map(|s| s.batcher.requests).sum();
        assert_eq!(per_shard_sum, total_sent, "shard sum != aggregate");
        let completed_sum: u64 = m.shards.iter().map(|s| s.completed).sum();
        assert_eq!(completed_sum, total_sent, "latency counts lost requests");
        assert_eq!(m.total.exec_errors, 0);
        assert_eq!(m.total.queue_depth, 0, "queues must drain");
        assert_eq!(router.aggregate().requests, total_sent);
    }

    #[test]
    fn bounded_shard_queue_returns_overload_not_oom() {
        // One slow executor shard with queue depth 2: a burst must see
        // overload errors while admitted requests all finish.
        let slow: Box<ExecuteFn> = Box::new(|buf: &[f32], bsz: usize| {
            std::thread::sleep(Duration::from_millis(2));
            Ok(buf[..bsz].to_vec())
        });
        let router = Arc::new(
            InferenceRouter::builder()
                .model_from_executors(
                    "m",
                    1,
                    1,
                    vec![slow],
                    BatchPolicy {
                        max_batch: 1,
                        max_wait: Duration::from_micros(50),
                        max_queue_depth: 2,
                        overload: OverloadPolicy::RejectNewest,
                        ..BatchPolicy::default()
                    },
                )
                .build()
                .unwrap(),
        );
        let handles: Vec<_> = (0..12)
            .map(|i| {
                let r = router.clone();
                std::thread::spawn(move || r.infer("m", vec![i as f32]).map(|_| ()))
            })
            .collect();
        let mut overloads = 0;
        for h in handles {
            if let Err(e) = h.join().unwrap() {
                assert!(e.to_string().contains("overloaded"), "{e}");
                overloads += 1;
            }
        }
        let m = router.metrics("m").unwrap();
        assert_eq!(m.total.rejected, overloads);
        assert_eq!(m.total.requests + m.total.rejected, 12);
        assert!(m.total.peak_queue_depth <= 2, "queue exceeded bound: {:?}", m.total);
    }

    #[test]
    fn load_aware_dispatch_starves_the_backed_up_shard() {
        use std::sync::mpsc::channel;
        // shard 0 parks inside execute() until gated; shard 1 replies
        // instantly. ROADMAP "load-aware dispatch": the deep queue must
        // stop receiving new work.
        let (gate_tx, gate_rx) = channel::<()>();
        let (entered_tx, entered_rx) = channel::<()>();
        let gated: Box<ExecuteFn> = Box::new(move |buf: &[f32], bsz: usize| {
            entered_tx.send(()).ok();
            gate_rx.recv().ok();
            Ok(buf[..bsz].to_vec())
        });
        let fast: Box<ExecuteFn> = Box::new(|buf: &[f32], bsz: usize| Ok(buf[..bsz].to_vec()));
        let router = Arc::new(
            InferenceRouter::builder()
                .model_from_executors("m", 1, 1, vec![gated, fast], quick_policy(1))
                .build()
                .unwrap(),
        );
        // Occupy shard 0: one in-flight request parks its worker, one
        // queued request raises its live queue_depth gauge to 1.
        let r0 = router.clone();
        let inflight = std::thread::spawn(move || r0.infer_on("m", 0, vec![100.0]).unwrap());
        entered_rx.recv().unwrap();
        let r0 = router.clone();
        let queued = std::thread::spawn(move || r0.infer_on("m", 0, vec![101.0]).unwrap());
        let deadline = Instant::now() + Duration::from_secs(10);
        while router.metrics("m").unwrap().shards[0].batcher.queue_depth == 0 {
            assert!(Instant::now() < deadline, "queued request never raised the depth gauge");
            std::thread::yield_now();
        }
        // Every new request must now route to shard 1 (gauge 0) rather
        // than blind round-robin alternating onto the stuck shard.
        for i in 0..8 {
            assert_eq!(router.infer("m", vec![i as f32]).unwrap().logits, vec![i as f32]);
        }
        let m = router.metrics("m").unwrap();
        assert_eq!(m.shards[1].batcher.requests, 8, "fast shard missed traffic");
        assert_eq!(m.shards[0].batcher.requests, 0, "backed-up shard must be starved");
        // Release the gate: the pinned requests still complete on shard
        // 0 — load-awareness never touches pinned dispatch.
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        assert_eq!(inflight.join().unwrap().logits, vec![100.0]);
        assert_eq!(queued.join().unwrap().logits, vec![101.0]);
        assert_eq!(router.metrics("m").unwrap().shards[0].batcher.requests, 2);
    }

    #[test]
    fn submit_returns_pollable_replies_with_live_results() {
        let params = tiny_params(0);
        let router = InferenceRouter::builder()
            .model("m", params.clone(), 2, quick_policy(2))
            .build()
            .unwrap();
        let engine = Engine::from_params(params);
        // Non-blocking path: submit a burst, then poll every reply to
        // completion — results must be bit-identical to direct forward.
        let mut pending: Vec<_> =
            (0..6).map(|i| (i, router.submit("m", img(i)).unwrap())).collect();
        let deadline = Instant::now() + Duration::from_secs(10);
        while !pending.is_empty() {
            assert!(Instant::now() < deadline, "submitted replies never resolved");
            pending.retain_mut(|(i, p)| match p.try_wait() {
                None => true,
                Some(outcome) => {
                    let reply = outcome.expect("healthy router must not fail");
                    assert_eq!(
                        reply.logits,
                        engine.forward(&img(*i), 1).unwrap(),
                        "submit path diverged from direct forward for image {i}"
                    );
                    false
                }
            });
            std::thread::sleep(Duration::from_micros(50));
        }
        assert_eq!(router.aggregate().requests, 6);
    }

    #[test]
    fn builder_rejects_bad_configs() {
        assert!(InferenceRouter::builder().build().is_err(), "empty router must not build");
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 0, quick_policy(1))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains(">= 1"), "{err}");
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, quick_policy(1))
            .model("m", tiny_params(0), 1, quick_policy(1))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
        // degenerate policies are build errors, not spawn panics
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, quick_policy(0))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_batch"), "{err}");
        let bad_depth = BatchPolicy { max_queue_depth: 0, ..BatchPolicy::default() };
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, bad_depth)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_queue_depth"), "{err}");
        // A queue deadline inside the batch-fill window would shed every
        // request on an idle server — a build error, not a footgun.
        let bad_deadline = BatchPolicy {
            max_wait: Duration::from_millis(5),
            max_queue_wait: Some(Duration::from_millis(3)),
            ..BatchPolicy::default()
        };
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, bad_deadline)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_queue_wait"), "{err}");
    }
}
