//! Sharded multi-engine serving router.
//!
//! One process, N engines: an [`InferenceRouter`] hosts any number of
//! **named models**, each served through one or more **policy
//! variants**, each variant by one or more **replica shards**. A shard
//! is a dynamic [`Batcher`](super::batcher::Batcher) with its own
//! worker thread and its own engine scratch; all shards of a variant
//! execute through cheap [`Engine`] handles over one shared
//! `Arc<ModelParams>`, and every variant of a model shares the *same*
//! `Arc<Graph>` + `Arc<Weights>` (enforced at build) — the weight
//! bytes exist **once** per model no matter how many replicas or
//! quantization operating points serve it. Replica count is a runtime
//! throughput knob, and variant count a quantization knob; neither is a
//! memory multiplier (the whole point of SPARQ's memory economy).
//!
//! ```text
//!   infer("resnet18", img)        infer_variant("resnet18", "first8", img)
//!          │                                        │
//!          ▼ default variant                        ▼ named variant
//!   ┌───────────────────────────────────────────────────────────┐
//!   │ variant "a4w8"                 variant "first8"           │
//!   │ shard 0   shard 1              shard 0   shard 1          │
//!   │ engine────engine               engine────engine           │
//!   │    └─ Arc<ModelParams> A          └─ Arc<ModelParams> B   │
//!   │           └────────── Arc<Graph> + Arc<Weights> ──┘       │
//!   └───────────────────────────────────────────────────────────┘
//! ```
//!
//! * **Variants** — [`RouterBuilder::model_variant`] registers one
//!   quantization operating point of a model (its own prepared
//!   per-layer policy tables — see
//!   [`ModelParams::with_policy`](crate::model::ModelParams::with_policy));
//!   the first registered variant is the default that plain
//!   [`InferenceRouter::infer`] dispatches to. Build-time validation
//!   rejects variants whose `ModelParams` do not share the model's
//!   graph/weights allocations.
//! * **Sharding** — dispatch is load-aware: [`InferenceRouter::infer`]
//!   (and its non-blocking twin [`InferenceRouter::submit`]) sends each
//!   request to the shard with the shallowest live `queue_depth` gauge,
//!   breaking ties with a rotating cursor — all-idle traffic therefore
//!   degenerates to exact round-robin, and a shard backed up behind a
//!   slow executor stops receiving new work.
//!   [`InferenceRouter::infer_on`] pins a shard (tests, session
//!   affinity).
//! * **SLO degradation** — unaddressed dispatch flows through one seam
//!   that, when [`InferenceRouter::set_slo_policy`] has installed a
//!   [`SloPolicy`](super::slo::SloPolicy) ladder, routes new requests
//!   to a cheaper variant while the serving rung is over its pressure
//!   thresholds and walks back as pressure clears — degrade quality
//!   instead of shedding traffic (see [`super::slo`]). With no policy
//!   installed the seam is the plain default-variant lookup.
//! * **Isolation** — each shard has its own queue, worker and executor:
//!   a failing replica errors its *own* callers with the real message
//!   while sibling shards keep serving.
//! * **Backpressure** — every shard queue is bounded by its
//!   [`BatchPolicy`]; overload surfaces as an error to the caller and
//!   as shed/rejected counts in the shard's stats, never as unbounded
//!   memory growth.
//! * **Metrics** — [`InferenceRouter::metrics`] reports per-shard
//!   latency + batcher snapshots and the merged aggregate per model;
//!   [`InferenceRouter::aggregate`] merges across every model.
//! * **Versioning** — every params-built variant owns a
//!   [`VersionSlot`] + [`VersionTracker`]
//!   (see [`super::registry`]): executors re-read the slot once per
//!   batch, so [`InferenceRouter::reload_variant`] can stage a new
//!   generation ([`ReloadSource`]: explicit params, a new policy over
//!   the live weights, a weights `.npz`, or a deterministic test
//!   perturbation) and hot-swap or canary it with zero dropped
//!   requests — in-flight batches drain on the old `Arc`.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::json::JsonValue;
use crate::model::{Engine, ModelParams, Scratch, Weights};
use crate::quant::QuantPolicy;
use crate::search::{SearchPhase, SearchProgress};

use super::batcher::{
    BatchPolicy, Batcher, BatcherSnapshot, BatcherStats, ExecuteFn, PendingReply, Reply,
};
use super::registry::{
    self, Dispatch, ModelVersion, RolloutConfig, RolloutStatus, VersionProvenance, VersionSlot,
    VersionTracker,
};
use super::server::LatencyHist;
use super::slo::{LadderState, PressureSample, SloPolicy, SloStatus};

/// One replica: a batcher worker plus its metrics.
struct Shard {
    batcher: Batcher,
    stats: Arc<BatcherStats>,
    /// End-to-end latency of successful requests routed to this shard.
    e2e: Mutex<LatencyHist>,
}

/// One quantization variant of a model: its own prepared parameter
/// block (per-layer policy tables) behind replica shards, sharing the
/// graph/weights allocations with its sibling variants.
struct VariantShards {
    name: String,
    shards: Vec<Shard>,
    /// Tie-break cursor for load-aware dispatch; wraps on overflow
    /// (harmless modulo shards).
    cursor: AtomicUsize,
    /// Versioned parameter slot — every replica executor reads it once
    /// per batch, which is what makes the variant hot-swappable. `None`
    /// for executor-backed entries where the router can't see
    /// parameters (those can't be reloaded).
    slot: Option<Arc<VersionSlot>>,
    /// Rollout state machine (canary routing, drain accounting) shared
    /// by the variant's replicas; paired with `slot`.
    tracker: Option<Arc<VersionTracker>>,
}

impl VariantShards {
    /// Load-aware shard pick: the live `queue_depth` gauge decides —
    /// the shallowest queue wins, so a shard backed up behind a slow
    /// executor stops receiving new work while its siblings stay busy.
    /// The scan starts at a rotating cursor so depth ties break fairly;
    /// when every queue is empty (the common sequential case) that
    /// degenerates to exact round-robin, keeping dispatch deterministic
    /// for idle routers.
    fn pick(&self) -> usize {
        let n = self.shards.len();
        let start = self.cursor.fetch_add(1, Relaxed) % n;
        let mut best = start;
        let mut best_depth = u64::MAX;
        for off in 0..n {
            let idx = (start + off) % n;
            let depth = self.shards[idx].stats.queue_depth.load(Relaxed);
            if depth < best_depth {
                best_depth = depth;
                best = idx;
                if depth == 0 {
                    break; // nothing beats an empty queue
                }
            }
        }
        best
    }

    /// The currently serving parameter block (an `Arc` clone of the
    /// live version's params; `None` for executor-backed variants).
    fn current_params(&self) -> Option<Arc<ModelParams>> {
        self.slot.as_ref().map(|s| Arc::clone(&s.load().params))
    }
}

/// Ladder machinery for one model — present on every model, inert (and
/// free) until [`InferenceRouter::set_slo_policy`] installs a policy.
struct SloCell {
    /// Fast-path flag: `false` means default dispatch takes exactly the
    /// pre-SLO route (one relaxed load added, no lock, no sampling) —
    /// the acceptance bar is *byte-for-byte unchanged* behavior when no
    /// policy is configured.
    active: AtomicBool,
    inner: Mutex<Option<SloRuntime>>,
}

impl Default for SloCell {
    fn default() -> Self {
        Self { active: AtomicBool::new(false), inner: Mutex::new(None) }
    }
}

/// An installed policy plus its live decision state. The state machine
/// is pure compute over µs stamps ([`LadderState`]); the router owns
/// the wall clock via `epoch` so `coordinator/slo.rs` stays
/// Miri-interpretable.
struct SloRuntime {
    policy: SloPolicy,
    state: LadderState,
    epoch: Instant,
}

/// All variants serving one named model.
struct ModelShards {
    image_len: usize,
    classes: usize,
    /// Bytes of the weight store shared by every variant and shard (0
    /// for executor-backed entries where the router can't see
    /// parameters). Counted ONCE — the allocations are shared.
    param_bytes: usize,
    /// Registration order; index 0 is the default variant.
    variants: Vec<VariantShards>,
    /// Degradation-ladder state (inert unless a policy is installed).
    slo: SloCell,
    /// Latest policy auto-search launched against this model (`None`
    /// until the first `POST /v1/models/{name}/autosearch`). The cell
    /// keeps the last run's progress/outcome visible on `/v1/metrics`
    /// and serializes runs: a new search is rejected while one is live.
    autosearch: Mutex<Option<Arc<SearchProgress>>>,
}

impl ModelShards {
    fn variant(&self, name: &str) -> Option<&VariantShards> {
        self.variants.iter().find(|v| v.name == name)
    }

    fn default_variant(&self) -> &VariantShards {
        &self.variants[0]
    }

    /// The dispatch seam every non-pinned, non-variant-addressed
    /// request flows through. With no SLO policy installed this *is*
    /// the old `default_variant()` lookup; with one installed, each
    /// call samples the serving rung's live pressure, advances the
    /// ladder state machine one decision, and returns the rung's
    /// variant. Pinned (`infer_on`) and explicitly-addressed
    /// (`infer_variant`) traffic bypasses the ladder by design.
    fn serving(&self) -> &VariantShards {
        if !self.slo.active.load(Relaxed) {
            return self.default_variant();
        }
        let rung_name = {
            let mut guard = super::lock_recover(&self.slo.inner);
            match guard.as_mut() {
                None => return self.default_variant(),
                Some(rt) => {
                    let now_us = rt.epoch.elapsed().as_micros() as u64;
                    let ladder = rt.policy.ladder();
                    let current = &ladder[rt.state.rung().min(ladder.len() - 1)];
                    let sample = self.pressure_of(current);
                    let rung = rt.state.step(&rt.policy, now_us, sample);
                    rt.policy.ladder()[rung].clone()
                }
            }
        };
        // Install-time validation pinned every rung to a registered
        // variant; the fallback is pure defensiveness.
        self.variant(&rung_name).unwrap_or_else(|| self.default_variant())
    }

    /// Live pressure on one variant: `queue_depth` summed across its
    /// shards plus the p99 of the merged sliding-window latency view
    /// (the cumulative per-shard histograms are too stale for control).
    fn pressure_of(&self, variant: &str) -> PressureSample {
        let Some(vs) = self.variant(variant) else {
            return PressureSample::default();
        };
        let mut queue_depth = 0u64;
        let mut recent = LatencyHist::default();
        for s in &vs.shards {
            queue_depth += s.stats.queue_depth.load(Relaxed);
            recent.merge(&s.batcher.recent_hist());
        }
        PressureSample { queue_depth, p99_us: recent.quantile_us(0.99) }
    }
}

/// Per-shard metrics view.
#[derive(Clone, Debug, Default)]
pub struct ShardMetrics {
    pub shard: usize,
    /// Successful requests completed through this shard.
    pub completed: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    /// Full bucketed e2e latency histogram (the quantiles above are
    /// derived from it); serialized over `GET /v1/metrics` so external
    /// collectors see distribution shape, not just two points.
    pub hist: LatencyHist,
    pub batcher: BatcherSnapshot,
}

/// Per-variant metrics: one quantization operating point of a model.
#[derive(Clone, Debug, Default)]
pub struct VariantMetrics {
    pub variant: String,
    pub replicas: usize,
    /// Resolved policy display (`"A4W8+R[first=A8W8]"`); empty for
    /// executor-backed variants the router cannot introspect.
    pub policy: String,
    /// Policy-weighted storage bits per quantized activation (0 when
    /// not introspectable).
    pub footprint_bits: f64,
    /// Serving generation number (0 for executor-backed variants the
    /// registry doesn't version).
    pub generation: u64,
    /// Content hash of the serving weight store (empty when not
    /// introspectable).
    pub weights_sha: String,
    /// Lifecycle label: `serving` / `canary` / `draining` (empty for
    /// executor-backed variants).
    pub state: String,
    /// How the serving version's parameters were chosen (`None` for
    /// build-time parameters, untagged reloads and executor-backed
    /// variants) — lets dashboards mark search-generated operating
    /// points.
    pub provenance: Option<VersionProvenance>,
    /// Full rollout snapshot: canary progress, per-generation served
    /// counters, draining/drained versions, last outcome/error.
    pub rollout: Option<RolloutStatus>,
    /// p99 of the variant's sliding-window latency view, merged across
    /// its shards — the *recent* pressure signal the SLO ladder reads
    /// (0 when the window holds no samples), as opposed to the
    /// since-boot quantiles in `shards[].hist`.
    pub recent_p99_us: u64,
    pub shards: Vec<ShardMetrics>,
    pub total: BatcherSnapshot,
}

/// Per-model metrics: every variant and shard plus merged aggregates.
/// `shards` flattens all variants' shards (registration order, shard
/// indices continuing across variants) so single-variant callers see
/// the pre-variant shape unchanged.
#[derive(Clone, Debug, Default)]
pub struct ModelMetrics {
    pub model: String,
    /// Total replica shards across every variant.
    pub replicas: usize,
    /// Parameter bytes held once and shared by all variants+replicas.
    pub param_bytes: usize,
    /// Degradation-ladder position: current rung, serving variant,
    /// time-in-degraded-mode, transition counters. `None` when no SLO
    /// policy is installed.
    pub slo: Option<SloStatus>,
    pub variants: Vec<VariantMetrics>,
    pub shards: Vec<ShardMetrics>,
    pub total: BatcherSnapshot,
}

enum EntrySource {
    /// Native-engine replicas over one shared parameter block.
    Params { params: Arc<ModelParams>, threads: Option<usize> },
    /// Caller-supplied executors, one per replica (PJRT executables,
    /// test doubles). `executors.len()` is the replica count.
    Executors { image_len: usize, classes: usize, executors: Vec<Box<ExecuteFn>> },
}

struct Entry {
    name: String,
    variant: String,
    replicas: usize,
    policy: BatchPolicy,
    source: EntrySource,
}

/// Name [`RouterBuilder::model`] registers its (single) variant under.
pub const DEFAULT_VARIANT: &str = "default";

/// Builder for [`InferenceRouter`]. Add models (and optionally further
/// policy variants of them), then [`RouterBuilder::build`].
#[derive(Default)]
pub struct RouterBuilder {
    entries: Vec<Entry>,
}

impl RouterBuilder {
    /// Serve `replicas` native-engine shards of one model, all sharing
    /// `params`, as the variant named [`DEFAULT_VARIANT`]. Each replica
    /// uses the engine's default thread count.
    pub fn model(
        self,
        name: &str,
        params: Arc<ModelParams>,
        replicas: usize,
        policy: BatchPolicy,
    ) -> Self {
        self.model_entry(name, DEFAULT_VARIANT, params, replicas, policy, None)
    }

    /// Like [`RouterBuilder::model`] but pins every replica engine to
    /// `threads` workers — use `1` when the replicas themselves are the
    /// parallelism (one core per shard) to avoid oversubscription.
    pub fn model_with_threads(
        self,
        name: &str,
        params: Arc<ModelParams>,
        replicas: usize,
        policy: BatchPolicy,
        threads: usize,
    ) -> Self {
        self.model_entry(name, DEFAULT_VARIANT, params, replicas, policy, Some(threads))
    }

    /// Register one **policy variant** of a model (e.g.
    /// `"resnet18"`/`"first8"`): its own `Arc<ModelParams>` — and thus
    /// its own per-layer LUT/weight tables — over the *same*
    /// `Arc<Graph>`/`Arc<Weights>` as the model's other variants
    /// (validated at build). The first variant registered for a model
    /// is its default.
    pub fn model_variant(
        self,
        name: &str,
        variant: &str,
        params: Arc<ModelParams>,
        replicas: usize,
        policy: BatchPolicy,
    ) -> Self {
        self.model_entry(name, variant, params, replicas, policy, None)
    }

    /// [`RouterBuilder::model_variant`] with the replica engines pinned
    /// to `threads` workers.
    pub fn model_variant_with_threads(
        self,
        name: &str,
        variant: &str,
        params: Arc<ModelParams>,
        replicas: usize,
        policy: BatchPolicy,
        threads: usize,
    ) -> Self {
        self.model_entry(name, variant, params, replicas, policy, Some(threads))
    }

    #[allow(clippy::too_many_arguments)]
    fn model_entry(
        mut self,
        name: &str,
        variant: &str,
        params: Arc<ModelParams>,
        replicas: usize,
        policy: BatchPolicy,
        threads: Option<usize>,
    ) -> Self {
        self.entries.push(Entry {
            name: name.to_string(),
            variant: variant.to_string(),
            replicas,
            policy,
            source: EntrySource::Params { params, threads },
        });
        self
    }

    /// Serve a model through caller-supplied batch executors, one per
    /// replica — the escape hatch for PJRT-backed shards and for tests
    /// that need a deliberately failing replica. Registers the
    /// [`DEFAULT_VARIANT`].
    pub fn model_from_executors(
        self,
        name: &str,
        image_len: usize,
        classes: usize,
        executors: Vec<Box<ExecuteFn>>,
        policy: BatchPolicy,
    ) -> Self {
        self.model_variant_from_executors(
            name,
            DEFAULT_VARIANT,
            image_len,
            classes,
            executors,
            policy,
        )
    }

    /// Executor-backed **variant** registration: a named operating
    /// point served by caller-supplied executors, composable with the
    /// model's other variants. This is how tests and the degrade-smoke
    /// rig build a multi-variant model whose rungs have controlled
    /// speed (a deliberately parked "full" variant over an instant
    /// cheap one) without engine parameters.
    pub fn model_variant_from_executors(
        mut self,
        name: &str,
        variant: &str,
        image_len: usize,
        classes: usize,
        executors: Vec<Box<ExecuteFn>>,
        policy: BatchPolicy,
    ) -> Self {
        let replicas = executors.len();
        self.entries.push(Entry {
            name: name.to_string(),
            variant: variant.to_string(),
            replicas,
            policy,
            source: EntrySource::Executors { image_len, classes, executors },
        });
        self
    }

    /// Spawn every shard worker and produce the router.
    pub fn build(self) -> Result<InferenceRouter> {
        let mut models: HashMap<String, ModelShards> = HashMap::new();
        for entry in self.entries {
            // '@' is the HTTP front door's model/variant separator
            // (`POST /v1/infer/{model}@{variant}`): a model name
            // containing it would build fine yet be permanently
            // unreachable over the network — reject at startup.
            if entry.name.is_empty() || entry.name.contains('@') {
                bail!(
                    "model name `{}` is invalid: must be non-empty and must not contain \
                     '@' (reserved for HTTP variant addressing)",
                    entry.name
                );
            }
            if entry.variant.is_empty() {
                bail!("model `{}`: variant name must be non-empty", entry.name);
            }
            if entry.replicas == 0 {
                bail!("model `{}`: replica count must be >= 1", entry.name);
            }
            // Validate the policy here so a bad config is a build error,
            // not a panic inside Batcher::spawn's asserts.
            if entry.policy.max_batch == 0 {
                bail!("model `{}`: policy.max_batch must be >= 1", entry.name);
            }
            if entry.policy.max_queue_depth == 0 {
                bail!("model `{}`: policy.max_queue_depth must be >= 1", entry.name);
            }
            if let Some(limit) = entry.policy.max_queue_wait {
                if limit <= entry.policy.max_wait {
                    bail!(
                        "model `{}`: policy.max_queue_wait ({:?}) must exceed max_wait ({:?}) \
                         — queue age includes the batch-fill window, so a smaller deadline \
                         would shed every request",
                        entry.name,
                        limit,
                        entry.policy.max_wait
                    );
                }
            }
            type Versioned = Option<(Arc<VersionSlot>, Arc<VersionTracker>)>;
            let (image_len, classes, versioned, executors): (
                usize,
                usize,
                Versioned,
                Vec<Box<ExecuteFn>>,
            ) = match entry.source {
                EntrySource::Params { params, threads } => {
                    let [h, w, c] = params.graph.input_hwc;
                    let image_len = h * w * c;
                    let classes = params.graph.num_classes;
                    // The variant's versioned slot: replicas re-read it
                    // per batch (a cheap Arc clone + handle rebuild), so
                    // a hot-swap takes effect on each replica's very
                    // next batch while in-flight batches drain on the
                    // old Arc.
                    let slot = Arc::new(VersionSlot::new(params));
                    let tracker = Arc::new(VersionTracker::new());
                    let executors = (0..entry.replicas)
                        .map(|_| {
                            let slot = Arc::clone(&slot);
                            let tracker = Arc::clone(&tracker);
                            // Shard-private scratch; the second one runs
                            // the shadow side of canary batches.
                            let mut scratch = Scratch::default();
                            let mut shadow = Scratch::default();
                            Box::new(move |buf: &[f32], bsz: usize| {
                                versioned_execute(
                                    &slot,
                                    &tracker,
                                    threads,
                                    classes,
                                    buf,
                                    bsz,
                                    &mut scratch,
                                    &mut shadow,
                                )
                            }) as Box<ExecuteFn>
                        })
                        .collect();
                    (image_len, classes, Some((slot, tracker)), executors)
                }
                EntrySource::Executors { image_len, classes, executors } => {
                    (image_len, classes, None, executors)
                }
            };
            let shards = executors
                .into_iter()
                .map(|exec| {
                    let stats = Arc::new(BatcherStats::default());
                    let batcher =
                        Batcher::spawn(entry.policy, image_len, classes, exec, stats.clone());
                    Shard { batcher, stats, e2e: Mutex::new(LatencyHist::default()) }
                })
                .collect();
            let vs = match versioned {
                Some((slot, tracker)) => VariantShards {
                    name: entry.variant.clone(),
                    shards,
                    cursor: AtomicUsize::new(0),
                    slot: Some(slot),
                    tracker: Some(tracker),
                },
                None => VariantShards {
                    name: entry.variant.clone(),
                    shards,
                    cursor: AtomicUsize::new(0),
                    slot: None,
                    tracker: None,
                },
            };
            match models.get_mut(&entry.name) {
                Some(ms) => {
                    if ms.variant(&vs.name).is_some() {
                        bail!(
                            "duplicate registration of model `{}` variant `{}` in router",
                            entry.name,
                            vs.name
                        );
                    }
                    if ms.image_len != image_len || ms.classes != classes {
                        bail!(
                            "model `{}` variant `{}`: shape ({image_len}, {classes}) differs \
                             from the model's ({}, {})",
                            entry.name,
                            vs.name,
                            ms.image_len,
                            ms.classes
                        );
                    }
                    // Variants exist to serve many operating points off
                    // ONE weight copy; silently accepting a second
                    // allocation would defeat the design, so reject it.
                    // (Build-time only: a later weight hot-swap
                    // necessarily gives the reloaded variant its own
                    // allocation.)
                    if let (Some(prev), Some(newp)) = (
                        ms.variants.iter().find_map(VariantShards::current_params),
                        vs.current_params(),
                    ) {
                        if !Arc::ptr_eq(&prev.graph, &newp.graph)
                            || !Arc::ptr_eq(&prev.weights, &newp.weights)
                        {
                            bail!(
                                "model `{}` variant `{}`: variants must share one \
                                 graph+weights allocation — build each variant's \
                                 ModelParams over the same Arc<Graph>/Arc<Weights>",
                                entry.name,
                                vs.name
                            );
                        }
                    }
                    if ms.param_bytes == 0 {
                        ms.param_bytes =
                            vs.current_params().map_or(0, |p| p.weights.param_bytes());
                    }
                    ms.variants.push(vs);
                }
                None => {
                    let param_bytes =
                        vs.current_params().map_or(0, |p| p.weights.param_bytes());
                    models.insert(
                        entry.name.clone(),
                        ModelShards {
                            image_len,
                            classes,
                            param_bytes,
                            variants: vec![vs],
                            slo: SloCell::default(),
                            autosearch: Mutex::new(None),
                        },
                    );
                }
            }
        }
        if models.is_empty() {
            bail!("router has no models; add at least one before build()");
        }
        Ok(InferenceRouter { models })
    }
}

/// One batch through a versioned variant. The slot is read once and
/// the whole batch runs on that version's engine (a cheap
/// `Engine::from_params` Arc bump per batch — no caching, so a stale
/// engine can never outlive a swap), which is what guarantees no
/// response is ever torn across generations. Canary batches run on the
/// incoming generation with the serving generation shadow-computing the
/// same rows for the agreement measure; if the candidate's executor
/// fails, the canary auto-rolls-back and the serving generation's
/// (already computed) logits answer the batch — callers never see the
/// candidate's failure.
#[allow(clippy::too_many_arguments)]
fn versioned_execute(
    slot: &VersionSlot,
    tracker: &VersionTracker,
    threads: Option<usize>,
    classes: usize,
    buf: &[f32],
    bsz: usize,
    scratch: &mut Scratch,
    shadow: &mut Scratch,
) -> Result<Vec<f32>> {
    let engine_for = |v: &Arc<ModelVersion>| {
        let mut e = Engine::from_params(Arc::clone(&v.params));
        if let Some(t) = threads {
            e.set_threads(t);
        }
        e
    };
    match tracker.dispatch(slot) {
        Dispatch::Serving(v) => {
            let out = engine_for(&v).forward_scratch(buf, bsz, scratch)?;
            tracker.note_served(v.generation, bsz as u64);
            Ok(out)
        }
        Dispatch::Canary { incoming, serving } => {
            let reference = engine_for(&serving).forward_scratch(buf, bsz, scratch)?;
            match engine_for(&incoming).forward_scratch(buf, bsz, shadow) {
                Ok(out) => {
                    let agree = registry::top1_agreement(&out, &reference, classes);
                    tracker.note_served(incoming.generation, bsz as u64);
                    tracker.record_canary(slot, incoming.generation, agree, bsz as u64);
                    Ok(out)
                }
                Err(e) => {
                    tracker.fail_canary(incoming.generation, &format!("{e:#}"));
                    tracker.note_served(serving.generation, bsz as u64);
                    Ok(reference)
                }
            }
        }
    }
}

/// Where a staged reload's parameters come from.
pub enum ReloadSource {
    /// Fully staged parameters (shape-validated against the live
    /// version before publication).
    Params(Arc<ModelParams>),
    /// Re-prepare the live weights under a new [`QuantPolicy`] — a
    /// quantization operating-point change with zero new weight bytes.
    Policy(QuantPolicy),
    /// Load a fresh weight store from a `_weights.npz` file.
    WeightsNpz(PathBuf),
    /// Deterministically perturb the live weights (rollout drill: no
    /// artifact needed). Small amplitudes stay top-1-compatible with
    /// the serving version, so a canary promotes; large amplitudes
    /// corrupt predictions, so a canary rolls back.
    Perturb { seed: u64, amplitude: i8 },
}

/// A reload request: the parameter source plus the rollout gate.
pub struct ReloadSpec {
    pub source: ReloadSource,
    pub rollout: RolloutConfig,
    /// Optional provenance tag carried onto the incoming
    /// [`ModelVersion`] — the auto-search install path stamps
    /// `origin: "search"` plus its measured agreement and report hash
    /// here so `/v1/models` can tell searched variants from
    /// hand-written ones.
    pub provenance: Option<VersionProvenance>,
}

fn splitmix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Clone the live weight store and nudge ~1/8 of each quantized conv's
/// weights by up to ±`amplitude`, deterministically from `seed`. The
/// conv names are visited in sorted order so the result is independent
/// of `HashMap` iteration order.
fn perturb_weights(live: &Weights, seed: u64, amplitude: i8) -> Weights {
    let mut out = live.clone();
    let mut names: Vec<String> = out.quant.keys().cloned().collect();
    names.sort();
    let span = 2 * u64::from(amplitude.unsigned_abs()) + 1;
    let mut ctr = seed;
    for name in &names {
        if let Some(q) = out.quant.get_mut(name) {
            for w in &mut q.wq {
                ctr = ctr.wrapping_add(1);
                let r = splitmix(ctr);
                if r % 8 == 0 {
                    let delta = ((r >> 8) % span) as i64 - i64::from(amplitude.unsigned_abs());
                    // delta ∈ [-amplitude, amplitude] fits i8 by
                    // construction; saturate at the type bounds.
                    let nudged = i64::from(*w) + delta;
                    *w = nudged.clamp(i64::from(i8::MIN), i64::from(i8::MAX)) as i8;
                }
            }
        }
    }
    out
}

/// Routes inference requests across named models and their replica
/// shards. See the module docs for the architecture.
pub struct InferenceRouter {
    models: HashMap<String, ModelShards>,
}

impl InferenceRouter {
    pub fn builder() -> RouterBuilder {
        RouterBuilder::default()
    }

    /// Registered model names, sorted.
    pub fn model_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.models.keys().map(String::as_str).collect();
        names.sort_unstable();
        names
    }

    /// Total replica shards across every variant of the model.
    pub fn replicas(&self, model: &str) -> Result<usize> {
        Ok(self.shards_of(model)?.variants.iter().map(|v| v.shards.len()).sum())
    }

    /// (image_len, classes) the named model expects/produces.
    pub fn shape(&self, model: &str) -> Result<(usize, usize)> {
        let ms = self.shards_of(model)?;
        Ok((ms.image_len, ms.classes))
    }

    /// The model's variant names, registration order (index 0 is the
    /// default).
    pub fn variant_names(&self, model: &str) -> Result<Vec<&str>> {
        Ok(self.shards_of(model)?.variants.iter().map(|v| v.name.as_str()).collect())
    }

    /// `(variant name, replica count)` pairs, registration order — the
    /// cheap introspection view: unlike [`InferenceRouter::metrics`] it
    /// touches no stats snapshots and no latency-histogram locks.
    pub fn variant_replicas(&self, model: &str) -> Result<Vec<(&str, usize)>> {
        Ok(self
            .shards_of(model)?
            .variants
            .iter()
            .map(|v| (v.name.as_str(), v.shards.len()))
            .collect())
    }

    /// Bytes of the weight store shared by every variant and replica of
    /// the model (0 for executor-backed entries).
    pub fn param_bytes(&self, model: &str) -> Result<usize> {
        Ok(self.shards_of(model)?.param_bytes)
    }

    /// The variant [`InferenceRouter::infer`] dispatches to.
    pub fn default_variant(&self, model: &str) -> Result<&str> {
        Ok(self.shards_of(model)?.default_variant().name.as_str())
    }

    /// The variant a plain [`InferenceRouter::infer`]/`submit` would
    /// serve **right now**: the default variant, unless a degradation
    /// ladder is installed — in which case this samples pressure and
    /// advances the ladder exactly like a dispatch would (the HTTP
    /// front door resolves each unaddressed request through this, then
    /// pins the returned variant so the response can echo what actually
    /// served it).
    pub fn serving_variant(&self, model: &str) -> Result<&str> {
        Ok(self.shards_of(model)?.serving().name.as_str())
    }

    /// Install (`Some`) or clear (`None`) the model's SLO degradation
    /// ladder — the programmatic face of `POST /v1/models/{name}/slo`.
    ///
    /// Install-time validation on top of [`SloPolicy`]'s own: every
    /// rung must be a registered variant of the model, rung 0 must be
    /// its default variant, and `footprint_bits` must not increase
    /// along the ladder (cheaper operating points only — checked across
    /// params-built rungs; executor-backed rungs have no introspectable
    /// footprint and are skipped). Installing resets the ladder to rung
    /// 0 with fresh transition counters; the first breach after install
    /// is exempt from dwell, so a policy installed mid-overload acts
    /// immediately.
    pub fn set_slo_policy(&self, model: &str, policy: Option<SloPolicy>) -> Result<()> {
        let ms = self.shards_of(model)?;
        let Some(policy) = policy else {
            ms.slo.active.store(false, Relaxed);
            *super::lock_recover(&ms.slo.inner) = None;
            return Ok(());
        };
        for rung in policy.ladder() {
            if ms.variant(rung).is_none() {
                bail!(
                    "SLO ladder rung `{rung}` is not a variant of model `{model}` \
                     (available: {:?})",
                    ms.variants.iter().map(|v| v.name.as_str()).collect::<Vec<_>>()
                );
            }
        }
        let default = ms.default_variant().name.as_str();
        if policy.ladder()[0] != default {
            bail!(
                "SLO ladder rung 0 must be the model's default variant `{default}`, \
                 got `{}`",
                policy.ladder()[0]
            );
        }
        // Ladder ordering: descending the ladder must never *increase*
        // the activation footprint — degrading to a more expensive
        // operating point would amplify the overload it reacts to.
        let mut prev: Option<(&str, f64)> = None;
        for rung in policy.ladder() {
            let bits = ms
                .variant(rung)
                .and_then(VariantShards::current_params)
                .map(|p| p.footprint_bits(1));
            if let Some(bits) = bits {
                if let Some((prev_rung, prev_bits)) = prev {
                    if bits > prev_bits + 1e-9 {
                        bail!(
                            "SLO ladder must be ordered by non-increasing footprint_bits: \
                             rung `{rung}` ({bits:.3} bits) follows `{prev_rung}` \
                             ({prev_bits:.3} bits)"
                        );
                    }
                }
                prev = Some((rung.as_str(), bits));
            }
        }
        *super::lock_recover(&ms.slo.inner) =
            Some(SloRuntime { policy, state: LadderState::new(), epoch: Instant::now() });
        ms.slo.active.store(true, Relaxed);
        Ok(())
    }

    /// Snapshot of the model's ladder position (`None` when no SLO
    /// policy is installed). Advances the degraded-time clock to now
    /// without making a routing decision.
    pub fn slo_status(&self, model: &str) -> Result<Option<SloStatus>> {
        Ok(Self::slo_snapshot(self.shards_of(model)?))
    }

    fn slo_snapshot(ms: &ModelShards) -> Option<SloStatus> {
        if !ms.slo.active.load(Relaxed) {
            return None;
        }
        let mut guard = super::lock_recover(&ms.slo.inner);
        guard.as_mut().map(|rt| {
            rt.state.touch(rt.epoch.elapsed().as_micros() as u64);
            let ladder = rt.policy.ladder();
            let rung = rt.state.rung().min(ladder.len() - 1);
            SloStatus {
                ladder: ladder.to_vec(),
                rung,
                serving: ladder[rung].clone(),
                degraded: rt.state.degraded(),
                time_degraded_us: rt.state.time_degraded_us(),
                transitions_down: rt.state.steps_down(),
                transitions_up: rt.state.steps_up(),
            }
        })
    }

    /// The **currently serving** parameter block behind a variant —
    /// `None` for executor-backed entries the router cannot introspect.
    /// This is the seam the HTTP `GET /v1/models` policy report reads
    /// through; it returns an owned `Arc` clone because the underlying
    /// slot can be hot-swapped at any moment.
    pub fn variant_params(
        &self,
        model: &str,
        variant: &str,
    ) -> Result<Option<Arc<ModelParams>>> {
        Ok(self.variant_of(model, variant)?.current_params())
    }

    /// The currently serving [`ModelVersion`] (generation number,
    /// weights hash, params) of a variant — `None` for executor-backed
    /// entries.
    pub fn variant_version(
        &self,
        model: &str,
        variant: &str,
    ) -> Result<Option<Arc<ModelVersion>>> {
        Ok(self.variant_of(model, variant)?.slot.as_ref().map(|s| s.load()))
    }

    /// The variant's rollout snapshot (canary progress, per-generation
    /// served counters, draining versions) — `None` for executor-backed
    /// entries.
    pub fn variant_rollout(&self, model: &str, variant: &str) -> Result<Option<RolloutStatus>> {
        Ok(self.variant_of(model, variant)?.tracker.as_ref().map(|t| t.status()))
    }

    /// Claim the model's auto-search cell for a new run — the
    /// programmatic face of `POST /v1/models/{name}/autosearch`. At
    /// most one search per model may be live: a second claim while the
    /// previous run is still in a non-terminal phase is rejected. The
    /// returned handle is shared with the search thread (which drives
    /// it through [`SearchPhase`](crate::search::SearchPhase)s) and
    /// with `/v1/metrics` (which snapshots it).
    pub fn begin_autosearch(&self, model: &str) -> Result<Arc<SearchProgress>> {
        let ms = self.shards_of(model)?;
        let mut cell = super::lock_recover(&ms.autosearch);
        if let Some(prev) = cell.as_ref() {
            // `Idle` means claimed-but-not-started (the HTTP route
            // claims before spawning the search thread) — both block a
            // second claim. A spawn failure marks the cell `Failed`,
            // so a wedged claim cannot outlive its request.
            if prev.running() || prev.phase() == SearchPhase::Idle {
                bail!(
                    "auto-search already in progress for model `{model}` \
                     (phase {})",
                    prev.phase().as_str()
                );
            }
        }
        let progress = Arc::new(SearchProgress::new());
        *cell = Some(Arc::clone(&progress));
        Ok(progress)
    }

    /// Snapshot of the model's latest auto-search — phase, eval
    /// progress and (once terminal) the outcome — or `None` if no
    /// search was ever launched. Surfaces on `/v1/metrics`.
    pub fn autosearch_progress(&self, model: &str) -> Result<Option<JsonValue>> {
        let ms = self.shards_of(model)?;
        Ok(super::lock_recover(&ms.autosearch).as_ref().map(|p| p.snapshot()))
    }

    /// Stage and roll out new parameters for one variant — the
    /// programmatic face of `POST /v1/models/{name}/reload`.
    ///
    /// Staging (loading/perturbing weights, re-preparing LUT and weight
    /// tables) happens on the calling thread, **off** the serving path:
    /// traffic keeps flowing on the live generation throughout. The
    /// staged block is shape-validated against the live graph, then
    /// either swapped in immediately (`canary_share == 0`) or installed
    /// as a canary that auto-promotes/auto-rolls-back on measured
    /// agreement. Returns the incoming generation number.
    ///
    /// Fails for executor-backed variants, on shape mismatch, or while
    /// another rollout of the same variant is still in flight; staging
    /// failures are also recorded on the variant for `/v1/models`.
    pub fn reload_variant(&self, model: &str, variant: &str, spec: ReloadSpec) -> Result<u64> {
        let vs = self.variant_of(model, variant)?;
        let (slot, tracker) = match (&vs.slot, &vs.tracker) {
            (Some(s), Some(t)) => (s, t),
            _ => bail!(
                "model `{model}` variant `{variant}` is executor-backed; hot reload \
                 requires a params-built variant"
            ),
        };
        let live = slot.load();
        let staged = match Self::stage(&live, spec.source) {
            Ok(p) => p,
            Err(e) => {
                tracker.set_error(format!("staging failed: {e:#}"));
                return Err(e.context(format!(
                    "staging reload for model `{model}` variant `{variant}`"
                )));
            }
        };
        match tracker.begin_rollout_tagged(slot, staged, spec.rollout, spec.provenance) {
            Ok(generation) => Ok(generation),
            Err(e) => {
                // Recorded on the variant so async callers (the HTTP
                // reload route stages off-thread) can see why a reload
                // never became a canary.
                tracker.set_error(format!("rollout rejected: {e:#}"));
                Err(e)
            }
        }
    }

    /// Build the staged parameter block for a reload (expensive: table
    /// preparation), without touching any serving state.
    fn stage(live: &ModelVersion, source: ReloadSource) -> Result<Arc<ModelParams>> {
        match source {
            ReloadSource::Params(p) => Ok(p),
            ReloadSource::Policy(policy) => {
                Ok(Arc::new(live.params.restage_policy(policy).context("restaging policy")?))
            }
            ReloadSource::WeightsNpz(path) => {
                let w = Weights::load(&path)?;
                Ok(Arc::new(live.params.restage_weights(Arc::new(w))?))
            }
            ReloadSource::Perturb { seed, amplitude } => {
                if amplitude == 0 {
                    bail!("perturb amplitude must be non-zero (a zero-delta reload is a no-op)");
                }
                let w = perturb_weights(&live.params.weights, seed, amplitude);
                Ok(Arc::new(live.params.restage_weights(Arc::new(w))?))
            }
        }
    }

    fn shards_of(&self, model: &str) -> Result<&ModelShards> {
        self.models.get(model).with_context(|| {
            format!("router has no model named `{model}` (available: {:?})", self.model_names())
        })
    }

    fn variant_of(&self, model: &str, variant: &str) -> Result<&VariantShards> {
        let ms = self.shards_of(model)?;
        ms.variant(variant).with_context(|| {
            format!(
                "model `{model}` has no variant `{variant}` (available: {:?})",
                ms.variants.iter().map(|v| v.name.as_str()).collect::<Vec<_>>()
            )
        })
    }

    /// Dispatch by model name, load-aware across the serving variant's
    /// shards (shallowest live queue wins; ties rotate round-robin).
    /// The serving variant is the default — unless an SLO policy
    /// ([`InferenceRouter::set_slo_policy`]) has degraded the model to
    /// a cheaper ladder rung under pressure. Blocks until the reply;
    /// executor failures and overload errors carry the shard's real
    /// message.
    pub fn infer(&self, model: &str, image: Vec<f32>) -> Result<Reply> {
        let vs = self.shards_of(model)?.serving();
        Self::shard_infer(&vs.shards[vs.pick()], image)
    }

    /// Dispatch to a named **policy variant** of a model — same
    /// load-aware pick within that variant's shards.
    pub fn infer_variant(&self, model: &str, variant: &str, image: Vec<f32>) -> Result<Reply> {
        let vs = self.variant_of(model, variant)?;
        Self::shard_infer(&vs.shards[vs.pick()], image)
    }

    /// Non-blocking dispatch for event-driven front ends (the HTTP
    /// layer): the same load-aware shard pick as
    /// [`InferenceRouter::infer`], but the caller gets a
    /// [`PendingReply`] to poll via
    /// [`try_wait`](PendingReply::try_wait) instead of parking a
    /// thread. The per-shard latency histograms only track the blocking
    /// path; submit traffic still lands in every batcher counter.
    pub fn submit(&self, model: &str, image: Vec<f32>) -> Result<PendingReply> {
        let vs = self.shards_of(model)?.serving();
        vs.shards[vs.pick()].batcher.submit(image)
    }

    /// Non-blocking dispatch to a named variant.
    pub fn submit_variant(
        &self,
        model: &str,
        variant: &str,
        image: Vec<f32>,
    ) -> Result<PendingReply> {
        let vs = self.variant_of(model, variant)?;
        vs.shards[vs.pick()].batcher.submit(image)
    }

    /// Dispatch to one specific shard (session affinity, deterministic
    /// tests). `shard` is the model-wide **flattened** index exactly as
    /// reported by [`InferenceRouter::metrics`]: variants in
    /// registration order, shard indices continuing across variants —
    /// so a pinning caller can drive this directly from the metrics
    /// view. Single-variant models behave as before.
    pub fn infer_on(&self, model: &str, shard: usize, image: Vec<f32>) -> Result<Reply> {
        let ms = self.shards_of(model)?;
        let mut idx = shard;
        for vs in &ms.variants {
            if idx < vs.shards.len() {
                return Self::shard_infer(&vs.shards[idx], image);
            }
            idx -= vs.shards.len();
        }
        let total: usize = ms.variants.iter().map(|v| v.shards.len()).sum();
        bail!("model `{model}` has {total} shard(s) across its variants; no shard {shard}")
    }

    fn shard_infer(shard: &Shard, image: Vec<f32>) -> Result<Reply> {
        let t0 = Instant::now();
        let reply = shard.batcher.infer(image)?;
        // Successful requests only: overload rejections return in
        // microseconds and would drag the latency histogram down.
        super::lock_recover(&shard.e2e).record(t0.elapsed());
        Ok(reply)
    }

    /// Per-variant, per-shard and aggregate metrics for one model.
    pub fn metrics(&self, model: &str) -> Result<ModelMetrics> {
        let ms = self.shards_of(model)?;
        let mut variants = Vec::with_capacity(ms.variants.len());
        let mut flat = Vec::new();
        let mut total = BatcherSnapshot::default();
        let mut shard_idx = 0usize;
        for vs in &ms.variants {
            let mut vshards = Vec::with_capacity(vs.shards.len());
            let mut vtotal = BatcherSnapshot::default();
            for s in &vs.shards {
                let snap = s.stats.snapshot();
                vtotal.merge(&snap);
                total.merge(&snap);
                let e2e = super::lock_recover(&s.e2e);
                let sm = ShardMetrics {
                    shard: shard_idx,
                    completed: e2e.count(),
                    mean_latency_us: e2e.mean_us(),
                    p50_latency_us: e2e.quantile_us(0.50),
                    p99_latency_us: e2e.quantile_us(0.99),
                    hist: e2e.clone(),
                    batcher: snap,
                };
                shard_idx += 1;
                vshards.push(sm.clone());
                flat.push(sm);
            }
            let version = vs.slot.as_ref().map(|s| s.load());
            let rollout = vs.tracker.as_ref().map(|t| t.status());
            let mut recent = LatencyHist::default();
            for s in &vs.shards {
                recent.merge(&s.batcher.recent_hist());
            }
            variants.push(VariantMetrics {
                variant: vs.name.clone(),
                replicas: vs.shards.len(),
                policy: version
                    .as_ref()
                    .map_or_else(String::new, |v| v.params.policy().to_string()),
                footprint_bits: version.as_ref().map_or(0.0, |v| v.params.footprint_bits(1)),
                generation: version.as_ref().map_or(0, |v| v.generation),
                weights_sha: version
                    .as_ref()
                    .map_or_else(String::new, |v| v.weights_sha.clone()),
                state: rollout.as_ref().map_or_else(String::new, |r| r.state().to_string()),
                provenance: version.as_ref().and_then(|v| v.provenance.clone()),
                rollout,
                recent_p99_us: recent.quantile_us(0.99),
                shards: vshards,
                total: vtotal,
            });
        }
        Ok(ModelMetrics {
            model: model.to_string(),
            replicas: shard_idx,
            param_bytes: ms.param_bytes,
            slo: Self::slo_snapshot(ms),
            variants,
            shards: flat,
            total,
        })
    }

    /// Merged batcher snapshot across every model, variant and shard.
    pub fn aggregate(&self) -> BatcherSnapshot {
        let mut total = BatcherSnapshot::default();
        for ms in self.models.values() {
            for vs in &ms.variants {
                for s in &vs.shards {
                    total.merge(&s.stats.snapshot());
                }
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::OverloadPolicy;
    use crate::model::{EngineMode, Graph, Node, Op, Weights};
    use crate::model::weights::QuantConv;
    use crate::quant::SparqConfig;
    use std::collections::HashMap;
    use std::time::Duration;

    /// Tiny all-native model: one quantized conv, 4x4x1 -> 2 classes.
    fn tiny_graph_weights(seed: i8) -> (Arc<Graph>, Arc<Weights>) {
        let graph = Graph {
            arch: "tinyq".into(),
            variant: "router-test".into(),
            num_classes: 2,
            input_hwc: [4, 4, 1],
            eval_batch: 4,
            quant_convs: vec!["q1".into()],
            nodes: vec![
                Node { name: "img".into(), op: Op::Input, inputs: vec![] },
                Node {
                    name: "q1".into(),
                    op: Op::Conv { k: 3, stride: 1, out_ch: 2, relu: true, quant: true },
                    inputs: vec!["img".into()],
                },
                Node { name: "g".into(), op: Op::Gap, inputs: vec!["q1".into()] },
                Node { name: "fc".into(), op: Op::Fc { out: 2 }, inputs: vec!["g".into()] },
            ],
        };
        let mut quant = HashMap::new();
        quant.insert(
            "q1".to_string(),
            QuantConv {
                wq: (0..18)
                    .map(|i| ((((i * 37) % 255) as i32 - 127) as i8).wrapping_add(seed))
                    .collect(),
                k: 9,
                o: 2,
                scale: vec![0.015, 0.02],
                bias: vec![0.05, -0.05],
            },
        );
        let weights = Weights {
            quant,
            float: HashMap::new(),
            fc_w: vec![1.0, -0.5, 0.25, 1.0],
            fc_in: 2,
            fc_out: 2,
            fc_b: vec![0.1, 0.2],
        };
        (Arc::new(graph), Arc::new(weights))
    }

    fn tiny_params(seed: i8) -> Arc<ModelParams> {
        let (graph, weights) = tiny_graph_weights(seed);
        Arc::new(
            ModelParams::new(
                graph,
                weights,
                SparqConfig::named("5opt_r").unwrap(),
                &[0.02],
                EngineMode::Dense,
            )
            .unwrap(),
        )
    }

    fn img(i: usize) -> Vec<f32> {
        (0..16).map(|j| ((i * 16 + j) as f32) / 40.0).collect()
    }

    fn quick_policy(max_batch: usize) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(200),
            ..BatchPolicy::default()
        }
    }

    #[test]
    fn autosearch_cell_serializes_claims_and_snapshots_progress() {
        let router = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, quick_policy(2))
            .build()
            .unwrap();
        assert!(router.begin_autosearch("ghost").is_err());
        assert!(router.autosearch_progress("m").unwrap().is_none(), "no search launched yet");
        let p = router.begin_autosearch("m").unwrap();
        // claimed-but-idle and live phases both block a second claim
        assert!(router.begin_autosearch("m").is_err());
        p.set_phase(SearchPhase::Sweep);
        let err = router.begin_autosearch("m").unwrap_err().to_string();
        assert!(err.contains("phase sweep"), "{err}");
        p.finish(SearchPhase::Done, crate::json_obj! { "ok" => true });
        let snap = router.autosearch_progress("m").unwrap().unwrap();
        assert_eq!(snap.get("phase").and_then(JsonValue::as_str), Some("done"));
        // a terminal cell frees the claim for the next run
        assert!(router.begin_autosearch("m").is_ok());
    }

    #[test]
    fn replicas_share_one_parameter_copy() {
        let params = tiny_params(0);
        let before = Arc::strong_count(&params);
        let router = InferenceRouter::builder()
            .model("m", params.clone(), 3, quick_policy(2))
            .build()
            .unwrap();
        // One registry copy total: the variant's `VersionSlot` holds the
        // sole `Arc<ModelParams>` clone; replica executors capture the
        // slot and re-borrow per batch — shared storage, not 3 deep
        // clones (the acceptance criterion), and no per-replica holds
        // that could outlive a hot-swap.
        assert_eq!(Arc::strong_count(&params), before + 1);
        assert_eq!(router.replicas("m").unwrap(), 3);
        let m = router.metrics("m").unwrap();
        assert_eq!(m.param_bytes, params.weights.param_bytes());
        assert!(m.param_bytes > 0);
        // all replicas compute the same function as a direct engine
        let engine = Engine::from_params(params.clone());
        let want = engine.forward(&img(7), 1).unwrap();
        for shard in 0..3 {
            let got = router.infer_on("m", shard, img(7)).unwrap();
            assert_eq!(got.logits, want, "shard {shard} diverged from the shared model");
        }
        // Dropping the router closes every shard queue; the workers
        // (whose executors own the version slot) exit asynchronously, so
        // poll. `before + 1` = the test-local `engine` above.
        drop(router);
        let deadline = Instant::now() + Duration::from_secs(10);
        while Arc::strong_count(&params) != before + 1 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(
            Arc::strong_count(&params),
            before + 1,
            "replica engines were not released after router shutdown"
        );
    }

    /// The variant acceptance bar: >= 2 policy variants of one model
    /// share exactly one weights allocation (pointer equality +
    /// `Arc::strong_count`) while serving bit-different logits, and the
    /// router refuses variants over a second allocation.
    #[test]
    fn variants_share_one_weights_allocation_and_serve_distinct_logits() {
        use crate::quant::QuantPolicy;
        let (graph, weights) = tiny_graph_weights(0);
        let before = Arc::strong_count(&weights);
        let pa = Arc::new(
            ModelParams::with_policy(
                graph.clone(),
                weights.clone(),
                QuantPolicy::named("a8w8").unwrap(),
                &[0.02],
                EngineMode::Dense,
            )
            .unwrap(),
        );
        let pb = Arc::new(
            ModelParams::with_policy(
                graph.clone(),
                weights.clone(),
                QuantPolicy::named("a4w8").unwrap(),
                &[0.02],
                EngineMode::Dense,
            )
            .unwrap(),
        );
        // pointer equality: both variants hold the SAME allocations
        assert!(Arc::ptr_eq(&pa.weights, &pb.weights), "variants must share weights");
        assert!(Arc::ptr_eq(&pa.graph, &pb.graph), "variants must share the graph");
        assert_eq!(
            Arc::strong_count(&weights),
            before + 2,
            "each variant is an Arc bump, not a weight copy"
        );
        let router = InferenceRouter::builder()
            .model_variant("m", "a8w8", pa.clone(), 2, quick_policy(2))
            .model_variant("m", "a4w8", pb.clone(), 1, quick_policy(2))
            .build()
            .unwrap();
        // router construction cost zero additional weight allocations
        assert_eq!(Arc::strong_count(&weights), before + 2);
        assert_eq!(router.replicas("m").unwrap(), 3);
        assert_eq!(router.variant_names("m").unwrap(), vec!["a8w8", "a4w8"]);
        assert_eq!(router.default_variant("m").unwrap(), "a8w8");
        // default dispatch = first variant; named dispatch = that variant
        let want_a = Engine::from_params(pa.clone()).forward(&img(5), 1).unwrap();
        let want_b = Engine::from_params(pb.clone()).forward(&img(5), 1).unwrap();
        assert_ne!(want_a, want_b, "test policies degenerate: identical outputs");
        assert_eq!(router.infer("m", img(5)).unwrap().logits, want_a);
        assert_eq!(router.infer_variant("m", "a8w8", img(5)).unwrap().logits, want_a);
        assert_eq!(router.infer_variant("m", "a4w8", img(5)).unwrap().logits, want_b);
        // unknown variants are descriptive errors naming the real ones
        let err = router.infer_variant("m", "nope", img(0)).unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains("a4w8"), "{err}");
        // introspection: the params behind each variant are reachable
        assert!(Arc::ptr_eq(
            &router.variant_params("m", "a8w8").unwrap().unwrap(),
            &pa
        ));
        // registry metadata: both variants serve generation 1
        assert_eq!(router.variant_version("m", "a8w8").unwrap().unwrap().generation, 1);
        assert_eq!(router.variant_version("m", "a4w8").unwrap().unwrap().generation, 1);
        // metrics: per-variant blocks + the flattened per-model view
        let m = router.metrics("m").unwrap();
        assert_eq!(m.variants.len(), 2);
        assert_eq!((m.variants[0].replicas, m.variants[1].replicas), (2, 1));
        assert_eq!(m.variants[0].policy, "A8W8");
        assert_eq!(m.variants[1].policy, "A4W8+R");
        assert!(
            m.variants[0].footprint_bits > m.variants[1].footprint_bits,
            "8-bit variant must report the larger activation footprint"
        );
        assert_eq!(m.shards.len(), 3);
        assert_eq!(m.replicas, 3);
        assert_eq!(m.param_bytes, weights.param_bytes());
        let per_variant: u64 = m.variants.iter().map(|v| v.total.requests).sum();
        assert_eq!(per_variant, m.total.requests, "variant totals must sum to the model's");
        // pinned dispatch uses the SAME flattened shard index as the
        // metrics view: shards 0-1 are a8w8's, shard 2 is a4w8's only
        // shard; one past the end is an error naming the real total.
        assert_eq!(router.infer_on("m", 1, img(5)).unwrap().logits, want_a);
        assert_eq!(router.infer_on("m", 2, img(5)).unwrap().logits, want_b);
        let err = router.infer_on("m", 3, img(5)).unwrap_err().to_string();
        assert!(err.contains("3 shard(s)"), "{err}");
        // '@' in a model name would be unreachable over the HTTP front
        // door's {model}@{variant} syntax — a build error, not a trap
        let err = InferenceRouter::builder()
            .model_variant("m@v2", "a", pa.clone(), 1, quick_policy(2))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains('@'), "{err}");
        // a variant over a *different* weights allocation is rejected
        let stranger = tiny_params(0);
        let err = InferenceRouter::builder()
            .model_variant("m", "a", pa.clone(), 1, quick_policy(2))
            .model_variant("m", "b", stranger, 1, quick_policy(2))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("share"), "{err}");
        // duplicate (model, variant) pairs are rejected
        let err = InferenceRouter::builder()
            .model_variant("m", "a", pa.clone(), 1, quick_policy(2))
            .model_variant("m", "a", pb.clone(), 1, quick_policy(2))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn round_robin_sharding_is_deterministic() {
        let router = InferenceRouter::builder()
            .model("m", tiny_params(0), 3, quick_policy(1))
            .build()
            .unwrap();
        // 9 sequential requests over 3 idle shards: every queue gauge
        // reads 0 at dispatch time, so load-aware picking degenerates
        // to its rotating tie-break — exactly 3 per shard, in order
        // 0,1,2,0,1,2,... (deterministic dispatch for idle routers).
        for i in 0..9 {
            router.infer("m", img(i)).unwrap();
        }
        let m = router.metrics("m").unwrap();
        let per_shard: Vec<u64> = m.shards.iter().map(|s| s.batcher.requests).collect();
        assert_eq!(per_shard, vec![3, 3, 3], "round-robin skewed: {per_shard:?}");
        assert_eq!(m.total.requests, 9);
    }

    #[test]
    fn dispatch_by_model_name() {
        // Two different parameterizations under one router: replies must
        // come from the model addressed by name.
        let pa = tiny_params(0);
        let pb = tiny_params(11);
        let router = InferenceRouter::builder()
            .model("alpha", pa.clone(), 2, quick_policy(2))
            .model("beta", pb.clone(), 1, quick_policy(2))
            .build()
            .unwrap();
        assert_eq!(router.model_names(), vec!["alpha", "beta"]);
        let want_a = Engine::from_params(pa).forward(&img(3), 1).unwrap();
        let want_b = Engine::from_params(pb).forward(&img(3), 1).unwrap();
        assert_ne!(want_a, want_b, "test models degenerate: identical outputs");
        assert_eq!(router.infer("alpha", img(3)).unwrap().logits, want_a);
        assert_eq!(router.infer("beta", img(3)).unwrap().logits, want_b);
        // unknown names are a descriptive error, not a panic
        let err = router.infer("gamma", img(0)).unwrap_err().to_string();
        assert!(err.contains("gamma") && err.contains("alpha"), "{err}");
    }

    #[test]
    fn poisoned_replica_errors_its_own_callers_only() {
        // shard 0 echoes; shard 1 always fails. Callers pinned to shard
        // 1 get the real error; shard 0 callers are unaffected — before
        // and after the failures.
        let ok: Box<ExecuteFn> =
            Box::new(|buf: &[f32], bsz: usize| Ok(buf[..bsz].to_vec()));
        let poisoned: Box<ExecuteFn> =
            Box::new(|_buf: &[f32], _bsz: usize| Err(anyhow::anyhow!("replica 1 lost its device")));
        let router = InferenceRouter::builder()
            .model_from_executors("m", 1, 1, vec![ok, poisoned], quick_policy(2))
            .build()
            .unwrap();
        assert_eq!(router.infer_on("m", 0, vec![5.0]).unwrap().logits, vec![5.0]);
        for _ in 0..3 {
            let msg = router.infer_on("m", 1, vec![6.0]).unwrap_err().to_string();
            assert!(msg.contains("replica 1 lost its device"), "{msg}");
        }
        // sibling shard still healthy after repeated failures next door
        assert_eq!(router.infer_on("m", 0, vec![7.0]).unwrap().logits, vec![7.0]);
        let m = router.metrics("m").unwrap();
        assert_eq!(m.shards[0].batcher.exec_errors, 0, "healthy shard counted errors");
        assert!(m.shards[1].batcher.exec_errors >= 3);
        assert!(m.total.exec_errors >= 3);
        // out-of-range shard index is an error, not a panic
        assert!(router.infer_on("m", 2, vec![0.0]).is_err());
    }

    #[test]
    fn aggregate_metrics_are_consistent_under_concurrent_load() {
        let router = Arc::new(
            InferenceRouter::builder()
                .model("m", tiny_params(0), 3, quick_policy(4))
                .build()
                .unwrap(),
        );
        let engine = Engine::from_params(tiny_params(0));
        let (threads, per) = (8usize, 12usize);
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let r = router.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        let idx = t * per + i;
                        let reply = r.infer("m", img(idx)).unwrap();
                        assert_eq!(reply.logits.len(), 2);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // spot-check correctness of a routed answer after the storm
        assert_eq!(
            router.infer("m", img(1)).unwrap().logits,
            engine.forward(&img(1), 1).unwrap()
        );
        let total_sent = (threads * per) as u64 + 1;
        let m = router.metrics("m").unwrap();
        assert_eq!(m.total.requests, total_sent, "aggregate lost requests");
        let per_shard_sum: u64 = m.shards.iter().map(|s| s.batcher.requests).sum();
        assert_eq!(per_shard_sum, total_sent, "shard sum != aggregate");
        let completed_sum: u64 = m.shards.iter().map(|s| s.completed).sum();
        assert_eq!(completed_sum, total_sent, "latency counts lost requests");
        assert_eq!(m.total.exec_errors, 0);
        assert_eq!(m.total.queue_depth, 0, "queues must drain");
        assert_eq!(router.aggregate().requests, total_sent);
    }

    #[test]
    fn bounded_shard_queue_returns_overload_not_oom() {
        // One slow executor shard with queue depth 2: a burst must see
        // overload errors while admitted requests all finish.
        let slow: Box<ExecuteFn> = Box::new(|buf: &[f32], bsz: usize| {
            std::thread::sleep(Duration::from_millis(2));
            Ok(buf[..bsz].to_vec())
        });
        let router = Arc::new(
            InferenceRouter::builder()
                .model_from_executors(
                    "m",
                    1,
                    1,
                    vec![slow],
                    BatchPolicy {
                        max_batch: 1,
                        max_wait: Duration::from_micros(50),
                        max_queue_depth: 2,
                        overload: OverloadPolicy::RejectNewest,
                        ..BatchPolicy::default()
                    },
                )
                .build()
                .unwrap(),
        );
        let handles: Vec<_> = (0..12)
            .map(|i| {
                let r = router.clone();
                std::thread::spawn(move || r.infer("m", vec![i as f32]).map(|_| ()))
            })
            .collect();
        let mut overloads = 0;
        for h in handles {
            if let Err(e) = h.join().unwrap() {
                assert!(e.to_string().contains("overloaded"), "{e}");
                overloads += 1;
            }
        }
        let m = router.metrics("m").unwrap();
        assert_eq!(m.total.rejected, overloads);
        assert_eq!(m.total.requests + m.total.rejected, 12);
        assert!(m.total.peak_queue_depth <= 2, "queue exceeded bound: {:?}", m.total);
    }

    #[test]
    fn load_aware_dispatch_starves_the_backed_up_shard() {
        use std::sync::mpsc::channel;
        // shard 0 parks inside execute() until gated; shard 1 replies
        // instantly. ROADMAP "load-aware dispatch": the deep queue must
        // stop receiving new work.
        let (gate_tx, gate_rx) = channel::<()>();
        let (entered_tx, entered_rx) = channel::<()>();
        let gated: Box<ExecuteFn> = Box::new(move |buf: &[f32], bsz: usize| {
            entered_tx.send(()).ok();
            gate_rx.recv().ok();
            Ok(buf[..bsz].to_vec())
        });
        let fast: Box<ExecuteFn> = Box::new(|buf: &[f32], bsz: usize| Ok(buf[..bsz].to_vec()));
        let router = Arc::new(
            InferenceRouter::builder()
                .model_from_executors("m", 1, 1, vec![gated, fast], quick_policy(1))
                .build()
                .unwrap(),
        );
        // Occupy shard 0: one in-flight request parks its worker, one
        // queued request raises its live queue_depth gauge to 1.
        let r0 = router.clone();
        let inflight = std::thread::spawn(move || r0.infer_on("m", 0, vec![100.0]).unwrap());
        entered_rx.recv().unwrap();
        let r0 = router.clone();
        let queued = std::thread::spawn(move || r0.infer_on("m", 0, vec![101.0]).unwrap());
        let deadline = Instant::now() + Duration::from_secs(10);
        while router.metrics("m").unwrap().shards[0].batcher.queue_depth == 0 {
            assert!(Instant::now() < deadline, "queued request never raised the depth gauge");
            std::thread::yield_now();
        }
        // Every new request must now route to shard 1 (gauge 0) rather
        // than blind round-robin alternating onto the stuck shard.
        for i in 0..8 {
            assert_eq!(router.infer("m", vec![i as f32]).unwrap().logits, vec![i as f32]);
        }
        let m = router.metrics("m").unwrap();
        assert_eq!(m.shards[1].batcher.requests, 8, "fast shard missed traffic");
        assert_eq!(m.shards[0].batcher.requests, 0, "backed-up shard must be starved");
        // Release the gate: the pinned requests still complete on shard
        // 0 — load-awareness never touches pinned dispatch.
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        assert_eq!(inflight.join().unwrap().logits, vec![100.0]);
        assert_eq!(queued.join().unwrap().logits, vec![101.0]);
        assert_eq!(router.metrics("m").unwrap().shards[0].batcher.requests, 2);
    }

    #[test]
    fn submit_returns_pollable_replies_with_live_results() {
        let params = tiny_params(0);
        let router = InferenceRouter::builder()
            .model("m", params.clone(), 2, quick_policy(2))
            .build()
            .unwrap();
        let engine = Engine::from_params(params);
        // Non-blocking path: submit a burst, then poll every reply to
        // completion — results must be bit-identical to direct forward.
        let mut pending: Vec<_> =
            (0..6).map(|i| (i, router.submit("m", img(i)).unwrap())).collect();
        let deadline = Instant::now() + Duration::from_secs(10);
        while !pending.is_empty() {
            assert!(Instant::now() < deadline, "submitted replies never resolved");
            pending.retain_mut(|(i, p)| match p.try_wait() {
                None => true,
                Some(outcome) => {
                    let reply = outcome.expect("healthy router must not fail");
                    assert_eq!(
                        reply.logits,
                        engine.forward(&img(*i), 1).unwrap(),
                        "submit path diverged from direct forward for image {i}"
                    );
                    false
                }
            });
            std::thread::sleep(Duration::from_micros(50));
        }
        assert_eq!(router.aggregate().requests, 6);
    }

    #[test]
    fn builder_rejects_bad_configs() {
        assert!(InferenceRouter::builder().build().is_err(), "empty router must not build");
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 0, quick_policy(1))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains(">= 1"), "{err}");
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, quick_policy(1))
            .model("m", tiny_params(0), 1, quick_policy(1))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
        // degenerate policies are build errors, not spawn panics
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, quick_policy(0))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_batch"), "{err}");
        let bad_depth = BatchPolicy { max_queue_depth: 0, ..BatchPolicy::default() };
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, bad_depth)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_queue_depth"), "{err}");
        // A queue deadline inside the batch-fill window would shed every
        // request on an idle server — a build error, not a footgun.
        let bad_deadline = BatchPolicy {
            max_wait: Duration::from_millis(5),
            max_queue_wait: Some(Duration::from_millis(3)),
            ..BatchPolicy::default()
        };
        let err = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, bad_deadline)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_queue_wait"), "{err}");
    }

    /// The tentpole acceptance bar: N client threads hammer `infer`
    /// while the main thread performs 8 consecutive hot-swaps. Zero
    /// requests may fail, every reply must be bit-identical to a
    /// generation's reference output (nothing torn across a swap), the
    /// final generation must serve after the storm, and — once traffic
    /// stops — every superseded generation must fully drain (its
    /// `Arc::strong_count` falls to the retired list's own reference
    /// and the sweep records it).
    #[test]
    fn hot_swap_storm_never_tears_or_drops_a_response() {
        use std::sync::atomic::AtomicBool;
        const GENS: usize = 9; // build seed 0 + 8 reloads
        const CLIENTS: usize = 3;
        let router = Arc::new(
            InferenceRouter::builder()
                .model("m", tiny_params(0), 2, quick_policy(2))
                .build()
                .unwrap(),
        );
        // Per-generation reference logits for each client's image,
        // computed on throwaway engines.
        let expected: Vec<Vec<Vec<f32>>> = (0..GENS)
            .map(|g| {
                let engine = Engine::from_params(tiny_params(g as i8));
                (0..CLIENTS).map(|t| engine.forward(&img(t), 1).unwrap()).collect()
            })
            .collect();
        // Consecutive seeds must produce distinct logits, or "the swap
        // published" below would be vacuous.
        for g in 1..GENS {
            assert_ne!(expected[g - 1], expected[g], "seeds {} and {g} collide", g - 1);
        }
        let stop = Arc::new(AtomicBool::new(false));
        let warmed = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..CLIENTS)
            .map(|t| {
                let router = Arc::clone(&router);
                let stop = Arc::clone(&stop);
                let warmed = Arc::clone(&warmed);
                let mine: Vec<Vec<f32>> = expected.iter().map(|per| per[t].clone()).collect();
                std::thread::spawn(move || -> usize {
                    let mut served = 0usize;
                    while !stop.load(Relaxed) {
                        let reply = router
                            .infer("m", img(t))
                            .expect("a hot-swap must never fail a request");
                        // Matching some generation's exact output proves
                        // the batch ran wholly on one version.
                        assert!(
                            mine.iter().any(|e| reply.logits == *e),
                            "client {t} got logits matching no generation (torn response)"
                        );
                        served += 1;
                        if served == 1 {
                            warmed.fetch_add(1, Relaxed);
                        }
                    }
                    served
                })
            })
            .collect();
        // Every client completes >= 1 request on the build generation
        // before the storm begins.
        let deadline = Instant::now() + Duration::from_secs(10);
        while warmed.load(Relaxed) < CLIENTS {
            assert!(Instant::now() < deadline, "clients never got a first reply");
            std::thread::yield_now();
        }
        // 8 consecutive immediate swaps under live traffic.
        for g in 1..GENS {
            let generation = router
                .reload_variant(
                    "m",
                    DEFAULT_VARIANT,
                    ReloadSpec {
                        source: ReloadSource::Params(tiny_params(g as i8)),
                        rollout: RolloutConfig { canary_share: 0, ..RolloutConfig::default() },
                        provenance: None,
                    },
                )
                .unwrap();
            assert_eq!(generation, (g + 1) as u64, "generations number up consecutively");
            std::thread::sleep(Duration::from_millis(2));
        }
        stop.store(true, Relaxed);
        let total: usize = handles.into_iter().map(|h| h.join().expect("client panicked")).sum();
        assert!(total >= CLIENTS, "clients served no traffic");
        // The last swap published: post-storm traffic serves the final
        // generation's exact logits.
        let last = router.infer("m", img(0)).unwrap();
        assert_eq!(last.logits, expected[GENS - 1][0], "final generation not serving");
        let version = router.variant_version("m", DEFAULT_VARIANT).unwrap().unwrap();
        assert_eq!(version.generation, GENS as u64);
        // Drain: with traffic stopped, all 8 superseded generations
        // reach strong_count == 1 and sweep into the drained list.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let st = router.variant_rollout("m", DEFAULT_VARIANT).unwrap().unwrap();
            if st.draining.is_empty() {
                assert_eq!(st.state(), "serving");
                let mut drained = st.drained.clone();
                drained.sort_unstable();
                assert_eq!(drained, (1..GENS as u64).collect::<Vec<_>>());
                let served: u64 = st.served.values().sum();
                assert!(served >= total as u64, "served rows undercounted");
                break;
            }
            assert!(
                Instant::now() < deadline,
                "retired generations never drained: {:?}",
                st.draining
            );
            std::thread::yield_now();
        }
    }

    /// `tiny_params(0)` with fc weights+biases negated: identical
    /// shapes, but the top-1 class flips on every input (2-class argmax
    /// of negated logits), so a canary against it measures 0 agreement.
    fn inverted_params() -> Arc<ModelParams> {
        let (graph, weights) = tiny_graph_weights(0);
        let mut w = (*weights).clone();
        for v in &mut w.fc_w {
            *v = -*v;
        }
        for v in &mut w.fc_b {
            *v = -*v;
        }
        Arc::new(
            ModelParams::new(
                graph,
                Arc::new(w),
                SparqConfig::named("5opt_r").unwrap(),
                &[0.02],
                EngineMode::Dense,
            )
            .unwrap(),
        )
    }

    /// Canary lifecycle through real traffic: a value-identical reload
    /// measures perfect agreement and auto-promotes; a top-1-flipping
    /// reload measures zero agreement and auto-rolls-back, leaving the
    /// original generation serving.
    #[test]
    fn canary_promotes_on_agreement_and_rolls_back_on_divergence() {
        let params = tiny_params(0);
        let router = InferenceRouter::builder()
            .model("m", params.clone(), 1, quick_policy(2))
            .build()
            .unwrap();
        let engine = Engine::from_params(params);
        let canary = RolloutConfig { canary_share: 1, promote_threshold: 0.5, min_requests: 2 };

        // --- promote: same values, new generation → agreement 1.0
        let gen2 = router
            .reload_variant(
                "m",
                DEFAULT_VARIANT,
                ReloadSpec {
                    source: ReloadSource::Params(tiny_params(0)),
                    rollout: canary,
                    provenance: None,
                },
            )
            .unwrap();
        assert_eq!(gen2, 2);
        let st = router.variant_rollout("m", DEFAULT_VARIANT).unwrap().unwrap();
        assert_eq!(st.state(), "canary");
        assert_eq!(st.canary.as_ref().map(|c| c.generation), Some(gen2));
        // share 1 → every batch is a canary; 2 single-row batches reach
        // min_requests and land the verdict synchronously.
        for i in 0..2 {
            let reply = router.infer("m", img(i)).unwrap();
            assert_eq!(reply.logits, engine.forward(&img(i), 1).unwrap());
        }
        let st = router.variant_rollout("m", DEFAULT_VARIANT).unwrap().unwrap();
        let outcome = st.last_outcome.clone().expect("verdict landed");
        assert!(outcome.promoted, "identical values must promote: {outcome:?}");
        assert_eq!(outcome.agreement, Some(1.0));
        assert_eq!(
            router.variant_version("m", DEFAULT_VARIANT).unwrap().unwrap().generation,
            gen2
        );

        // --- rollback: flipped top-1 on every row → agreement 0.0
        let gen3 = router
            .reload_variant(
                "m",
                DEFAULT_VARIANT,
                ReloadSpec {
                    source: ReloadSource::Params(inverted_params()),
                    rollout: canary,
                    provenance: None,
                },
            )
            .unwrap();
        assert_eq!(gen3, 3);
        // Canary batches serve the *incoming* generation's logits —
        // real traffic, not a shadow mirror.
        let inverted = Engine::from_params(inverted_params());
        for i in 0..2 {
            let reply = router.infer("m", img(i)).unwrap();
            assert_eq!(reply.logits, inverted.forward(&img(i), 1).unwrap());
        }
        let st = router.variant_rollout("m", DEFAULT_VARIANT).unwrap().unwrap();
        let outcome = st.last_outcome.clone().expect("verdict landed");
        assert!(!outcome.promoted, "flipped logits must roll back: {outcome:?}");
        assert_eq!(outcome.agreement, Some(0.0));
        // The original (promoted) generation still serves, bit-exact.
        assert_eq!(
            router.variant_version("m", DEFAULT_VARIANT).unwrap().unwrap().generation,
            gen2
        );
        let reply = router.infer("m", img(5)).unwrap();
        assert_eq!(reply.logits, engine.forward(&img(5), 1).unwrap());
        // per-generation served counters saw all three generations
        let st = router.variant_rollout("m", DEFAULT_VARIANT).unwrap().unwrap();
        assert!(st.served.contains_key(&gen2));
        assert!(st.served.contains_key(&gen3));
    }

    /// Reload guardrails at the router level: executor-backed variants
    /// refuse, shape changes refuse (and record the staging error),
    /// unknown models/variants name what exists.
    #[test]
    fn reload_rejects_executor_backed_shape_changed_and_unknown_targets() {
        let spec = || ReloadSpec {
            source: ReloadSource::Params(tiny_params(1)),
            rollout: RolloutConfig { canary_share: 0, ..RolloutConfig::default() },
            provenance: None,
        };
        let exec: Box<ExecuteFn> =
            Box::new(|_buf: &[f32], bsz: usize| Ok(vec![0.0; 2 * bsz]));
        let router = InferenceRouter::builder()
            .model("m", tiny_params(0), 1, quick_policy(2))
            .model_from_executors("raw", 16, 2, vec![exec], quick_policy(2))
            .build()
            .unwrap();
        let err = router.reload_variant("raw", DEFAULT_VARIANT, spec()).unwrap_err().to_string();
        assert!(err.contains("executor-backed"), "{err}");
        let err = router.reload_variant("ghost", DEFAULT_VARIANT, spec()).unwrap_err().to_string();
        assert!(err.contains("no model named"), "{err}");
        let err = router.reload_variant("m", "ghost", spec()).unwrap_err().to_string();
        assert!(err.contains("no variant"), "{err}");
        // zero-amplitude perturb is a staging error and lands in status
        let err = router
            .reload_variant(
                "m",
                DEFAULT_VARIANT,
                ReloadSpec {
                    source: ReloadSource::Perturb { seed: 1, amplitude: 0 },
                    rollout: RolloutConfig::default(),
                    provenance: None,
                },
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("amplitude"), "{err}");
        let st = router.variant_rollout("m", DEFAULT_VARIANT).unwrap().unwrap();
        assert!(st.last_error.as_deref().is_some_and(|e| e.contains("staging failed")), "{st:?}");
        // executor-backed variants report no version/rollout, params ones do
        assert!(router.variant_version("raw", DEFAULT_VARIANT).unwrap().is_none());
        assert!(router.variant_rollout("raw", DEFAULT_VARIANT).unwrap().is_none());
        assert_eq!(
            router.variant_version("m", DEFAULT_VARIANT).unwrap().unwrap().generation,
            FIRST_GENERATION
        );
    }

    /// A small deterministic perturbation keeps every top-1 intact on
    /// the tiny model (checked against a locally perturbed reference
    /// engine first, so the test never depends on luck), and a
    /// `Perturb` reload therefore canary-promotes with logits that
    /// bit-differ from the old generation.
    #[test]
    fn perturb_reload_changes_logits_and_canaries_on_real_agreement() {
        let params = tiny_params(0);
        let router = InferenceRouter::builder()
            .model("m", params.clone(), 1, quick_policy(2))
            .build()
            .unwrap();
        let engine = Engine::from_params(params.clone());
        // Reference: what the perturbed generation computes.
        let perturbed = Arc::new(
            ModelParams::new(
                Arc::clone(&params.graph),
                Arc::new(perturb_weights(&params.weights, 42, 3)),
                SparqConfig::named("5opt_r").unwrap(),
                &[0.02],
                EngineMode::Dense,
            )
            .unwrap(),
        );
        let pengine = Engine::from_params(perturbed);
        let probe: Vec<usize> = (0..8).collect();
        let agreeing: Vec<usize> = probe
            .iter()
            .copied()
            .filter(|&i| {
                let a = engine.forward(&img(i), 1).unwrap();
                let b = pengine.forward(&img(i), 1).unwrap();
                assert_ne!(a, b, "amplitude-3 perturbation must change logits bit-wise");
                registry::top1_agreement(&a, &b, 2) == 1
            })
            .collect();
        assert!(
            agreeing.len() >= 2,
            "perturbation flipped top-1 on nearly every probe image — pick a new seed"
        );
        let gen2 = router
            .reload_variant(
                "m",
                DEFAULT_VARIANT,
                ReloadSpec {
                    source: ReloadSource::Perturb { seed: 42, amplitude: 3 },
                    rollout: RolloutConfig {
                        canary_share: 1,
                        promote_threshold: 1.0,
                        min_requests: agreeing.len() as u64,
                    },
                    provenance: None,
                },
            )
            .unwrap();
        // Drive exactly the images the perturbed model agrees on →
        // agreement 1.0 ≥ threshold → promote.
        for &i in &agreeing {
            let reply = router.infer("m", img(i)).unwrap();
            assert_eq!(reply.logits, pengine.forward(&img(i), 1).unwrap());
        }
        let st = router.variant_rollout("m", DEFAULT_VARIANT).unwrap().unwrap();
        let outcome = st.last_outcome.clone().expect("verdict landed");
        assert!(outcome.promoted, "{outcome:?}");
        let version = router.variant_version("m", DEFAULT_VARIANT).unwrap().unwrap();
        assert_eq!(version.generation, gen2);
        // same seed+amplitude → same weights → same content hash as the
        // locally perturbed reference
        assert_eq!(version.weights_sha, pengine.params().weights.content_sha());
    }

    /// Satellite regression: unknown-variant errors on BOTH dispatch
    /// entry points (`infer_variant` and `submit_variant`) name the
    /// real variants, exactly like the HTTP 404 body does — and the
    /// executor-backed variant builder composes into one model.
    #[test]
    fn unknown_variant_errors_list_the_known_variants() {
        let echo = || -> Box<ExecuteFn> { Box::new(|buf: &[f32], bsz: usize| Ok(buf[..bsz].to_vec())) };
        let router = InferenceRouter::builder()
            .model_variant_from_executors("m", "full", 1, 1, vec![echo()], quick_policy(1))
            .model_variant_from_executors("m", "cheap", 1, 1, vec![echo()], quick_policy(1))
            .build()
            .unwrap();
        assert_eq!(router.variant_names("m").unwrap(), vec!["full", "cheap"]);
        let err = router.infer_variant("m", "nope", vec![0.0]).unwrap_err().to_string();
        assert!(
            err.contains("nope") && err.contains("full") && err.contains("cheap"),
            "infer_variant error must list known variants: {err}"
        );
        let err = router.submit_variant("m", "nope", vec![0.0]).unwrap_err().to_string();
        assert!(
            err.contains("nope") && err.contains("full") && err.contains("cheap"),
            "submit_variant error must list known variants: {err}"
        );
        // unknown model on the submit path lists the registered models
        let err = router.submit_variant("ghost", "full", vec![0.0]).unwrap_err().to_string();
        assert!(err.contains("ghost") && err.contains("\"m\""), "{err}");
    }

    /// SLO install validation happens against the live registry: rungs
    /// must exist (error lists the real variants), rung 0 must be the
    /// default, the ladder must not increase footprint_bits, and
    /// clearing restores plain default dispatch.
    #[test]
    fn slo_policy_install_validates_against_the_registry() {
        use crate::quant::QuantPolicy;
        let (graph, weights) = tiny_graph_weights(0);
        let mk = |policy: &str| {
            Arc::new(
                ModelParams::with_policy(
                    graph.clone(),
                    weights.clone(),
                    QuantPolicy::named(policy).unwrap(),
                    &[0.02],
                    EngineMode::Dense,
                )
                .unwrap(),
            )
        };
        // a4w8 registered FIRST → it is the default (and the cheaper
        // operating point), so an a4w8→a8w8 ladder is footprint-increasing.
        let router = InferenceRouter::builder()
            .model_variant("m", "a4w8", mk("a4w8"), 1, quick_policy(2))
            .model_variant("m", "a8w8", mk("a8w8"), 1, quick_policy(2))
            .build()
            .unwrap();
        let pol = |ladder: &[&str]| {
            SloPolicy::new(ladder.iter().map(|s| s.to_string()).collect(), 4, 0, 0, 0.5)
                .unwrap()
        };
        let err =
            router.set_slo_policy("m", Some(pol(&["a4w8", "ghost"]))).unwrap_err().to_string();
        assert!(err.contains("ghost") && err.contains("a8w8"), "{err}");
        let err =
            router.set_slo_policy("m", Some(pol(&["a8w8", "a4w8"]))).unwrap_err().to_string();
        assert!(err.contains("rung 0") && err.contains("a4w8"), "{err}");
        let err =
            router.set_slo_policy("m", Some(pol(&["a4w8", "a8w8"]))).unwrap_err().to_string();
        assert!(err.contains("footprint_bits"), "{err}");
        assert!(router.set_slo_policy("ghost", None).is_err());
        // No policy survived any failed install: status is None and
        // dispatch is the plain default path.
        assert!(router.slo_status("m").unwrap().is_none());
        assert_eq!(router.serving_variant("m").unwrap(), "a4w8");
        assert!(router.metrics("m").unwrap().slo.is_none());
    }

    /// The tentpole behavior at router level: a parked default variant
    /// crosses its queue-depth SLO, unaddressed dispatch degrades to
    /// the cheaper rung (first transition dwell-exempt), degraded time
    /// and transition counters accumulate, and once the backlog drains
    /// and dwell expires the default rung resumes serving.
    #[test]
    fn ladder_degrades_under_pressure_and_recovers_after_dwell() {
        use std::sync::mpsc::channel;
        let (gate_tx, gate_rx) = channel::<()>();
        let (entered_tx, entered_rx) = channel::<()>();
        // "full" parks inside execute() until the gate DROPS (recv then
        // errors → instant forever after); "cheap" answers immediately.
        // Distinct constant logits tell us who served each request.
        let full: Box<ExecuteFn> = Box::new(move |_buf: &[f32], bsz: usize| {
            entered_tx.send(()).ok();
            gate_rx.recv().ok();
            Ok(vec![1.0; bsz])
        });
        let cheap: Box<ExecuteFn> = Box::new(|_buf: &[f32], bsz: usize| Ok(vec![2.0; bsz]));
        let router = Arc::new(
            InferenceRouter::builder()
                .model_variant_from_executors("m", "full", 1, 1, vec![full], quick_policy(1))
                .model_variant_from_executors("m", "cheap", 1, 1, vec![cheap], quick_policy(1))
                .build()
                .unwrap(),
        );
        // Back up the full variant: one in-flight request parks its only
        // worker, two pinned queued requests raise its depth gauge to 2.
        let r0 = router.clone();
        let inflight = std::thread::spawn(move || r0.infer_on("m", 0, vec![0.0]).unwrap());
        entered_rx.recv().unwrap();
        let queued: Vec<_> = (0..2)
            .map(|_| {
                let r = router.clone();
                std::thread::spawn(move || r.infer_on("m", 0, vec![0.0]).unwrap())
            })
            .collect();
        let deadline = Instant::now() + Duration::from_secs(10);
        while router.metrics("m").unwrap().shards[0].batcher.queue_depth < 2 {
            assert!(Instant::now() < deadline, "queued requests never raised the gauge");
            std::thread::yield_now();
        }
        // Install the ladder mid-overload: depth trigger 1 (breached at
        // 2), p99 disabled, dwell 30ms, margin 1.0 (recover as soon as
        // the serving rung's depth is back at/below 1).
        let policy = SloPolicy::new(
            vec!["full".into(), "cheap".into()],
            1,
            0,
            30_000,
            1.0,
        )
        .unwrap();
        router.set_slo_policy("m", Some(policy)).unwrap();
        // The first unaddressed request samples the breach and — first
        // transition being dwell-exempt — serves the cheap rung at once.
        for i in 0..3 {
            let reply = router.infer("m", vec![i as f32]).unwrap();
            assert_eq!(reply.logits, vec![2.0], "request {i} not served by the cheap rung");
        }
        assert_eq!(router.serving_variant("m").unwrap(), "cheap");
        let st = router.slo_status("m").unwrap().unwrap();
        assert!(st.degraded && st.rung == 1 && st.serving == "cheap", "{st:?}");
        assert_eq!(st.transitions_down, 1);
        std::thread::sleep(Duration::from_millis(2));
        let m = router.metrics("m").unwrap();
        let st = m.slo.unwrap();
        assert!(st.time_degraded_us > 0, "degraded clock never advanced: {st:?}");
        // Clear the overload: dropping the gate unparks the worker and
        // makes "full" instant; the pinned backlog drains.
        drop(gate_tx);
        assert_eq!(inflight.join().unwrap().logits, vec![1.0]);
        for q in queued {
            assert_eq!(q.join().unwrap().logits, vec![1.0]);
        }
        assert_eq!(router.metrics("m").unwrap().shards[0].batcher.queue_depth, 0);
        // Once dwell expires, a calm sample steps the ladder back up and
        // that same request is served by the default rung again.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let reply = router.infer("m", vec![9.0]).unwrap();
            if reply.logits == vec![1.0] {
                break;
            }
            assert!(Instant::now() < deadline, "ladder never recovered to the default rung");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(router.serving_variant("m").unwrap(), "full");
        let st = router.slo_status("m").unwrap().unwrap();
        assert!(!st.degraded && st.rung == 0 && st.serving == "full", "{st:?}");
        assert!(st.transitions_up >= 1 && st.transitions_down >= 1, "{st:?}");
        assert!(st.time_degraded_us > 0);
        // Clearing the policy restores plain default dispatch and a
        // None status.
        router.set_slo_policy("m", None).unwrap();
        assert!(router.slo_status("m").unwrap().is_none());
        assert_eq!(router.serving_variant("m").unwrap(), "full");
    }
}
