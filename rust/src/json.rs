//! Minimal JSON substrate (parse + serialize) — no serde in the image's
//! baked dependency set, and the formats we exchange (manifest, model
//! meta, experiment reports) are small, so a strict recursive-descent
//! parser is sufficient and keeps the dependency surface at zero.
//!
//! Supports the full JSON grammar (RFC 8259) minus `\u` surrogate pairs
//! beyond the BMP (not produced by our python exporters).
//!
//! The HTTP front door ([`crate::coordinator::http`]) feeds this parser
//! **untrusted network bodies**, so recursion is bounded: containers
//! nested deeper than [`MAX_DEPTH`] are a parse error, not a stack
//! overflow that would take the serving thread down.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

/// Maximum container nesting the recursive-descent parser accepts.
/// Deep enough for any format this repo exchanges (manifests, model
/// meta, inference requests are < 10 levels), shallow enough that a
/// hostile `[[[[…` body errors long before the thread stack is at risk.
pub const MAX_DEPTH: usize = 128;

impl JsonValue {
    pub fn parse(text: &str) -> Result<Self> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Self::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Self::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Self::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            Self::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            Self::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; None for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Serialize (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Self::Null => out.push_str("null"),
            Self::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Self::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Self::String(s) => write_escaped(out, s),
            Self::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Self::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for JsonValue {
    fn from(v: f64) -> Self {
        Self::Number(v)
    }
}

impl From<usize> for JsonValue {
    fn from(v: usize) -> Self {
        Self::Number(v as f64)
    }
}

impl From<&str> for JsonValue {
    fn from(v: &str) -> Self {
        Self::String(v.to_string())
    }
}

impl From<String> for JsonValue {
    fn from(v: String) -> Self {
        Self::String(v)
    }
}

impl From<bool> for JsonValue {
    fn from(v: bool) -> Self {
        Self::Bool(v)
    }
}

impl<T: Into<JsonValue>> From<Vec<T>> for JsonValue {
    fn from(v: Vec<T>) -> Self {
        Self::Array(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for object literals.
#[macro_export]
macro_rules! json_obj {
    ($($key:expr => $val:expr),* $(,)?) => {{
        let mut m = std::collections::BTreeMap::new();
        $( m.insert($key.to_string(), $crate::json::JsonValue::from($val)); )*
        $crate::json::JsonValue::Object(m)
    }};
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting, bounded by [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    /// Account one level of container nesting; errors past [`MAX_DEPTH`]
    /// so untrusted input cannot recurse the stack away. Paired with a
    /// `depth -= 1` at each container's successful exit.
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH} levels at byte {}", self.pos);
        }
        Ok(())
    }
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(JsonValue::Number(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("short \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow::anyhow!("bad codepoint {cp:#x}"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(JsonValue::Array(items));
                }
                other => bail!("expected , or ] got {other:?} at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(JsonValue::Object(map));
                }
                other => bail!("expected , or }} got {other:?} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\ny"}"#;
        let v = JsonValue::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("e").unwrap().as_str(), Some("x\ny"));
        let re = JsonValue::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("[1] x").is_err());
        assert!(JsonValue::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = JsonValue::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn obj_macro() {
        let v = json_obj! {"k" => 1.5, "s" => "v", "arr" => vec![1usize, 2]};
        assert_eq!(v.get("k").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("arr").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(JsonValue::parse("[]").unwrap(), JsonValue::Array(vec![]));
        assert_eq!(JsonValue::parse("{}").unwrap(), JsonValue::Object(BTreeMap::new()));
    }

    #[test]
    fn deep_nesting_is_a_parse_error_not_a_stack_overflow() {
        // An attacker-sized body: 100k unclosed opens used to recurse
        // once per byte and blow the serving thread's stack. It must be
        // a descriptive error now.
        for open in ["[", "{\"k\":"] {
            let hostile = open.repeat(100_000);
            let err = JsonValue::parse(&hostile).unwrap_err().to_string();
            assert!(err.contains("deeper than"), "wrong error for {open:?}: {err}");
        }
        // Balanced-but-too-deep input errors the same way.
        let too_deep = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(JsonValue::parse(&too_deep).is_err());
        // The cap leaves honest nesting untouched: MAX_DEPTH exactly.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        let v = JsonValue::parse(&ok).unwrap();
        // siblings at the same level do not accumulate depth
        let wide = "[[1,2],[3,4],[5,6]]".to_string();
        assert!(JsonValue::parse(&wide).is_ok());
        let mut probe = &v;
        let mut levels = 0;
        while let JsonValue::Array(items) = probe {
            levels += 1;
            match items.first() {
                Some(inner) => probe = inner,
                None => break,
            }
        }
        assert_eq!(levels, MAX_DEPTH);
    }
}
